//! The discrete-event simulation engine — also the API-server facade: it
//! receives pod requests, drives the watcher, invokes the scheduler, binds
//! pods, and runs the kubelet pull/start lifecycle against the link model.
//!
//! The engine is a true event-driven core: arrivals, pull completions,
//! terminations, watcher ticks, GC sweeps, and scheduling-queue back-off
//! releases are timestamped events popped in order from one
//! [`EventQueue`] (`sim::events`). Unschedulable pods are not dropped:
//! they park in a [`SchedulingQueue`] with back-off and retry until they
//! bind or exhaust `SimConfig::retry_limit`.
//!
//! Two arrival modes reproduce the paper's protocols:
//! - **Sequential** (`inter_arrival_secs = None`): deploy, wait until the
//!   container is ready (or the pod gives up), then submit the next pod —
//!   §VI-B's measurement protocol for Table I / Fig. 5.
//! - **Timed arrivals** (`Some(dt)`): pods arrive every `dt` seconds and
//!   pulls overlap — the load-test mode used by the concurrency tests and
//!   the 100k-pod `scale` harness.
//!
//! Every workload enters through the **streaming arrival pipeline**
//! ([`Simulation::run_source`]): the engine keeps at most one future
//! arrival in the event queue and pulls the next from a pull-based
//! [`ArrivalSource`] only when the clock reaches it, so ingestion memory
//! is independent of workload length. `run_trace` and `run_arrivals` are
//! buffered conveniences over the same loop (see
//! `docs/ARCHITECTURE.md`, "Arrival pipeline").
//!
//! With `SimConfig::shards > 1` the engine additionally runs **sharded
//! per-node event lanes** ([`crate::sim::shard`]): node-local events
//! (pull completions, terminations, per-node GC checks) between two
//! coordinator events are drained in global order, routed to per-node
//! lanes, processed in parallel, and their effects merged back in pop
//! order — byte-identical to `shards = 1` by construction. Scheduling
//! cycles fan their per-node filter/score/layer passes across the same
//! worker pool. See `docs/ARCHITECTURE.md`, "Sharded event lanes".

use super::arrivals::{ArrivalSource, VecSource};
use super::bandwidth::LinkModel;
use super::cache::{self, CachePolicyChoice};
use super::clock::Clock;
use super::download::PullManager;
use super::events::{EventPayload, EventQueue};
use super::kubelet::{self, ImageLayerStore, PendingStart};
use super::metrics::{self, ClusterSnapshot, PodRecord};
use super::p2p::{Swarm, SwarmIndex};
use super::shard::{lane_bounds, lane_of, GcParams, LaneEffects, LaneItem, LaneOutcome, LanePool, LaneTask, Shard};
use super::workload::{ChurnAction, ChurnConfig, ChurnModel};
use crate::cluster::{
    ClusterState, EventKind, EventLog, Node, NodeId, Pod, PodId, Resources, NODE_SCOPE,
};
use crate::registry::{LayerId, LayerSet, MetadataCache, Registry, Watcher};
use crate::sched::queue::{ParkCure, SchedulingQueue};
use crate::sched::rl::{RlParams, RlScheduler};
use crate::sched::scoring::ScoringBackend;
use crate::sched::{CycleContext, FrameworkConfig, LrScheduler, Unschedulable, WeightParams};
use crate::util::units::{Bandwidth, Bytes};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Which of the paper's three schedulers to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerChoice {
    /// Kubernetes default plugins only.
    Default,
    /// Layer scheduler with static ω = 4.
    Layer,
    /// The paper's LRScheduler (dynamic ω).
    LR,
    /// Contextual-bandit scheduler — the paper's §VII future-work
    /// direction (long-term optimization via reinforcement learning).
    Rl,
}

impl SchedulerChoice {
    /// Human-readable scheduler name (report/CLI output).
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerChoice::Default => "Default",
            SchedulerChoice::Layer => "Layer",
            SchedulerChoice::LR => "LRScheduler",
            SchedulerChoice::Rl => "RLScheduler",
        }
    }

    /// The paper's three-way comparison set (Default/Layer/LR).
    pub fn all() -> [SchedulerChoice; 3] {
        [SchedulerChoice::Default, SchedulerChoice::Layer, SchedulerChoice::LR]
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Which scheduler drives the simulation.
    pub scheduler: SchedulerChoice,
    /// Dynamic-weight parameters for the LR scheduler.
    pub params: WeightParams,
    /// Plugin-profile configuration for the scheduling framework.
    pub framework: FrameworkConfig,
    /// Override every node's bandwidth (Fig. 4 sweeps this).
    pub bandwidth_mbps: Option<f64>,
    /// Optional shared registry uplink cap.
    pub registry_uplink_mbps: Option<f64>,
    /// None ⇒ sequential protocol; Some(dt) ⇒ timed arrivals.
    pub inter_arrival_secs: Option<f64>,
    /// Enable kubelet image GC under disk pressure.
    pub gc_enabled: bool,
    /// GC sweep trigger: disk usage fraction (kubelet
    /// ImageGCHighThresholdPercent analog).
    pub gc_high_pct: f64,
    /// GC sweep target: evict unused images until usage ≤ this fraction
    /// (ImageGCLowThresholdPercent analog).
    pub gc_low_pct: f64,
    /// Cloud-edge collaborative layer sharing (paper §VII): when set,
    /// layers cached on peer edge nodes transfer at this LAN bandwidth
    /// instead of being re-downloaded from the registry.
    pub p2p_lan_mbps: Option<f64>,
    /// Max concurrent uploads one peer seeder serves (P2P mode): a layer
    /// whose every Ready holder is at the cap falls back to the registry.
    pub p2p_seeder_cap: usize,
    /// Registry watcher poll interval (paper §V-1 default: 10 s).
    pub watcher_interval_secs: f64,
    /// Retries granted to an unschedulable pod after its first failed
    /// cycle before it is counted unschedulable (kube-scheduler's backoff
    /// queue retries indefinitely; a cap keeps simulations terminating).
    pub retry_limit: u32,
    /// Back-off before an unschedulable pod re-enters the active queue.
    pub retry_backoff_secs: f64,
    /// Record a cluster snapshot every N successful placements (1 = every
    /// placement, the paper-experiment default; the 100k-pod scale harness
    /// raises this to bound memory). A final snapshot is always taken.
    pub snapshot_every: usize,
    /// Cluster-volatility model: node joins/drains/crashes and registry
    /// outage windows injected as events over the trace (None = the
    /// static cluster of the paper's testbed).
    pub churn: Option<ChurnConfig>,
    /// Capacity-driven wake-ups (kube-scheduler `QueueingHint` analog):
    /// capacity-freeing events release parked pods immediately instead of
    /// waiting out their back-off timer (which stays armed as a fallback).
    /// Off reproduces PR 1's pure fixed-back-off behaviour.
    pub wake_on_capacity: bool,
    /// Per-node event lanes: partition the node table into this many
    /// contiguous shards and process node-local events (pull completions,
    /// terminations, per-node GC) in parallel between coordinator events,
    /// fanning scheduling cycles across the same worker pool. `1` (the
    /// default) is the fully sequential engine; any `N` produces a
    /// byte-identical report and event log (`docs/ARCHITECTURE.md`,
    /// "Sharded event lanes").
    pub shards: usize,
    /// Cure-aware parallel windows (the default): while pods sit parked,
    /// keep draining node-local events that cannot wake anything —
    /// consulting the scheduling queue's live-cure index — and cut the
    /// window at the first genuinely wake-relevant event, whose wake-up
    /// fires at the merge barrier in pop order. `false` restores the
    /// pre-cure conservative guard (any parked pod forces sequential
    /// stretches), kept for the `engine_parked` bench's before/after
    /// comparison and the conservative-vs-cure-aware differential test.
    /// Both settings are byte-identical to `shards = 1` by construction.
    pub cure_aware_windows: bool,
    /// Kubelet image-GC eviction/prefetch policy ([`crate::sim::cache`]).
    /// The default `PressureSweep` reproduces the pre-policy engine
    /// byte-for-byte (it never reads the per-layer use metadata).
    pub cache_policy: CachePolicyChoice,
    /// Half-life-style decay window (seconds) for the time-aware cache
    /// policies (popularity weighting, prefetch heat).
    pub cache_decay_secs: f64,
    /// Per-bind byte budget for the prefetch-on-intent cache policy.
    pub cache_prefetch_bytes: Bytes,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            scheduler: SchedulerChoice::LR,
            params: WeightParams::default(),
            framework: FrameworkConfig::default(),
            bandwidth_mbps: None,
            registry_uplink_mbps: None,
            inter_arrival_secs: None,
            gc_enabled: false,
            gc_high_pct: 0.85,
            gc_low_pct: 0.70,
            p2p_lan_mbps: None,
            p2p_seeder_cap: 4,
            watcher_interval_secs: crate::registry::watcher::DEFAULT_POLL_SECS,
            retry_limit: 3,
            retry_backoff_secs: 5.0,
            snapshot_every: 1,
            churn: None,
            wake_on_capacity: true,
            shards: 1,
            cure_aware_windows: true,
            cache_policy: CachePolicyChoice::PressureSweep,
            cache_decay_secs: 300.0,
            cache_prefetch_bytes: Bytes::from_mb(256.0),
        }
    }
}

/// The terminal (latest) state of one submitted pod. A crash can revert a
/// resolved pod to `Lost`; its resubmission then re-resolves it, so each
/// pod contributes exactly one bucket to the accounting identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PodOutcome {
    /// Container started (and was not subsequently lost to a crash).
    Started,
    /// Image install wedged (ImagePullBackOff analog).
    FailedPull,
    /// Exhausted its retries without binding.
    Unschedulable,
    /// Lost when its node crashed; awaiting (or denied) re-resolution.
    Lost,
}

/// Aggregated outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Label of the scheduler that ran.
    pub scheduler: &'static str,
    /// One record per successful placement, in bind order.
    pub records: Vec<PodRecord>,
    /// Periodic cluster snapshots plus the final one.
    pub snapshots: Vec<ClusterSnapshot>,
    /// Pods submitted to the API server (crash resubmissions of the same
    /// pod do not re-count).
    pub submitted: usize,
    /// Pods whose final state is started/ran (crash-lost instances that
    /// re-resolved count once, in their final bucket).
    pub started: usize,
    /// Pods that exhausted their retries without binding.
    pub unschedulable: usize,
    /// Bound pods whose image install wedged (ImagePullBackOff analog).
    pub failed_pulls: usize,
    /// Pods whose final state is crash-lost (nonzero only if the run ends
    /// before a resubmitted pod re-resolves).
    pub lost_to_crash: usize,
    /// Scheduling-cycle failures that parked a pod for retry.
    pub retries: u64,
    /// Pod instances returned to the scheduling queue by node crashes
    /// (does not count against the retry limit).
    pub resubmitted: u64,
    /// In-flight pulls stalled by registry outage windows.
    pub pulls_stalled: u64,
    /// Most concurrent uploads any single peer seeder served (0 without
    /// P2P sharing; never exceeds `SimConfig::p2p_seeder_cap`).
    pub peak_peer_uploads: usize,
    /// Parked pods released early by capacity-driven wake-ups
    /// (`QueueingHint` analog) instead of their back-off timer.
    pub wakeups: u64,
    /// Nodes that joined mid-run.
    pub nodes_joined: usize,
    /// Nodes cordoned mid-run.
    pub nodes_drained: usize,
    /// Nodes crashed mid-run.
    pub nodes_crashed: usize,
    /// Decisions taken at ω₁ (low weight).
    pub omega1_used: u64,
    /// Decisions taken at ω₂ (high weight).
    pub omega2_used: u64,
    /// Decisions taken at a mid-range ω (ThreeLevel / Linear policies).
    pub omega_mid_used: u64,
    /// ω chosen per decision, in bind order (Fig. 3f).
    pub omega_trace: Vec<f64>,
    /// Fraction of required image bytes served from the local layer cache
    /// across all placements (0.0 when nothing was required).
    pub cache_hit_rate: f64,
    /// Total bytes evicted by kubelet image GC over the run.
    pub evicted_bytes: Bytes,
    /// Total bytes installed ahead of need by the prefetch-on-intent
    /// cache policy (0 under every other policy).
    pub prefetched_bytes: Bytes,
}

impl SimReport {
    /// Total WAN bytes pulled across all placements (the paper's cost).
    pub fn total_download(&self) -> Bytes {
        self.records.iter().map(|r| r.download).sum()
    }

    /// Total bytes fetched from peer edge nodes over the LAN (0 without
    /// P2P sharing).
    pub fn total_p2p(&self) -> Bytes {
        self.records.iter().map(|r| r.p2p).sum()
    }

    /// Sum of per-placement download times (Table I's time column).
    pub fn total_download_secs(&self) -> f64 {
        self.records.iter().map(|r| r.download_secs).sum()
    }

    /// Cluster STD at the end of the run (last snapshot).
    pub fn final_std(&self) -> f64 {
        self.snapshots.last().map(|s| s.std_score).unwrap_or(0.0)
    }

    /// Placements the scheduler bound (includes pulls that later wedged;
    /// under churn a crash-resubmitted pod adds a placement per bind).
    pub fn deployed(&self) -> usize {
        self.records.len()
    }

    /// Pods that bound *and* started (final state, crash losses excluded).
    pub fn completed(&self) -> usize {
        self.started
    }

    /// No dropped events: every submitted pod is accounted for exactly
    /// once — completed, wedged, unschedulable-after-retries, or lost to a
    /// node crash — even under churn.
    pub fn accounting_balanced(&self) -> bool {
        self.completed() + self.failed_pulls + self.unschedulable + self.lost_to_crash
            == self.submitted
    }

    /// Render the full report — counters, every placement record, every
    /// snapshot (including per-node rows), and the ω trace — with lossless
    /// float formatting. Two reports render identically iff they are
    /// bit-identical; this is the fingerprint `scale --report-out` writes
    /// and the shard-equivalence tests and CI determinism job diff.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut s = String::with_capacity(64 + self.records.len() * 96);
        let _ = writeln!(
            s,
            "scheduler={} submitted={} started={} failed_pulls={} unschedulable={} \
             lost_to_crash={} retries={} resubmitted={} pulls_stalled={} peak_uploads={} \
             wakeups={} nodes_joined={} nodes_drained={} nodes_crashed={} omega1={} omega2={} \
             omega_mid={} cache_hit_rate={:?} evicted_mb={:?} prefetched_mb={:?}",
            self.scheduler,
            self.submitted,
            self.started,
            self.failed_pulls,
            self.unschedulable,
            self.lost_to_crash,
            self.retries,
            self.resubmitted,
            self.pulls_stalled,
            self.peak_peer_uploads,
            self.wakeups,
            self.nodes_joined,
            self.nodes_drained,
            self.nodes_crashed,
            self.omega1_used,
            self.omega2_used,
            self.omega_mid_used,
            self.cache_hit_rate,
            self.evicted_bytes.as_mb(),
            self.prefetched_bytes.as_mb(),
        );
        for r in &self.records {
            let _ = writeln!(
                s,
                "record pod={} image={} node={} download={} p2p={} secs={:?} std={:?} \
                 omega={:?} layer={:?} final={:?} at={:?}",
                r.pod.0,
                r.image,
                r.node,
                r.download.0,
                r.p2p.0,
                r.download_secs,
                r.std_after,
                r.omega,
                r.layer_score,
                r.final_score,
                r.at,
            );
        }
        for snap in &self.snapshots {
            let _ = write!(
                s,
                "snapshot at={:?} cpu={:?} mem={:?} disk={} std={:?} per_node=",
                snap.at, snap.cpu_util, snap.mem_util, snap.disk_used.0, snap.std_score,
            );
            for (c, m, d) in &snap.per_node {
                let _ = write!(s, "({c:?},{m:?},{}) ", d.0);
            }
            s.push('\n');
        }
        let _ = writeln!(s, "omega_trace={:?}", self.omega_trace);
        s
    }
}

/// Everything `lrsched serve` reports about one binding decision —
/// captured inside the scheduling cycle when
/// [`Simulation::collect_decisions`] is on, and drained with
/// [`Simulation::take_decisions`]. A superset of the corresponding
/// [`PodRecord`]: it adds the winning node's per-plugin score breakdown
/// and the pod/node identities the protocol needs. Collection is off by
/// default so batch replays (and the CI memory gate) pay nothing.
#[derive(Debug, Clone)]
pub struct DecisionDetail {
    /// The bound pod.
    pub pod: PodId,
    /// Its metadata name (the protocol's correlation handle).
    pub pod_name: String,
    /// Image key (`name:tag`).
    pub image: String,
    /// Winning node id.
    pub node: NodeId,
    /// Winning node name.
    pub node_name: String,
    /// Final S^{k,n}(t) of the winner.
    pub final_score: f64,
    /// Its S_layer (Eq. 3).
    pub layer_score: f64,
    /// Its S_K8s.
    pub k8s_score: f64,
    /// The ω used.
    pub omega: f64,
    /// Per-plugin `(name, normalized score)` pairs behind `k8s_score`, in
    /// plugin registration order (empty for the RL scheduler).
    pub breakdown: Vec<(&'static str, f64)>,
    /// Bytes pulled from the registry over the WAN for this placement.
    pub wan_bytes: Bytes,
    /// Bytes fetched from peer edge nodes over the LAN.
    pub p2p_bytes: Bytes,
    /// Estimated seconds until the image is ready on the node.
    pub est_secs: f64,
    /// Virtual decision time (seconds).
    pub at: f64,
}

/// The scheduler driving a simulation: the paper's Algorithm-1 family or
/// the §VII learning-based extension.
enum SchedImpl {
    Lr(LrScheduler),
    Rl(RlScheduler),
}

impl SchedImpl {
    fn build(cfg: &SimConfig) -> SchedImpl {
        let framework = cfg.framework.build("sim");
        match cfg.scheduler {
            SchedulerChoice::Default => SchedImpl::Lr(LrScheduler::default_scheduler(framework)),
            SchedulerChoice::Layer => SchedImpl::Lr(LrScheduler::layer_scheduler(framework)),
            SchedulerChoice::LR => {
                let mut s = LrScheduler::lr_scheduler(framework);
                s.params = cfg.params;
                SchedImpl::Lr(s)
            }
            SchedulerChoice::Rl => {
                SchedImpl::Rl(RlScheduler::new(framework, RlParams::default(), 2024))
            }
        }
    }
}

/// One parallel window of node-local events: per-lane routed work in
/// global pop order, plus the speculative-termination bookkeeping the
/// merge step needs (see [`Simulation::collect_window`]).
struct Window {
    /// Routed work per lane, each list in global pop order.
    lanes: Vec<Vec<LaneItem>>,
    /// Per-slot seq of the speculatively scheduled termination event
    /// (cancelled at merge if the pull wedges).
    spec: Vec<Option<u64>>,
    /// Slots routed to lanes.
    n_slots: usize,
    /// Events consumed from the global queue — ≥ `n_slots`, because no-op
    /// pops (stale events) and outage re-queues consume without routing.
    consumed: usize,
    /// The final slot is a wake-relevant event (a termination, or a GC
    /// check that may evict) collected under live capacity-curable parks:
    /// after the merge applies its effects, the coordinator fires the
    /// wake-up the sequential handler would have fired at the same pop
    /// position — if the slot actually freed capacity (`LaneEffects::
    /// freed_capacity`).
    wake_candidate: bool,
}

impl Window {
    fn new(n_lanes: usize) -> Window {
        Window {
            lanes: (0..n_lanes).map(|_| Vec::new()).collect(),
            spec: Vec::new(),
            n_slots: 0,
            consumed: 0,
            wake_candidate: false,
        }
    }

    fn route(&mut self, lane: usize, task: LaneTask, spec: Option<u64>) {
        let slot = self.n_slots;
        self.n_slots += 1;
        self.spec.push(spec);
        self.lanes[lane].push(LaneItem { slot, task });
    }
}

/// Engine-loop instrumentation for the windowed (sharded) mode — read by
/// the `engine_parked` bench and the scale harness via
/// [`Simulation::window_stats`]. Deliberately **not** part of
/// [`SimReport`]: window shapes depend on `shards` and
/// `SimConfig::cure_aware_windows`, and the report must stay
/// byte-identical across both.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowStats {
    /// Parallel windows opened (≥1 routed slot each).
    pub windows: u64,
    /// Node-local events drained through parallel windows.
    pub windowed_events: u64,
    /// Windows cut at a wake-relevant event under live capacity parks.
    pub wake_stops: u64,
    /// Sim-time with at least one pod parked (both engines account it).
    pub parked_busy_secs: f64,
}

/// Monotonic suffix so every `Simulation` gets its own metadata-cache path
/// (the seed hard-coded one `/tmp` path, leaking state between runs that
/// chose to persist the cache).
static CACHE_PATH_SEQ: AtomicU64 = AtomicU64::new(0);

fn unique_cache_path() -> String {
    // det: allow(R2): cache *location* only — simulation state never
    // depends on the path, and the per-process sequence keeps it unique.
    std::env::temp_dir()
        .join(format!(
            "lrsched-sim-cache-{}-{}.json",
            std::process::id(),
            CACHE_PATH_SEQ.fetch_add(1, Ordering::Relaxed)
        ))
        .to_string_lossy()
        .into_owned()
}

/// The simulator.
pub struct Simulation {
    /// Cluster state (nodes, pods, bindings, layer inventory).
    pub state: ClusterState,
    /// The registry serving image metadata and layers.
    pub registry: Registry,
    /// Watcher-maintained metadata cache the scheduler reads.
    pub cache: MetadataCache,
    watcher: Watcher,
    /// Virtual clock.
    pub clock: Clock,
    links: LinkModel,
    pulls: PullManager,
    scheduler: SchedImpl,
    /// In-flight pulls keyed by pod (completion fires as an event).
    pending: HashMap<PodId, PendingStart>,
    /// containerd-image-store analog, scoped to this simulation.
    images: ImageLayerStore,
    /// The unified discrete-event queue.
    queue: EventQueue,
    /// Active/back-off queues for pods awaiting (re)scheduling.
    sched_queue: SchedulingQueue,
    /// Failed scheduling cycles per still-pending pod.
    retry_counts: HashMap<PodId, u32>,
    /// The active streaming arrival source (`run_source`): the engine
    /// holds at most **one** future arrival in the event queue and pulls
    /// the next from here when it pops (offset-timed runs) or when the
    /// current pod resolves (the sequential protocol) — the
    /// constant-memory half of the arrival pipeline.
    arrival_source: Option<Box<dyn ArrivalSource>>,
    /// Absolute virtual time the active source's offsets are measured
    /// from (the clock at `run_source` entry).
    arrivals_t0: f64,
    /// Sequential-protocol chaining: when set (only by `run_trace` with
    /// `inter_arrival_secs = None`), arrival offsets are ignored and the
    /// next pod is pulled when the previous one resolves instead of when
    /// its arrival event pops.
    chain_arrivals: bool,
    /// A serve session is live ([`Simulation::open_stream`]): the stream
    /// may still produce arrivals, so the watcher treats the session
    /// itself as pending work and [`Simulation::step_until`] must not
    /// drain past the frontier. Always false in batch runs.
    session_open: bool,
    /// A future `Arrival` event is sitting in the queue. Guards
    /// [`Simulation::pump_stream`]: the one-future-arrival invariant of
    /// the arrival pipeline must hold even when the serve session pumps
    /// after every pushed pod rather than once per pop.
    arrival_pending: bool,
    /// Capture a [`DecisionDetail`] per bind (serve mode only; batch
    /// replays leave this off so memory stays flat).
    collect_decisions: bool,
    /// Captured decisions awaiting [`Simulation::take_decisions`].
    decision_log: Vec<DecisionDetail>,
    /// Is a WatcherTick event currently scheduled?
    watcher_armed: bool,
    /// Terminal state per submitted pod (the accounting source of truth;
    /// a crash reverts a pod to `Lost` until it re-resolves). Ordered so
    /// the report tally iterates in pod order, not hash order.
    outcomes: BTreeMap<PodId, PodOutcome>,
    /// Termination-timer epoch per pod: bumped when a crash loses the
    /// instance, so a stale `PodTermination` cannot kill the rebound one.
    epochs: HashMap<PodId, u64>,
    /// Pods released by a capacity wake-up whose next failed cycle is
    /// free: wake-ups are opportunistic extra attempts on top of the
    /// timer cadence, so they must not burn `retry_limit` (kube's
    /// `QueueingHint` re-queues without consuming back-off budget).
    retry_grace: std::collections::HashSet<PodId>,
    /// Pods whose resolution already released the next sequential
    /// arrival (each pod chains exactly once; see `chain_next_arrival`).
    chained: std::collections::HashSet<PodId>,
    /// Registry unreachable until this virtual time (0 = reachable).
    outage_until: f64,
    /// Layer → holders index for peer-swarm planning. Maintained at every
    /// inventory-mutation site (marking is cheap and bounded) but synced
    /// only when a P2P plan needs it. Coordinator-only state: the sharded
    /// lanes never touch it, so source plans — and therefore reports —
    /// are byte-identical at every shard count.
    swarm: SwarmIndex,
    /// Worker pool for sharded event lanes and scheduling fan-outs
    /// (None when `SimConfig::shards <= 1`).
    pool: Option<LanePool>,
    /// Required-layer bytes served from the local cache so far (the hit
    /// side of `SimReport::cache_hit_rate`).
    cache_hit_bytes: Bytes,
    /// Total required-layer bytes across all placements so far.
    cache_required_bytes: Bytes,
    /// Decayed per-layer demand observed at bind time — the prefetch
    /// policy's heat map. Coordinator-only state (updated inside the
    /// scheduling cycle), so it is shard-count-independent by
    /// construction; empty under every other policy.
    layer_heat: BTreeMap<LayerId, (f64, f64)>,
    /// Audit log of everything that happened.
    pub events: EventLog,
    /// Placement records (mirrored into the report).
    pub records: Vec<PodRecord>,
    /// Cluster snapshots (mirrored into the report).
    pub snapshots: Vec<ClusterSnapshot>,
    /// Pods submitted so far (crash resubmissions don't re-count).
    pub submitted: usize,
    /// Scheduling-cycle failures that parked a pod.
    pub retries: u64,
    /// Pod instances returned to the queue by node crashes.
    pub resubmitted: u64,
    /// In-flight pulls stalled by registry outages.
    pub pulls_stalled: u64,
    /// Parked pods released early by capacity wake-ups.
    pub wakeups: u64,
    /// Nodes that joined mid-run.
    pub nodes_joined: usize,
    /// Nodes cordoned mid-run.
    pub nodes_drained: usize,
    /// Nodes crashed mid-run.
    pub nodes_crashed: usize,
    /// Parallel windows opened by the sharded loop (≥1 routed slot).
    windows_opened: u64,
    /// Node-local events drained through parallel windows.
    windowed_events: u64,
    /// Windows cut at a wake-relevant event under live capacity parks
    /// (the cure-aware stop; zero when nothing capacity-curable parks).
    window_wake_stops: u64,
    /// Sim-time during which at least one pod sat parked — the parked
    /// occupancy the `engine_parked` bench asserts on. Instrumentation
    /// only: never reaches the report or the event log.
    parked_busy_secs: f64,
    cfg: SimConfig,
}

impl Simulation {
    /// Build a simulation over `nodes` and `registry` (applies the
    /// config's bandwidth override and uplink cap to the link model).
    pub fn new(nodes: Vec<Node>, registry: Registry, cfg: SimConfig) -> Simulation {
        let mut state = ClusterState::new();
        let mut bws = Vec::new();
        for mut n in nodes {
            if let Some(mbps) = cfg.bandwidth_mbps {
                n.bandwidth = Bandwidth::from_mbps(mbps);
            }
            bws.push(n.bandwidth);
            state.add_node(n);
        }
        let mut links = LinkModel::new(bws);
        if let Some(up) = cfg.registry_uplink_mbps {
            links.registry_uplink = Some(Bandwidth::from_mbps(up));
        }
        let scheduler = SchedImpl::build(&cfg);
        let n_nodes = state.node_count();
        let mut sched_queue = SchedulingQueue::new();
        sched_queue.backoff_secs = cfg.retry_backoff_secs;
        Simulation {
            state,
            registry,
            cache: MetadataCache::new(&unique_cache_path()),
            watcher: Watcher::new(cfg.watcher_interval_secs),
            clock: Clock::new(),
            links,
            pulls: PullManager::new(n_nodes),
            scheduler,
            pending: HashMap::new(),
            images: ImageLayerStore::new(),
            queue: EventQueue::new(),
            sched_queue,
            retry_counts: HashMap::new(),
            arrival_source: None,
            arrivals_t0: 0.0,
            chain_arrivals: false,
            session_open: false,
            arrival_pending: false,
            collect_decisions: false,
            decision_log: Vec::new(),
            watcher_armed: false,
            outcomes: BTreeMap::new(),
            epochs: HashMap::new(),
            retry_grace: std::collections::HashSet::new(),
            chained: std::collections::HashSet::new(),
            outage_until: 0.0,
            swarm: SwarmIndex::new(),
            pool: if cfg.shards > 1 { Some(LanePool::new(cfg.shards)) } else { None },
            cache_hit_bytes: Bytes::ZERO,
            cache_required_bytes: Bytes::ZERO,
            layer_heat: BTreeMap::new(),
            events: EventLog::new(),
            records: Vec::new(),
            snapshots: Vec::new(),
            submitted: 0,
            retries: 0,
            resubmitted: 0,
            pulls_stalled: 0,
            wakeups: 0,
            nodes_joined: 0,
            nodes_drained: 0,
            nodes_crashed: 0,
            windows_opened: 0,
            windowed_events: 0,
            window_wake_stops: 0,
            parked_busy_secs: 0.0,
            cfg,
        }
    }

    /// Install the XLA scoring backend (otherwise native math runs).
    /// The RL scheduler has no dense-scoring path; it keeps native math.
    pub fn with_backend(mut self, backend: Box<dyn ScoringBackend>) -> Simulation {
        self.scheduler = match SchedImpl::build(&self.cfg) {
            SchedImpl::Lr(s) => SchedImpl::Lr(s.with_backend(backend)),
            rl @ SchedImpl::Rl(_) => rl,
        };
        self
    }

    /// Total events ever queued (observability for the scale harness).
    pub fn events_queued(&self) -> u64 {
        self.queue.pushed_total
    }

    /// Most concurrent uploads any single peer seeder has served so far
    /// (0 without P2P sharing) — the seeder-cap observability hook.
    pub fn peak_peer_uploads(&self) -> usize {
        self.links.peak_peer_uploads()
    }

    /// Windowed-loop instrumentation (window counts, cure-aware wake
    /// stops, parked sim-time occupancy). All zeros in a sequential run
    /// except `parked_busy_secs`, which both engines account.
    pub fn window_stats(&self) -> WindowStats {
        WindowStats {
            windows: self.windows_opened,
            windowed_events: self.windowed_events,
            wake_stops: self.window_wake_stops,
            parked_busy_secs: self.parked_busy_secs,
        }
    }

    // --- event loop -------------------------------------------------------

    /// Advance the virtual clock to `at`, charging the elapsed interval to
    /// the parked-occupancy accumulator when any pod sits parked — the
    /// measurement behind the `engine_parked` bench's ≥80 % parked-time
    /// workload contract. Pure instrumentation: coordinator-only, never
    /// observable in the report or event log.
    fn advance_clock(&mut self, at: f64) {
        let now = self.clock.now();
        if at > now && self.sched_queue.parked_len() > 0 {
            self.parked_busy_secs += at - now;
        }
        self.clock.advance_to(at);
    }

    /// Schedule the next watcher poll if none is pending.
    fn arm_watcher(&mut self, now: f64) {
        if self.watcher_armed {
            return;
        }
        let at = self.watcher.next_poll_at().max(now);
        if at.is_finite() {
            self.queue.push(at, EventPayload::WatcherTick);
            self.watcher_armed = true;
        }
    }

    /// Pop and dispatch events until the simulation quiesces. The watcher
    /// re-arms itself only while real work remains, so the loop terminates.
    /// With `shards > 1` and timed arrivals, node-local events are drained
    /// in parallel windows on the per-node lanes instead.
    fn run_events(&mut self) {
        if self.pool.is_some() && self.cfg.inter_arrival_secs.is_some() {
            self.run_events_windowed();
        } else {
            self.run_events_seq();
        }
    }

    /// The fully sequential event loop (`shards = 1`, and the sequential
    /// arrival protocol regardless of shards — its arrival chaining makes
    /// pull resolutions coordinator events).
    fn run_events_seq(&mut self) {
        while let Some(ev) = self.queue.pop() {
            if ev.payload.is_watcher() && !self.queue.has_pending_work() && !self.session_open
            {
                // Nothing left that a poll could affect: let the sim drain.
                // (An open serve session counts as pending work — the
                // stream can still produce arrivals, exactly like the
                // future arrival a batch run would hold in the queue.)
                self.watcher_armed = false;
                continue;
            }
            self.advance_clock(ev.at);
            let t = self.clock.now();
            self.step_event(t, ev.payload);
        }
    }

    /// Dispatch one popped event at time `t` — the shared handler of the
    /// sequential loop and the windowed loop's coordinator stretches.
    fn step_event(&mut self, t: f64, payload: EventPayload) {
        {
            match payload {
                EventPayload::WatcherTick => {
                    self.watcher_armed = false;
                    self.watcher.poll(t, &self.registry, &mut self.cache);
                    let next = self.watcher.next_poll_at();
                    if (self.queue.has_pending_work() || self.session_open)
                        && next.is_finite()
                        && next > t
                    {
                        self.queue.push(next, EventPayload::WatcherTick);
                        self.watcher_armed = true;
                    }
                }
                EventPayload::Arrival { pod } => {
                    self.arrival_pending = false;
                    let pid = self.state.submit_pod(pod);
                    self.submitted += 1;
                    self.events.record(t, pid, EventKind::Submitted);
                    // Offset-timed runs pull the next arrival as soon as
                    // this one pops — the queue holds at most one future
                    // arrival at a time (sequential-protocol runs chain
                    // on resolution instead; see `chain_next_arrival`).
                    if !self.chain_arrivals {
                        self.pump_arrival(t);
                    }
                    self.sched_queue.push(pid);
                    self.drain_sched_queue();
                }
                EventPayload::BackoffRelease => {
                    if self.sched_queue.release_due(t) > 0 {
                        self.drain_sched_queue();
                    }
                }
                EventPayload::PullComplete { pod } => {
                    if let Some(p) = self.pending.remove(&pod) {
                        if p.plan.ready_at > t + 1e-9 {
                            // A registry outage stalled this pull after its
                            // completion was queued (or this is a stale
                            // pre-crash event racing a rebind): the layers
                            // actually land at the updated ready time.
                            let at = p.plan.ready_at;
                            self.pending.insert(pod, p);
                            self.queue.push(at, EventPayload::PullComplete { pod });
                            return;
                        }
                        let duration = self.state.pod(pod).and_then(|x| x.duration_secs);
                        let started = self.finish_pull(p);
                        self.pulls.gc(t);
                        if started {
                            if let Some(d) = duration {
                                let epoch = self.epochs.get(&pod).copied().unwrap_or(0);
                                self.queue
                                    .push(t + d, EventPayload::PodTermination { pod, epoch });
                            }
                        }
                        self.chain_next_arrival(t, pod);
                    }
                }
                EventPayload::PodTermination { pod, epoch } => {
                    // Ignore stale timers from a pre-crash instance: the
                    // pod may be rebound and running a fresh epoch.
                    if self.epochs.get(&pod).copied().unwrap_or(0) != epoch {
                        return;
                    }
                    // Resources release; layers stay cached until GC needs
                    // them (image retention is the kubelet's GC job).
                    let node = self.state.binding(pod);
                    let released = self.state.unbind(pod).is_ok();
                    if self.cfg.gc_enabled {
                        // Only this node's in-use image set changed, so the
                        // pressure re-check is node-local (the full sweep
                        // still runs at every scheduling cycle).
                        if let Some(n) = node {
                            self.queue.push(t, EventPayload::GcSweepNode { node: n });
                        }
                    }
                    // QueueingHint: freed capacity wakes parked pods now,
                    // instead of at their back-off deadline.
                    if released && self.wake_parked() > 0 {
                        self.drain_sched_queue();
                    }
                }
                EventPayload::GcSweep => {
                    let evicted = self.gc_pressure_sweep();
                    // Freed disk can cure NodeCapacity rejections.
                    if evicted && self.wake_parked() > 0 {
                        self.drain_sched_queue();
                    }
                }
                EventPayload::GcSweepNode { node } => {
                    let evicted = self.gc_check_node(node);
                    if evicted && self.wake_parked() > 0 {
                        self.drain_sched_queue();
                    }
                }
                EventPayload::NodeJoin => self.handle_node_join(t),
                EventPayload::NodeDrain { node } => {
                    if self.state.node(node).is_schedulable() {
                        self.state.drain_node(node);
                        self.nodes_drained += 1;
                        self.events.record(t, NODE_SCOPE, EventKind::NodeDrained { node });
                    }
                }
                EventPayload::NodeCrash { node } => self.handle_node_crash(t, node),
                EventPayload::RegistryOutageStart { until } => {
                    self.handle_outage_start(t, until)
                }
                EventPayload::RegistryOutageEnd => {
                    if t >= self.outage_until - 1e-9 {
                        self.watcher.set_online(true);
                        self.events.record(t, NODE_SCOPE, EventKind::RegistryOutageEnd);
                        // Stalled pulls resume: treat connectivity return
                        // as a wake-up source (it unblocks progress).
                        if self.wake_parked() > 0 {
                            self.drain_sched_queue();
                        }
                    }
                }
            }
        }
    }

    // --- sharded event lanes ----------------------------------------------

    /// The sharded event loop: alternate between parallel windows of
    /// node-local events (drained in global order, routed to per-node
    /// lanes, effects merged back in pop order) and sequential handling of
    /// coordinator events. Byte-identical to [`Simulation::run_events_seq`]
    /// by construction — see `docs/ARCHITECTURE.md`, "Sharded event lanes".
    fn run_events_windowed(&mut self) {
        let n_lanes = self.cfg.shards.max(1);
        loop {
            // Cure-aware windows (the default) open whenever no pod is
            // *actively* queued for scheduling: parked pods are fine,
            // because `collect_window` consults the live-cure index and
            // cuts the window at the first event that could wake one
            // (firing the wake-up at the merge barrier, in pop order).
            // The conservative mode keeps the pre-cure guard: a window
            // only while nothing is parked either, so terminations and
            // evictions can never wake anything mid-window.
            let window_ok = if self.cfg.cure_aware_windows {
                self.sched_queue.active_len() == 0
            } else {
                self.sched_queue.is_empty()
            };
            if window_ok {
                let w = self.collect_window(n_lanes);
                let consumed = w.consumed;
                if w.n_slots > 0 {
                    self.process_window(w);
                }
                if consumed > 0 {
                    continue;
                }
            }
            match self.queue.pop() {
                None => break,
                Some(ev) => {
                    if ev.payload.is_watcher() && !self.queue.has_pending_work() {
                        self.watcher_armed = false;
                        continue;
                    }
                    self.advance_clock(ev.at);
                    let t = self.clock.now();
                    self.step_event(t, ev.payload);
                }
            }
        }
    }

    /// Drain a maximal prefix of node-local events from the global queue,
    /// in (time, class, seq) order, routing each to the lane owning its
    /// node. The coordinator performs each event's *predictable* half
    /// inline — exactly the pushes and map updates the sequential handler
    /// would do at the same point in the pop/push stream — and defers the
    /// node mutation to the lane. A termination event for a just-finished
    /// pull is scheduled *speculatively* (the lane has not yet confirmed
    /// the container started); collection stops before popping an
    /// unconfirmed speculative event, and the merge step cancels it if the
    /// pull turned out to wedge.
    ///
    /// **Cure-aware stops.** When capacity-curable pods sit parked
    /// (`SchedulingQueue::capacity_parked`, constant during collection —
    /// parks are only created and consumed on the coordinator), an event
    /// that could wake one must not run mid-window: the sequential engine
    /// fires `wake_parked` + scheduling cycles right at its pop position.
    /// Such an event becomes the window's **final** slot instead
    /// (`Window::wake_candidate`), and the merge barrier fires its wake-up
    /// after applying every effect — same state, same clock, same pop
    /// position as the sequential engine. Wake relevance per class
    /// ([`EventPayload::is_wake_candidate`]):
    /// - pull completions never wake (finish-side evictions are disk
    ///   bookkeeping, not wake sources) — always safe mid-window;
    /// - valid terminations always release capacity — always final-slot;
    /// - a per-node GC check wakes only if it evicts, which the
    ///   coordinator can *predict* from its own node state while the
    ///   node's disk is untouched this window: under the high-pressure
    ///   threshold it cannot evict and is safe mid-window; over it (or
    ///   with the node's disk already touched by an earlier slot) it is
    ///   final-slot, and the barrier consults the lane-reported
    ///   `freed_capacity` flag for the actual wake decision.
    fn collect_window(&mut self, n_lanes: usize) -> Window {
        /// Bounds per-window memory (routed work + buffered effects).
        const WINDOW_CAP: usize = 8192;
        let n_nodes = self.state.node_count();
        let mut w = Window::new(n_lanes);
        let mut speculative: std::collections::HashSet<u64> = std::collections::HashSet::new();
        // Could any node-local event wake a parked pod this window? Parks
        // only change on the coordinator, so one read is sound for the
        // whole collection. (In conservative mode the guard already
        // ensured nothing is parked, making this false.)
        let wake_possible =
            self.cfg.wake_on_capacity && self.sched_queue.capacity_parked() > 0;
        // Nodes whose disk state an earlier slot may have changed —
        // membership-only (never iterated), so hash order cannot escape.
        let mut disk_touched: std::collections::HashSet<NodeId> =
            std::collections::HashSet::new();
        loop {
            if w.n_slots >= WINDOW_CAP {
                break;
            }
            match self.queue.peek() {
                None => break,
                Some(head) => {
                    if !head.payload.is_node_local() || speculative.contains(&head.seq()) {
                        break;
                    }
                }
            }
            let ev = self.queue.pop().expect("peeked head exists");
            self.advance_clock(ev.at);
            let t = ev.at;
            w.consumed += 1;
            match ev.payload {
                EventPayload::PullComplete { pod } => {
                    let ready_at = match self.pending.get(&pod) {
                        None => continue, // stale post-crash event: no-op
                        Some(p) => p.plan.ready_at,
                    };
                    if ready_at > t + 1e-9 {
                        // Outage-stalled pull: re-queue at the real ready
                        // time, exactly like the sequential handler.
                        self.queue.push(ready_at, EventPayload::PullComplete { pod });
                        continue;
                    }
                    let p = self.pending.remove(&pod).expect("pending checked above");
                    let duration = self.state.pod(pod).and_then(|x| x.duration_secs);
                    let mut spec = None;
                    if let Some(d) = duration {
                        // Speculative: the sequential engine pushes this
                        // only if the container starts. The lane reports
                        // `started` and the merge cancels on failure, so
                        // the observable stream is identical either way.
                        let epoch = self.epochs.get(&pod).copied().unwrap_or(0);
                        let seq =
                            self.queue.push(t + d, EventPayload::PodTermination { pod, epoch });
                        speculative.insert(seq);
                        spec = Some(seq);
                    }
                    let lane = lane_of(p.node.0 as usize, n_nodes, n_lanes);
                    if wake_possible {
                        // The install (and a possible finish-side GC)
                        // changes this node's disk: later GC checks on it
                        // can no longer be predicted from coordinator
                        // state.
                        disk_touched.insert(p.node);
                    }
                    w.route(lane, LaneTask::Pull { p }, spec);
                }
                EventPayload::PodTermination { pod, epoch } => {
                    if self.epochs.get(&pod).copied().unwrap_or(0) != epoch {
                        continue; // stale pre-crash timer: no-op
                    }
                    let node = match self.state.take_binding(pod) {
                        Some(n) => n,
                        None => continue, // unreachable in practice: started pods are bound
                    };
                    if self.cfg.gc_enabled {
                        self.queue.push(t, EventPayload::GcSweepNode { node });
                    }
                    let requests = self.state.pod(pod).expect("bound pod exists").requests;
                    let lane = lane_of(node.0 as usize, n_nodes, n_lanes);
                    w.route(lane, LaneTask::Term { pod, node, requests }, None);
                    if wake_possible {
                        // A valid termination always releases capacity, so
                        // the sequential engine would wake parked pods at
                        // exactly this pop position: close the window here
                        // and let the merge barrier fire the wake-up.
                        w.wake_candidate = true;
                        self.window_wake_stops += 1;
                        break;
                    }
                }
                EventPayload::GcSweepNode { node } => {
                    // Can this check evict (and so wake)? Predicted from
                    // coordinator state while the node's disk is untouched
                    // this window: under `gc_high_pct` the lane's sweep
                    // no-ops, so it is safe mid-window. Over it — or with
                    // the prediction stale — close the window on it and
                    // let the barrier read the lane-reported outcome.
                    let may_evict = wake_possible
                        && self.cfg.gc_enabled
                        && {
                            let n = self.state.node(node);
                            n.is_up()
                                && (disk_touched.contains(&node) || {
                                    let (disk, used) =
                                        (n.disk.0 as f64, n.disk_used.0 as f64);
                                    disk > 0.0 && used / disk > self.cfg.gc_high_pct
                                })
                        };
                    let lane = lane_of(node.0 as usize, n_nodes, n_lanes);
                    w.route(lane, LaneTask::Sweep { t, node }, None);
                    if may_evict {
                        w.wake_candidate = true;
                        self.window_wake_stops += 1;
                        break;
                    }
                }
                other => unreachable!("non-lane event {other:?} collected into a window"),
            }
        }
        self.windows_opened += u64::from(w.n_slots > 0);
        self.windowed_events += w.n_slots as u64;
        w
    }

    /// Advance every lane over its routed window in parallel, then merge
    /// the buffered effects back in global pop order: event-log records
    /// append in the order the sequential engine would have written them,
    /// outcome/memo updates apply per slot, and a wedged pull cancels its
    /// speculative termination. A window closed on a wake-relevant final
    /// slot ([`Window::wake_candidate`]) fires its wake-up last — after
    /// every effect (and the pull bookkeeping GC) has been applied, the
    /// cluster state and clock are exactly what the sequential engine's
    /// handler saw at that pop position, so the barrier wake's scheduling
    /// cycles are byte-identical by the same merge-order argument.
    fn process_window(&mut self, w: Window) {
        let n_lanes = w.lanes.len();
        let wake_candidate = w.wake_candidate;
        let final_slot = w.n_slots.wrapping_sub(1);
        let mut final_freed = false;
        let gc = GcParams {
            enabled: self.cfg.gc_enabled,
            high: self.cfg.gc_high_pct,
            low: self.cfg.gc_low_pct,
            policy: self.cfg.cache_policy,
            decay: self.cfg.cache_decay_secs,
        };
        let mut slot_effects: Vec<Option<LaneEffects>> = Vec::new();
        slot_effects.resize_with(w.n_slots, || None);
        {
            let pool = self.pool.as_ref().expect("windowed mode requires a pool");
            let images = &self.images;
            let (nodes, pods, interner) = self.state.lane_split();
            let bounds = lane_bounds(nodes.len(), n_lanes);
            let mut shards: Vec<Mutex<Shard<'_>>> = Vec::with_capacity(n_lanes);
            let mut rest = nodes;
            for (&(lo, hi), items) in bounds.iter().zip(w.lanes) {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
                rest = tail;
                shards.push(Mutex::new(Shard::new(lo, head, items)));
            }
            pool.run(n_lanes, &|lane| {
                let mut shard = shards[lane].lock().expect("lane lock");
                shard.process(pods, interner, images, gc);
            });
            for shard in shards {
                let shard = shard.into_inner().expect("lane lock");
                for eff in shard.effects {
                    let slot = eff.slot;
                    slot_effects[slot] = Some(eff);
                }
            }
        }
        for (slot, eff) in slot_effects.into_iter().enumerate() {
            let eff = match eff {
                Some(e) => e,
                None => continue, // slot routed but produced no effects
            };
            if wake_candidate && slot == final_slot {
                final_freed = eff.freed_capacity;
            }
            // A lane that installed or evicted layers changed its node's
            // inventory; the coordinator owns the swarm index, so the
            // dirty mark happens here, at the merge barrier — before any
            // later scheduling cycle can plan against stale holders.
            if eff.remember.is_some()
                || eff.log.iter().any(|(_, _, k)| matches!(k, EventKind::Evicted { .. }))
            {
                self.swarm.mark_dirty(eff.node);
            }
            for (at, pod, kind) in eff.log {
                self.events.record(at, pod, kind);
            }
            if let Some((pod, outcome)) = eff.outcome {
                let mapped = match outcome {
                    LaneOutcome::Started => PodOutcome::Started,
                    LaneOutcome::FailedPull => PodOutcome::FailedPull,
                };
                self.outcomes.insert(pod, mapped);
            }
            if let Some((image, layers)) = eff.remember {
                self.images.remember(&image, &layers);
            }
            if !eff.started {
                // The pull wedged: retract the speculative termination so
                // the queue reads exactly as the sequential engine's.
                if let Some(seq) = w.spec[slot] {
                    self.queue.cancel(seq);
                }
            }
        }
        self.pulls.gc(self.clock.now());
        // The barrier wake: the final slot was wake-relevant and actually
        // freed capacity (a valid termination always does; a GC check only
        // when it evicted). State, clock, and pop position now match the
        // sequential engine at the instant its handler called
        // `wake_parked`, so the released pods' scheduling cycles — and
        // everything they push — are identical.
        if wake_candidate && final_freed && self.wake_parked() > 0 {
            self.drain_sched_queue();
        }
    }

    // --- cluster volatility -----------------------------------------------

    /// A cold node joins: dense next id, empty layer cache (the
    /// `ScoreArena` spots the new row via `layers_version`), fresh link
    /// and pull bookkeeping — then parked pods wake: new capacity may
    /// cure their rejection.
    fn handle_node_join(&mut self, t: f64) {
        let spec = self.cfg.churn.clone().unwrap_or_default();
        let id = self.state.next_node_id();
        let mut node = Node::new(
            id,
            &format!("join{:03}", self.nodes_joined + 1),
            Resources::cores_gb(spec.join_cores, spec.join_mem_gb),
            Bytes::from_gb(spec.join_disk_gb),
            Bandwidth::from_mbps(spec.join_bw_mbps),
        );
        if let Some(mbps) = self.cfg.bandwidth_mbps {
            node.bandwidth = Bandwidth::from_mbps(mbps);
        }
        let bw = node.bandwidth;
        self.state.add_node(node);
        self.links.add_node(bw);
        self.pulls.add_node();
        self.swarm.mark_dirty(id);
        self.nodes_joined += 1;
        self.events.record(t, NODE_SCOPE, EventKind::NodeJoined { node: id });
        if self.wake_parked() > 0 {
            self.drain_sched_queue();
        }
    }

    /// A node crashes: its running/pulling pods lose their instance and
    /// resubmit to the scheduling queue — without counting against the
    /// retry limit (kube controllers recreate pods of failed nodes; the
    /// retry budget guards scheduling failures, not infrastructure loss).
    fn handle_node_crash(&mut self, t: f64, node: NodeId) {
        if !self.state.node(node).is_up() {
            return;
        }
        let lost = self.state.crash_node(node);
        self.nodes_crashed += 1;
        // The dead node's in-flight transfer releases the shared registry
        // uplink (per-transfer bookings in `LinkModel`), so other nodes'
        // pulls planned after the crash see uplink capacity at baseline
        // instead of queuing behind a phantom transfer.
        self.links.release_node(node.0 as usize);
        self.pulls.clear_node(node.0 as usize);
        // The wiped layer cache must vanish from the swarm's holder lists.
        self.swarm.mark_dirty(node);
        self.events
            .record(t, NODE_SCOPE, EventKind::NodeCrashed { node, lost_pods: lost.len() });
        for pid in lost {
            // In-flight pull (if any) dies with the node; its queued
            // PullComplete event becomes a no-op.
            self.pending.remove(&pid);
            // Invalidate the old instance's termination timer.
            *self.epochs.entry(pid).or_insert(0) += 1;
            self.outcomes.insert(pid, PodOutcome::Lost);
            self.retry_counts.remove(&pid);
            self.resubmitted += 1;
            self.events.record(t, pid, EventKind::Resubmitted);
            self.sched_queue.push(pid);
        }
        self.drain_sched_queue();
    }

    /// Registry becomes unreachable until `until`: the watcher keeps its
    /// last good cache, and every in-flight WAN pull pauses for the
    /// remainder of the window.
    fn handle_outage_start(&mut self, t: f64, until: f64) {
        let effective_from = self.outage_until.max(t);
        if until <= effective_from {
            return; // window already covered by a live outage
        }
        let extra = until - effective_from;
        self.watcher.set_online(false);
        self.events.record(t, NODE_SCOPE, EventKind::RegistryOutageStart { until });
        self.links.stall_in_flight(t, extra);
        self.pulls.stall_in_flight(t, extra);
        // Collect, then sort: HashMap iteration order must never reach
        // the event log (byte-identical reports per seed). Only pulls
        // whose *WAN transfer* is still in flight stall (`finish > t`,
        // matching `stall_in_flight`'s bookkeeping) — pure-P2P/LAN tails
        // and zero-byte cache hits don't touch the registry, matching
        // the bind-during-outage exemption in `try_schedule`.
        let mut stalled: Vec<(PodId, NodeId, f64)> = Vec::new();
        // det: sorted(pid)
        for (pid, p) in self.pending.iter_mut() {
            if p.plan.bytes > Bytes::ZERO && p.plan.finish > t {
                p.plan.finish += extra;
                p.plan.ready_at = p.plan.ready_at.max(p.plan.finish);
                stalled.push((*pid, p.node, p.plan.ready_at));
            }
        }
        stalled.sort_by_key(|(pid, _, _)| pid.0);
        for (pid, node, resume_at) in stalled {
            self.pulls_stalled += 1;
            self.events
                .record(t, pid, EventKind::PullStalled { node, until: resume_at });
        }
        self.outage_until = until;
        self.queue.push(until, EventPayload::RegistryOutageEnd);
    }

    /// Capacity wake-up (`QueueingHint`): release parked pods whose
    /// rejection freed capacity could cure. Their `BackoffRelease` events
    /// stay queued as harmless no-op fallbacks, and each woken pod's next
    /// failed cycle is free — a wake retry is an opportunistic bonus, so
    /// it must not erode the `retry_limit × backoff` wall-clock coverage
    /// the timer path guarantees. Returns released count.
    fn wake_parked(&mut self) -> usize {
        if !self.cfg.wake_on_capacity {
            return 0;
        }
        let woken = self.sched_queue.wake_capacity();
        self.wakeups += woken.len() as u64;
        let n = woken.len();
        for pid in woken {
            self.retry_grace.insert(pid);
        }
        n
    }

    /// Pull the next arrival from the streaming source (if one is armed)
    /// and schedule its event. Offset-timed runs schedule at
    /// `t0 + offset`; sequential-protocol chaining schedules at the
    /// resolution time `now`. Sources emit non-decreasing offsets (the
    /// [`ArrivalSource`] contract), so the scheduled time never precedes
    /// the clock; the `max` guards a misbehaving source anyway.
    fn pump_arrival(&mut self, now: f64) {
        let next = match &mut self.arrival_source {
            None => return,
            Some(src) => src.next_arrival(),
        };
        if let Some((offset, pod)) = next {
            let at = if self.chain_arrivals {
                now
            } else {
                self.arrivals_t0 + offset.max(0.0)
            };
            self.queue.push(at.max(now), EventPayload::Arrival { pod });
            self.arrival_pending = true;
        }
    }

    /// In the sequential protocol, the next pod arrives once the current
    /// one resolves (container started, pull wedged, or retries
    /// exhausted). A pod releases the next arrival exactly once: a crash
    /// re-resolution must not run arrivals ahead of the one-at-a-time
    /// protocol, and a mid-pull crash must not lose the chain.
    fn chain_next_arrival(&mut self, t: f64, resolved: PodId) {
        if self.chain_arrivals && self.chained.insert(resolved) {
            self.pump_arrival(t);
        }
    }

    fn drain_sched_queue(&mut self) {
        while let Some(pid) = self.sched_queue.pop() {
            self.try_schedule(pid);
        }
    }

    // --- scheduling cycle -------------------------------------------------

    /// One scheduling cycle for `pid`: filter + score + bind + begin pull,
    /// or park with back-off / give up.
    fn try_schedule(&mut self, pid: PodId) {
        let now = self.clock.now();
        self.gc_pressure_sweep();

        let pod = self.state.pod(pid).cloned().expect("queued pod exists");
        let (meta, required, bytes) = CycleContext::prepare(&mut self.state, &self.cache, &pod);
        let ctx = CycleContext::new(&self.state, &pod, meta, required.clone(), bytes);
        let pool = self.pool.as_ref();
        let decision = match &mut self.scheduler {
            SchedImpl::Lr(s) => s.schedule_with_pool(&ctx, pool),
            SchedImpl::Rl(s) => s.schedule(&ctx).map(|node| {
                // Build an equivalent decision record for the RL pick.
                let n = ctx.state.node(node);
                let local = crate::sched::layer_score::local_bytes(&ctx, n);
                crate::sched::Decision {
                    node,
                    final_score: 0.0,
                    layer_score: crate::sched::layer_score::layer_sharing_score(
                        local,
                        ctx.required_bytes,
                    ),
                    k8s_score: 0.0,
                    omega: 0.0,
                    download_cost: crate::sched::layer_score::download_cost(&ctx, n),
                    breakdown: Vec::new(),
                }
            }),
        };
        let decision = match decision {
            Ok(d) => d,
            Err(u) => {
                drop(ctx);
                // Wake-released cycles are uncharged (see `wake_parked`);
                // timer releases and first attempts consume the budget.
                let graced = self.retry_grace.remove(&pid);
                let attempts = {
                    let c = self.retry_counts.entry(pid).or_insert(0);
                    if !graced {
                        *c += 1;
                    }
                    *c
                };
                if attempts > self.cfg.retry_limit {
                    // Retries exhausted: the pod is unschedulable for good.
                    self.retry_counts.remove(&pid);
                    self.outcomes.insert(pid, PodOutcome::Unschedulable);
                    self.events
                        .record(now, pid, EventKind::Unschedulable { reason: u.to_string() });
                    self.chain_next_arrival(now, pid);
                } else {
                    // Park with back-off and retry (kube-scheduler's
                    // unschedulable queue, instead of dropping the pod).
                    // The cure class routes capacity wake-ups to it.
                    self.retries += 1;
                    let release_at = self.sched_queue.park_with_cure(pid, now, cure_for(&u));
                    self.queue.push(release_at, EventPayload::BackoffRelease);
                    self.events.record(
                        now,
                        pid,
                        EventKind::Unschedulable {
                            reason: format!(
                                "parked for retry {attempts}/{} (0/{} nodes available)",
                                self.cfg.retry_limit,
                                u.rejections.len()
                            ),
                        },
                    );
                }
                return;
            }
        };
        drop(ctx);
        self.retry_counts.remove(&pid);
        self.retry_grace.remove(&pid);

        self.events.record(
            now,
            pid,
            EventKind::Scheduled { node: decision.node, score: decision.final_score },
        );
        self.state.bind(pid, decision.node).expect("bind after schedule");

        // Per-layer use metadata: stamp demand for the required layers on
        // the chosen node. Maintained under every policy (the default
        // PressureSweep simply never reads it, keeping its behaviour
        // byte-identical to the pre-policy engine).
        {
            let decay = self.cfg.cache_decay_secs;
            let node = self.state.node_mut(decision.node);
            for l in required.iter() {
                node.touch_layer(l, now, decay);
            }
        }
        if self.cfg.cache_policy == CachePolicyChoice::Prefetch {
            let decay = self.cfg.cache_decay_secs;
            for l in required.iter() {
                let e = self.layer_heat.entry(l).or_insert((0.0, 0.0));
                e.0 = cache::decayed(e.0, e.1, now, decay) + 1.0;
                e.1 = now;
            }
        }

        if self.cfg.p2p_lan_mbps.is_some() {
            self.swarm.sync(&self.state);
        }
        let swarm_view = self.cfg.p2p_lan_mbps.map(|mbps| Swarm {
            index: &self.swarm,
            lan_bw: Bandwidth::from_mbps(mbps),
            seeder_cap: self.cfg.p2p_seeder_cap,
        });
        let mut pending = kubelet::begin_pull(
            &self.state,
            &mut self.pulls,
            &mut self.links,
            now,
            pid,
            decision.node,
            &pod.image,
            &required,
            swarm_view.as_ref(),
        );
        self.events.record(
            now,
            pid,
            EventKind::PullStarted {
                node: decision.node,
                bytes: pending.plan.bytes,
                layers: pending.plan.new_layers.len(),
            },
        );
        if pending.p2p_bytes > Bytes::ZERO {
            self.events.record(
                now,
                pid,
                EventKind::PeerFetch {
                    node: decision.node,
                    bytes: pending.p2p_bytes,
                    layers: pending.p2p_layers,
                },
            );
        }
        if self.outage_until > now && pending.plan.bytes > Bytes::ZERO {
            // WAN transfer begun during a registry outage: it cannot move
            // bytes until the window closes. Shift the transfer finish,
            // the in-flight layer bookkeeping (so same-node followers
            // wait for the real arrival and `PullManager::gc` cannot drop
            // the entries mid-stall), and the link booking.
            let stall = self.outage_until - now;
            pending.plan.finish += stall;
            pending.plan.ready_at = pending.plan.ready_at.max(pending.plan.finish);
            self.pulls
                .delay_layers(decision.node.0 as usize, &pending.plan.new_layers, stall);
            self.links.delay_booking(decision.node.0 as usize, stall);
            self.pulls_stalled += 1;
            self.events.record(
                now,
                pid,
                EventKind::PullStalled { node: decision.node, until: pending.plan.ready_at },
            );
        }
        let (wan_bytes, p2p_bytes) = (pending.wan_bytes, pending.p2p_bytes);
        let ready_at = pending.plan.ready_at;
        let download_secs = ready_at - now;
        self.pending.insert(pid, pending);
        self.queue.push(ready_at, EventPayload::PullComplete { pod: pid });

        // Cache-hit accounting: the required bytes not transferred (WAN or
        // peer LAN) were already local on the chosen node.
        let total_required = required.total_bytes(&self.state.interner);
        self.cache_required_bytes += total_required;
        self.cache_hit_bytes +=
            total_required.saturating_sub(wan_bytes).saturating_sub(p2p_bytes);
        if self.cfg.cache_policy == CachePolicyChoice::Prefetch {
            self.prefetch_on_intent(now, decision.node, &required, wan_bytes + p2p_bytes);
        }

        let std_after = metrics::cluster_std(&self.state);
        if let SchedImpl::Rl(s) = &mut self.scheduler {
            // Online reward: the paper's two objectives as one scalar.
            s.learn(wan_bytes.as_mb(), std_after);
        }
        if self.collect_decisions {
            self.decision_log.push(DecisionDetail {
                pod: pid,
                pod_name: pod.name.clone(),
                image: pod.image.key(),
                node: decision.node,
                node_name: self.state.node(decision.node).name.clone(),
                final_score: decision.final_score,
                layer_score: decision.layer_score,
                k8s_score: decision.k8s_score,
                omega: decision.omega,
                breakdown: decision.breakdown.clone(),
                wan_bytes,
                p2p_bytes,
                est_secs: download_secs,
                at: now,
            });
        }
        self.records.push(PodRecord {
            pod: pid,
            image: pod.image.key(),
            node: self.state.node(decision.node).name.clone(),
            download: wan_bytes,
            p2p: p2p_bytes,
            download_secs,
            std_after,
            omega: decision.omega,
            layer_score: decision.layer_score,
            final_score: decision.final_score,
            at: now,
        });
        let every = self.cfg.snapshot_every.max(1);
        if self.records.len() % every == 0 {
            self.snapshots.push(metrics::snapshot(&self.state, now));
        }
    }

    /// Prefetch-on-intent: at bind time, warm the hottest globally
    /// demanded layers (decayed bind-frequency from `layer_heat`) onto the
    /// chosen node, up to the configured byte budget and the disk headroom
    /// left after the bound pod's own pending install. Runs on the
    /// coordinator inside the scheduling cycle, so it is byte-identical at
    /// every shard count by construction.
    fn prefetch_on_intent(
        &mut self,
        now: f64,
        node: NodeId,
        required: &LayerSet,
        pending_bytes: Bytes,
    ) {
        let decay = self.cfg.cache_decay_secs;
        let n = self.state.node(node);
        let headroom = n.disk_free().saturating_sub(pending_bytes);
        let mut budget = self.cfg.cache_prefetch_bytes;
        if headroom < budget {
            budget = headroom;
        }
        if budget == Bytes::ZERO {
            return;
        }
        // Hottest first; the layer id breaks ties so the order is total.
        let mut hot: Vec<(LayerId, f64)> = self
            .layer_heat
            .iter()
            .filter(|(l, _)| !required.contains(**l) && !n.layers.contains(**l))
            .map(|(l, &(w, at))| (*l, cache::decayed(w, at, now, decay)))
            .filter(|(_, h)| *h > 1e-12)
            .collect();
        hot.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        let mut picked: Vec<LayerId> = Vec::new();
        let mut cost = Bytes::ZERO;
        for (l, _) in hot {
            let size = self.state.interner.size(l);
            if size == Bytes::ZERO || cost + size > budget {
                continue;
            }
            cost += size;
            picked.push(l);
        }
        if picked.is_empty() {
            return;
        }
        let (bytes, count) = self.state.prefetch_layers(node, &picked, now);
        if count > 0 {
            self.swarm.mark_dirty(node);
            self.events
                .record(now, NODE_SCOPE, EventKind::Prefetched { node, bytes, layers: count });
        }
    }

    // --- kubelet ----------------------------------------------------------

    /// Kubelet image GC: when a node crosses the high disk-usage threshold
    /// (kubelet's ImageGCHighThresholdPercent analog, 85%), evict unused
    /// images down to the low threshold (70%). Returns whether anything
    /// was evicted (eviction is a capacity-freeing wake-up source).
    fn gc_pressure_sweep(&mut self) -> bool {
        if !self.cfg.gc_enabled {
            return false;
        }
        let mut evicted_any = false;
        for i in 0..self.state.node_count() {
            evicted_any |= self.gc_check_node(NodeId(i as u32));
        }
        evicted_any
    }

    /// The per-node body of [`Simulation::gc_pressure_sweep`], also the
    /// [`EventPayload::GcSweepNode`] handler (a termination changes only
    /// its own node's in-use image set). The sharded lanes replicate this
    /// check verbatim against their node slices.
    fn gc_check_node(&mut self, node: NodeId) -> bool {
        if !self.cfg.gc_enabled {
            return false;
        }
        let now = self.clock.now();
        let n = self.state.node(node);
        if !n.is_up() {
            return false; // a crashed node's disk is gone, not reclaimable
        }
        let (disk, used) = (n.disk.0 as f64, n.disk_used.0 as f64);
        if disk > 0.0 && used / disk > self.cfg.gc_high_pct {
            // Free down to the low-threshold usage.
            let target = Bytes((disk * (1.0 - self.cfg.gc_low_pct)) as u64);
            let freed = kubelet::gc_images(
                &mut self.state,
                &self.images,
                node,
                target,
                self.cfg.cache_policy,
                self.cfg.cache_decay_secs,
                now,
            );
            if freed > Bytes::ZERO {
                self.swarm.mark_dirty(node);
                self.events.record(
                    now,
                    NODE_SCOPE, // node-level event
                    EventKind::Evicted { node, bytes: freed },
                );
                return true;
            }
        }
        false
    }

    /// Install the pulled image and start the container. Returns whether
    /// the container actually started.
    fn finish_pull(&mut self, p: PendingStart) -> bool {
        let now = p.plan.ready_at;
        if self.cfg.gc_enabled {
            let need = p.layers.difference_bytes(
                &self.state.node(p.node).layers,
                &self.state.interner,
            );
            if need > self.state.node(p.node).disk_free() {
                let freed = kubelet::gc_images(
                    &mut self.state,
                    &self.images,
                    p.node,
                    need,
                    self.cfg.cache_policy,
                    self.cfg.cache_decay_secs,
                    now,
                );
                if freed > Bytes::ZERO {
                    self.swarm.mark_dirty(p.node);
                    self.events.record(
                        now,
                        p.pod,
                        EventKind::Evicted { node: p.node, bytes: freed },
                    );
                }
            }
        }
        match kubelet::complete_pull(&mut self.state, &p) {
            Ok(_) => {
                // The node now advertises the freshly installed layers.
                self.swarm.mark_dirty(p.node);
                {
                    let node = self.state.node_mut(p.node);
                    for l in p.layers.iter() {
                        node.touch_layer_install(l, now);
                    }
                }
                self.images.remember(&p.image, &p.layers);
                self.outcomes.insert(p.pod, PodOutcome::Started);
                self.events.record(
                    now,
                    p.pod,
                    EventKind::PullFinished { node: p.node, secs: now - p.plan.start },
                );
                self.events.record(now, p.pod, EventKind::Started { node: p.node });
                true
            }
            Err(e) => {
                // Disk overcommitted by concurrent binds: the pod wedges
                // (ImagePullBackOff analog). Counted, surfaced in events.
                self.outcomes.insert(p.pod, PodOutcome::FailedPull);
                self.events.record(
                    now,
                    p.pod,
                    EventKind::Unschedulable { reason: format!("pull failed: {e}") },
                );
                false
            }
        }
    }

    // --- public driving API ----------------------------------------------

    /// Deploy one pod at the current virtual time and run the event loop to
    /// quiescence. Returns false if the scheduler never found a feasible
    /// node (even after retries).
    pub fn deploy(&mut self, pod: Pod) -> bool {
        let pid = pod.id;
        let now = self.clock.now();
        self.arm_watcher(now);
        self.queue.push(now, EventPayload::Arrival { pod });
        self.run_events();
        // A record exists iff the pod bound. (The binding itself may be
        // gone already: a finite-duration pod can terminate inside the
        // same drain.)
        self.records.iter().rev().any(|r| r.pod == pid)
    }

    /// Queue an arbitrary event at absolute virtual time `at` — the
    /// failure-injection hook: tests and harnesses drive node churn and
    /// registry outages through it without a [`ChurnConfig`].
    pub fn inject_event(&mut self, at: f64, payload: EventPayload) {
        self.queue.push(at, payload);
    }

    /// Enqueue the seeded cluster-volatility trace (if configured).
    fn inject_churn_trace(&mut self, t0: f64) {
        let churn = match &self.cfg.churn {
            Some(c) => c.clone(),
            None => return,
        };
        for ev in ChurnModel::trace(&churn, self.state.node_count()) {
            let at = t0 + ev.at;
            let payload = match ev.action {
                ChurnAction::Join => EventPayload::NodeJoin,
                ChurnAction::Drain { node } => EventPayload::NodeDrain { node },
                ChurnAction::Crash { node } => EventPayload::NodeCrash { node },
                ChurnAction::Outage { secs } => {
                    EventPayload::RegistryOutageStart { until: at + secs }
                }
            };
            self.queue.push(at, payload);
        }
    }

    /// Run a whole trace through the event queue. Timed mode replays the
    /// pods at the fixed `inter_arrival_secs` cadence; sequential mode
    /// chains each arrival to the previous pod's resolution. Both reduce
    /// to a buffered [`VecSource`] driven through the streaming
    /// [`Simulation::run_source`] loop. Returns once every event —
    /// including terminations, churn, and back-off releases due after
    /// the last pull — fired.
    pub fn run_trace(&mut self, pods: Vec<Pod>) -> SimReport {
        match self.cfg.inter_arrival_secs {
            Some(dt) => {
                let arrivals: Vec<(f64, Pod)> =
                    pods.into_iter().enumerate().map(|(i, p)| (i as f64 * dt, p)).collect();
                self.run_source(Box::new(VecSource::new(arrivals)))
            }
            None => {
                // Offsets are ignored under chaining; 0.0 keeps VecSource's
                // stable sort a no-op so submission order is preserved.
                let arrivals: Vec<(f64, Pod)> = pods.into_iter().map(|p| (0.0, p)).collect();
                self.chain_arrivals = true;
                let report = self.run_source(Box::new(VecSource::new(arrivals)));
                self.chain_arrivals = false;
                report
            }
        }
    }

    /// Replay explicit `(arrival-offset, pod)` pairs — the buffered
    /// trace-replay entry point ([`crate::sim::trace::Trace::arrivals`]):
    /// each pod arrives at `now + offset`, preserving a real trace's
    /// burstiness instead of the fixed `inter_arrival_secs` cadence.
    /// Offsets must be finite; negative offsets clamp to the current
    /// time. Equivalent to [`Simulation::run_source`] over a
    /// [`VecSource`] — which is exactly what it does.
    pub fn run_arrivals(&mut self, arrivals: Vec<(f64, Pod)>) -> SimReport {
        self.run_source(Box::new(VecSource::new(arrivals)))
    }

    /// Drive the engine from a pull-based [`ArrivalSource`] — the
    /// constant-memory arrival loop: the queue holds at most one future
    /// arrival, and popping it (or, under the sequential protocol,
    /// resolving its pod) pulls the next from the source. Event order —
    /// and therefore the report and the event log — is byte-identical to
    /// enqueuing every arrival up front, because arrivals are the last
    /// event class at any timestamp and sources emit non-decreasing
    /// offsets. Returns once the source is exhausted and every event
    /// fired. Source-side errors have no channel here: sources that can
    /// fail mid-stream (e.g. [`crate::sim::trace::TraceSource`]) record
    /// the error for the caller to check after the run.
    pub fn run_source(&mut self, source: Box<dyn ArrivalSource>) -> SimReport {
        let t0 = self.clock.now();
        self.arm_watcher(t0);
        self.inject_churn_trace(t0);
        self.arrivals_t0 = t0;
        self.arrival_source = Some(source);
        // Seed the chain with the first arrival; each pop/resolution
        // pulls the next.
        self.pump_arrival(t0);
        let report = self.drain_and_report();
        self.arrival_source = None;
        report
    }

    // --- serve sessions ---------------------------------------------------

    /// Open a live serve session over `source` (normally a
    /// [`crate::sim::arrivals::StreamSource`]): arm the watcher, anchor
    /// arrival offsets at the current clock, and mark the session open so
    /// the watcher keeps polling while the stream may still produce
    /// arrivals. The caller then alternates
    /// [`Simulation::pump_stream`] / [`Simulation::step_until`] as events
    /// arrive and finishes with [`Simulation::close_stream`]. Exactly the
    /// [`Simulation::run_source`] loop, cut at the arrival boundary — the
    /// popped event sequence (and therefore the report, records, and
    /// event log) is byte-identical to handing the same arrivals to
    /// `run_source` up front, because arrivals are the last event class
    /// at any timestamp and stream offsets are non-decreasing.
    pub fn open_stream(&mut self, source: Box<dyn ArrivalSource>) {
        let t0 = self.clock.now();
        self.arm_watcher(t0);
        self.arrivals_t0 = t0;
        self.arrival_source = Some(source);
        self.session_open = true;
    }

    /// Pull the next arrival from the session source unless one is
    /// already queued — the serve-session pump. Preserves the arrival
    /// pipeline's one-future-arrival invariant even though the session
    /// pumps after every pushed pod rather than once per arrival pop.
    pub fn pump_stream(&mut self) {
        if !self.arrival_pending {
            let now = self.clock.now();
            self.pump_arrival(now);
        }
    }

    /// Incremental stepping: pop and dispatch every queued event due at
    /// or before virtual time `t`, without draining the horizon. The
    /// clock advances only to event times (never to `t` itself), so a
    /// later-pushed arrival at exactly `t` still fires at its own
    /// timestamp — the serve session calls this before injecting each
    /// stream event to bring the engine to that event's frontier.
    pub fn step_until(&mut self, t: f64) {
        loop {
            let due = match self.queue.peek() {
                Some(head) => head.at <= t,
                None => false,
            };
            if !due {
                return;
            }
            let ev = self.queue.pop().expect("peeked event exists");
            if ev.payload.is_watcher() && !self.queue.has_pending_work() && !self.session_open
            {
                self.watcher_armed = false;
                continue;
            }
            self.advance_clock(ev.at);
            let now = self.clock.now();
            self.step_event(now, ev.payload);
        }
    }

    /// End a serve session: mark the stream closed (the watcher may now
    /// disarm when real work drains), run every remaining event to
    /// quiescence — the same tail a batch run executes after its last
    /// arrival — take the final snapshot, and build the report.
    pub fn close_stream(&mut self) -> SimReport {
        self.session_open = false;
        let report = self.drain_and_report();
        self.arrival_source = None;
        report
    }

    /// Toggle per-bind [`DecisionDetail`] capture (serve mode). Off by
    /// default: batch replays keep constant memory.
    pub fn collect_decisions(&mut self, on: bool) {
        self.collect_decisions = on;
    }

    /// Drain the decisions captured since the last call (empty unless
    /// [`Simulation::collect_decisions`] is on).
    pub fn take_decisions(&mut self) -> Vec<DecisionDetail> {
        std::mem::take(&mut self.decision_log)
    }

    /// Run the event loop to quiescence, take the final snapshot, and
    /// build the report (shared tail of [`Simulation::run_trace`] and
    /// [`Simulation::run_arrivals`]).
    fn drain_and_report(&mut self) -> SimReport {
        self.run_events();
        // Final snapshot so end-of-run metrics (final_std, disk usage) see
        // the fully drained state — terminations included.
        self.snapshots.push(metrics::snapshot(&self.state, self.clock.now()));
        self.report()
    }

    /// Aggregate the current outcome tallies, records, and snapshots into
    /// a [`SimReport`] (also the tail of every `run_*` entry point).
    pub fn report(&self) -> SimReport {
        let (w1, w2, wmid, trace) = match &self.scheduler {
            SchedImpl::Lr(s) => (
                s.stats.omega1_used,
                s.stats.omega2_used,
                s.stats.omega_mid_used,
                s.stats.omega_trace.clone(),
            ),
            SchedImpl::Rl(_) => (0, 0, 0, Vec::new()),
        };
        // Tally terminal pod states: every submitted pod lands in exactly
        // one bucket (the accounting identity the scale harness checks).
        let (mut started, mut failed, mut unsched, mut lost) = (0, 0, 0, 0);
        for outcome in self.outcomes.values() {
            match outcome {
                PodOutcome::Started => started += 1,
                PodOutcome::FailedPull => failed += 1,
                PodOutcome::Unschedulable => unsched += 1,
                PodOutcome::Lost => lost += 1,
            }
        }
        // Byte totals come from the single merged event log, so sequential
        // and sharded runs tally eviction/prefetch identically.
        let (mut evicted, mut prefetched) = (Bytes::ZERO, Bytes::ZERO);
        for e in self.events.all() {
            match e.kind {
                EventKind::Evicted { bytes, .. } => evicted += bytes,
                EventKind::Prefetched { bytes, .. } => prefetched += bytes,
                _ => {}
            }
        }
        let cache_hit_rate = if self.cache_required_bytes == Bytes::ZERO {
            0.0
        } else {
            self.cache_hit_bytes.0 as f64 / self.cache_required_bytes.0 as f64
        };
        SimReport {
            scheduler: self.cfg.scheduler.label(),
            records: self.records.clone(),
            snapshots: self.snapshots.clone(),
            submitted: self.submitted,
            started,
            unschedulable: unsched,
            failed_pulls: failed,
            lost_to_crash: lost,
            retries: self.retries,
            resubmitted: self.resubmitted,
            pulls_stalled: self.pulls_stalled,
            peak_peer_uploads: self.links.peak_peer_uploads(),
            wakeups: self.wakeups,
            nodes_joined: self.nodes_joined,
            nodes_drained: self.nodes_drained,
            nodes_crashed: self.nodes_crashed,
            omega1_used: w1,
            omega2_used: w2,
            omega_mid_used: wmid,
            omega_trace: trace,
            cache_hit_rate,
            evicted_bytes: evicted,
            prefetched_bytes: prefetched,
        }
    }
}

/// Which wake-up class could cure this rejection set? If *any* node was
/// rejected for lack of capacity (resources, container slots, disk/volume,
/// or node lifecycle), freed capacity might cure the pod; purely
/// constraint-based rejections (taints, affinity) only a timer revisits.
fn cure_for(u: &Unschedulable) -> ParkCure {
    let capacity_ish = u.rejections.iter().any(|(_, plugin, _)| {
        matches!(
            *plugin,
            "NodeResourcesFit" | "NodeCapacity" | "VolumeBinding" | "NodeUnschedulable"
        )
    });
    if capacity_ish {
        ParkCure::Capacity
    } else {
        ParkCure::Timer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use crate::cluster::Resources;
    use crate::sim::workload::{WorkloadConfig, WorkloadGen};

    fn nodes(n: u32) -> Vec<Node> {
        (0..n)
            .map(|i| {
                Node::new(
                    NodeId(i),
                    &format!("worker{}", i + 1),
                    Resources::cores_gb(4.0, 4.0),
                    Bytes::from_gb(30.0),
                    Bandwidth::from_mbps(10.0),
                )
            })
            .collect()
    }

    #[test]
    fn sequential_run_deploys_everything() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        let mut sim = Simulation::new(nodes(4), reg, SimConfig::default());
        let report = sim.run_trace(trace);
        assert_eq!(report.deployed(), 10);
        assert_eq!(report.submitted, 10);
        assert_eq!(report.unschedulable, 0);
        assert_eq!(report.failed_pulls, 0);
        assert!(report.accounting_balanced());
        assert!(report.total_download() > Bytes::ZERO);
        sim.state.check_invariants().unwrap();
        // Clock advanced by the total download time.
        assert!(sim.clock.now() > 0.0);
    }

    #[test]
    fn cure_relevant_event_closes_the_window_at_its_slot() {
        // The cure-aware collection contract, pinned at the unit level:
        // with a capacity-curable pod parked, safe node-local events keep
        // extending the window, and the first wake-relevant event (here a
        // valid termination) becomes the final slot — later node-local
        // events stay queued for the next window.
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let mut pod = gen.next_pod();
        pod.duration_secs = None; // keep the binding alive after deploy
        let pid = pod.id;
        let cfg = SimConfig { shards: 2, inter_arrival_secs: Some(1.0), ..Default::default() };
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        assert!(sim.deploy(pod));
        let node = sim.state.binding(pid).expect("deployed pod stays bound");

        // Park a capacity-curable pod: wake_possible is now true, so the
        // window must stop at the first event that could wake it.
        sim.sched_queue.park_with_cure(PodId(9_999), sim.clock.now(), ParkCure::Capacity);
        assert_eq!(sim.sched_queue.capacity_parked(), 1);

        let t = sim.clock.now();
        // GC is disabled, so per-node GC checks cannot evict — safe
        // mid-window. The termination is the first wake-relevant event.
        sim.queue.push(t + 1.0, EventPayload::GcSweepNode { node });
        sim.queue.push(t + 2.0, EventPayload::GcSweepNode { node });
        sim.queue.push(t + 3.0, EventPayload::PodTermination { pod: pid, epoch: 0 });
        sim.queue.push(t + 4.0, EventPayload::GcSweepNode { node });

        let w = sim.collect_window(2);
        assert_eq!(w.n_slots, 3, "two safe sweeps + the closing termination");
        assert!(w.wake_candidate, "the final slot must carry the barrier wake");
        assert_eq!(sim.window_stats().wake_stops, 1);
        let head = sim.queue.peek().expect("trailing sweep still queued");
        assert_eq!(head.at, t + 4.0, "events after the wake stop wait for the next window");
    }

    #[test]
    fn pull_completions_extend_windows_while_pods_are_parked() {
        // A parked pod must no longer disable windowing: pull completions
        // can never wake anything, so they are collected even with a
        // capacity-curable pod parked.
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let mut pod = gen.next_pod();
        pod.duration_secs = None;
        let cfg = SimConfig { shards: 2, inter_arrival_secs: Some(1.0), ..Default::default() };
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        assert!(sim.deploy(pod));
        sim.sched_queue.park_with_cure(PodId(9_999), sim.clock.now(), ParkCure::Capacity);
        let before = sim.window_stats();
        // Deploy another pod: its pull completion must drain through a
        // parallel window despite the parked pod.
        let mut second = gen.next_pod();
        second.duration_secs = None;
        assert!(sim.deploy(second));
        let after = sim.window_stats();
        assert!(
            after.windowed_events > before.windowed_events,
            "pull completion must ride a window, not a sequential stretch"
        );
    }

    #[test]
    fn repeat_images_download_less() {
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let first = gen.next_pod();
        // Same image five times.
        let mut pods = vec![first.clone()];
        for _ in 0..4 {
            let mut p = gen.next_pod();
            p.image = first.image.clone();
            pods.push(p);
        }
        let mut sim = Simulation::new(nodes(3), reg, SimConfig::default());
        let report = sim.run_trace(pods);
        // After the first few placements every node can hold the image, so
        // at least one later deployment is a zero-byte pull.
        assert!(report.records.iter().skip(1).any(|r| r.download == Bytes::ZERO));
    }

    #[test]
    fn lr_downloads_less_than_default() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(20);
        let mut total = std::collections::HashMap::new();
        for choice in SchedulerChoice::all() {
            let mut cfg = SimConfig::default();
            cfg.scheduler = choice;
            let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
            let report = sim.run_trace(trace.clone());
            assert_eq!(report.deployed(), 20, "{choice:?}");
            total.insert(choice.label(), report.total_download());
        }
        assert!(
            total["LRScheduler"] < total["Default"],
            "LR {} !< Default {}",
            total["LRScheduler"],
            total["Default"]
        );
        // Layer (static ω=4) also beats Default; its ordering vs. LR varies
        // per trace (the paper's Table I shows the same per-step flips).
        assert!(
            total["Layer"] < total["Default"],
            "Layer {} !< Default {}",
            total["Layer"],
            total["Default"]
        );
        let _ = reg;
    }

    #[test]
    fn timed_arrivals_overlap_pulls() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(8);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.deployed(), 8);
        // Arrivals every 1s while pulls take tens of seconds ⇒ the clock
        // at the last arrival is ~8s but the drain runs far past it.
        assert!(sim.clock.now() > 8.0);
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn omega_stats_recorded_for_lr_only() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(12);
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
        let report = sim.run_trace(trace.clone());
        assert_eq!(report.omega1_used + report.omega2_used, 12);
        assert_eq!(report.omega_mid_used, 0, "TwoLevel has no mid weight");
        assert_eq!(report.omega_trace.len(), 12);

        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::Default;
        let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.omega1_used + report.omega2_used, 0);
        let _ = reg;
    }

    #[test]
    fn unschedulable_pods_counted_not_fatal() {
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let mut big = gen.next_pod();
        big.requests = Resources::cores_gb(64.0, 64.0);
        let ok = gen.next_pod();
        let mut sim = Simulation::new(nodes(2), reg, SimConfig::default());
        let report = sim.run_trace(vec![big, ok]);
        assert_eq!(report.unschedulable, 1);
        assert_eq!(report.deployed(), 1);
        // The impossible pod exercised the back-off queue before giving up.
        assert_eq!(report.retries as u32, SimConfig::default().retry_limit);
        assert!(report.accounting_balanced());
    }

    #[test]
    fn terminations_fire_after_final_pull() {
        // Seed bug: the drain only advanced to the last pull's ready_at,
        // so terminations due later never fired and resources stayed bound.
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let pods: Vec<Pod> = (0..6).map(|_| gen.next_pod().with_duration(40.0)).collect();
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        let mut sim = Simulation::new(nodes(3), reg, cfg);
        let report = sim.run_trace(pods);
        assert_eq!(report.deployed(), 6);
        for node in sim.state.nodes() {
            assert_eq!(node.used, Resources::ZERO, "{}: resources still bound", node.name);
            assert!(node.pods.is_empty());
        }
        // The final snapshot reflects the drained cluster.
        let last = report.snapshots.last().unwrap();
        assert_eq!(last.cpu_util, 0.0);
        assert_eq!(last.mem_util, 0.0);
        assert!((report.final_std() - 0.0).abs() < 1e-12);
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn retried_pod_binds_when_capacity_frees() {
        let reg = Registry::with_corpus();
        let mut b = crate::cluster::PodBuilder::new();
        // Pod A fills the single node; pod B must wait for A to die.
        let a = b.build("redis:7.2", Resources::cores_gb(3.9, 0.5)).with_duration(30.0);
        let bpod = b.build("nginx:1.25", Resources::cores_gb(3.9, 0.5));
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        cfg.retry_limit = 20;
        let mut sim = Simulation::new(nodes(1), reg, cfg);
        let report = sim.run_trace(vec![a, bpod]);
        assert_eq!(report.deployed(), 2, "retry must eventually bind pod B");
        assert_eq!(report.unschedulable, 0);
        assert!(report.retries > 0, "pod B must have parked at least once");
        assert!(report.accounting_balanced());
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn per_instance_cache_paths_differ() {
        let a = Simulation::new(nodes(1), Registry::with_corpus(), SimConfig::default());
        let b = Simulation::new(nodes(1), Registry::with_corpus(), SimConfig::default());
        assert_ne!(a.cache.cache_file, b.cache.cache_file);
    }

    #[test]
    fn snapshot_cadence_bounds_memory() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(20);
        let mut cfg = SimConfig::default();
        cfg.snapshot_every = 7;
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        let report = sim.run_trace(trace);
        // 20 placements / 7 = 2 periodic snapshots + 1 final.
        assert_eq!(report.snapshots.len(), 3);
    }

    #[test]
    fn node_crash_resubmits_running_pods() {
        // 3 nodes × 2 pods of 1.5 cores each (a third never fits): node 0's
        // crash loses 2 instances, which resubmit without burning the retry
        // budget and rebind once survivors terminate.
        let reg = Registry::with_corpus();
        let mut b = crate::cluster::PodBuilder::new();
        let pods: Vec<Pod> = (0..6)
            .map(|_| b.build("redis:7.2", Resources::cores_gb(1.5, 0.5)).with_duration(120.0))
            .collect();
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        cfg.retry_limit = 200;
        let mut sim = Simulation::new(nodes(3), reg, cfg);
        sim.inject_event(50.0, EventPayload::NodeCrash { node: NodeId(0) });
        let report = sim.run_trace(pods);

        assert_eq!(report.nodes_crashed, 1);
        assert_eq!(report.resubmitted, 2, "node 0 held exactly 2 pods at t=50");
        assert_eq!(report.deployed(), 8, "6 first placements + 2 re-placements");
        assert_eq!(report.completed(), 6, "every pod eventually ran");
        assert_eq!(report.lost_to_crash, 0, "all lost instances re-resolved");
        assert_eq!(report.unschedulable, 0);
        assert!(report.accounting_balanced());
        let down = sim.state.node(NodeId(0));
        assert!(!down.is_up());
        assert!(down.pods.is_empty());
        assert_eq!(down.disk_used, Bytes::ZERO, "crashed node lost its cache");
        let crashes = sim
            .events
            .all()
            .iter()
            .filter(|e| matches!(e.kind, EventKind::NodeCrashed { lost_pods: 2, .. }))
            .count();
        assert_eq!(crashes, 1);
        assert_eq!(
            sim.events.all().iter().filter(|e| e.kind == EventKind::Resubmitted).count(),
            2
        );
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn drain_stops_new_bindings_and_lets_pods_finish() {
        let reg = Registry::with_corpus();
        let mut b = crate::cluster::PodBuilder::new();
        let first = b.build("redis:7.2", Resources::cores_gb(0.5, 0.5)).with_duration(30.0);
        let later: Vec<Pod> =
            (0..2).map(|_| b.build("nginx:1.25", Resources::cores_gb(0.5, 0.5))).collect();
        let mut pods = vec![first];
        pods.extend(later);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(5.0);
        let mut sim = Simulation::new(nodes(2), reg, cfg);
        // Cordon worker1 after the first pod binds there (tie-break picks
        // the lower node id on an idle cluster) but before the others land.
        sim.inject_event(2.5, EventPayload::NodeDrain { node: NodeId(0) });
        let report = sim.run_trace(pods);

        assert_eq!(report.nodes_drained, 1);
        assert_eq!(report.deployed(), 3);
        assert_eq!(report.records[0].node, "worker1");
        assert!(
            report.records.iter().skip(1).all(|r| r.node == "worker2"),
            "post-drain bindings must avoid the cordoned node"
        );
        // The drained node's pod ran to completion there.
        assert!(sim.state.node(NodeId(0)).pods.is_empty());
        assert!(!sim.state.node(NodeId(0)).is_schedulable());
        assert!(sim.state.node(NodeId(0)).is_up());
        assert!(report.accounting_balanced());
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn joined_node_wakes_and_binds_parked_pod() {
        // A full single-node cluster parks pod B; a cold node joining at
        // t=30 must wake it immediately — before its next back-off deadline
        // — and the ScoreArena path must pick the new row up cleanly.
        let reg = Registry::with_corpus();
        let mut b = crate::cluster::PodBuilder::new();
        let a = b.build("redis:7.2", Resources::cores_gb(3.9, 0.5));
        let bpod = b.build("nginx:1.25", Resources::cores_gb(3.9, 0.5));
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        cfg.retry_limit = 100;
        cfg.retry_backoff_secs = 7.0; // deadlines at 8, 15, 22, 29, 36...
        let mut sim = Simulation::new(nodes(1), reg, cfg).with_backend(Box::new(
            crate::sched::NativeScorer,
        ));
        sim.inject_event(30.0, EventPayload::NodeJoin);
        let report = sim.run_trace(vec![a, bpod]);

        assert_eq!(report.nodes_joined, 1);
        assert_eq!(report.completed(), 2);
        assert!(report.wakeups >= 1, "join must wake the parked pod");
        let bind = report.records.last().unwrap();
        assert_eq!(bind.node, "join001", "only the joined node has room");
        assert_eq!(bind.at, 30.0, "wake-up binds at the join, not at t=36 back-off");
        assert!(bind.download > Bytes::ZERO, "joined node starts with a cold cache");
        assert!(report.accounting_balanced());
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn registry_outage_stalls_inflight_pulls() {
        let run = |outage: bool| {
            let reg = Registry::with_corpus();
            let mut b = crate::cluster::PodBuilder::new();
            let pod = b.build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
            let mut sim = Simulation::new(nodes(1), reg, SimConfig::default());
            if outage {
                sim.inject_event(1.0, EventPayload::RegistryOutageStart { until: 31.0 });
            }
            let report = sim.run_trace(vec![pod]);
            let started_at = sim
                .events
                .all()
                .iter()
                .find(|e| matches!(e.kind, EventKind::Started { .. }))
                .map(|e| e.at)
                .expect("pod started");
            (report, started_at)
        };
        let (base, t_base) = run(false);
        let (stalled, t_stalled) = run(true);
        assert_eq!(base.pulls_stalled, 0);
        assert_eq!(stalled.pulls_stalled, 1);
        assert!(
            (t_stalled - (t_base + 30.0)).abs() < 1e-6,
            "30s outage must delay the start by exactly its remainder: \
             base {t_base}, stalled {t_stalled}"
        );
        assert!(stalled
            .records
            .iter()
            .all(|r| r.download == base.records[0].download));
        assert!(stalled.accounting_balanced());
    }

    #[test]
    fn wakeups_bind_no_later_than_fixed_backoff() {
        // Acceptance regression: on the same trace, capacity-driven
        // wake-ups must bind a retried pod no later than PR 1's fixed
        // back-off timers would.
        let bind_time = |wake: bool| {
            let reg = Registry::with_corpus();
            let mut b = crate::cluster::PodBuilder::new();
            let blocker =
                b.build("redis:7.2", Resources::cores_gb(3.9, 0.5)).with_duration(40.0);
            let waiter = b.build("nginx:1.25", Resources::cores_gb(3.9, 0.5));
            let mut cfg = SimConfig::default();
            cfg.inter_arrival_secs = Some(1.0);
            cfg.retry_limit = 100;
            cfg.retry_backoff_secs = 7.0;
            cfg.wake_on_capacity = wake;
            let mut sim = Simulation::new(nodes(1), reg, cfg);
            let report = sim.run_trace(vec![blocker, waiter]);
            assert_eq!(report.deployed(), 2);
            report.records.last().unwrap().at
        };
        let woken = bind_time(true);
        let timed = bind_time(false);
        assert!(
            woken <= timed + 1e-9,
            "wake-up bound at {woken}, later than fixed back-off at {timed}"
        );
        assert!(woken < timed, "with a 7s back-off the wake-up must win outright");
    }

    #[test]
    fn crashed_nodes_inflight_transfer_releases_uplink() {
        // Regression (ROADMAP churn follow-on): node 0 crashes mid-pull on
        // a capped shared registry uplink. Its resubmitted pod re-pulls on
        // node 1 and must start that transfer at crash time — not behind
        // the dead node's phantom uplink booking.
        let reg = Registry::with_corpus();
        let mut b = crate::cluster::PodBuilder::new();
        // wordpress:6.4 is 243 MB ⇒ 243 s on a 1 MB/s uplink.
        let pod = b.build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
        let mut cfg = SimConfig::default();
        cfg.registry_uplink_mbps = Some(1.0);
        let mut sim = Simulation::new(nodes(2), reg, cfg);
        sim.inject_event(50.0, EventPayload::NodeCrash { node: NodeId(0) });
        let report = sim.run_trace(vec![pod]);

        assert_eq!(report.nodes_crashed, 1);
        assert_eq!(report.resubmitted, 1);
        assert_eq!(report.completed(), 1);
        assert!(report.accounting_balanced());
        let started_at = sim
            .events
            .all()
            .iter()
            .find(|e| matches!(e.kind, EventKind::Started { .. }))
            .map(|e| e.at)
            .expect("pod started");
        // Crash at 50 + full 243 s re-pull = 293; the pre-fix phantom
        // booking would push the restart to t=243 (finish 486).
        assert!(
            (started_at - 293.0).abs() < 1e-6,
            "re-pull queued behind a phantom uplink booking: started at {started_at}"
        );
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn run_arrivals_replays_explicit_offsets() {
        let reg = Registry::with_corpus();
        let mut b = crate::cluster::PodBuilder::new();
        let arrivals = vec![
            (0.0, b.build("redis:7.2", Resources::cores_gb(0.5, 0.5))),
            (0.0, b.build("redis:7.2", Resources::cores_gb(0.5, 0.5))),
            (7.5, b.build("nginx:1.25", Resources::cores_gb(0.5, 0.5)).with_duration(30.0)),
        ];
        let mut sim = Simulation::new(nodes(3), reg, SimConfig::default());
        let report = sim.run_arrivals(arrivals);
        assert_eq!(report.submitted, 3);
        assert_eq!(report.deployed(), 3);
        assert!(report.accounting_balanced());
        // Bursty arrivals land at their trace offsets, not a fixed cadence.
        assert_eq!(report.records[0].at, 0.0);
        assert_eq!(report.records[1].at, 0.0);
        assert_eq!(report.records[2].at, 7.5);
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn churn_model_trace_keeps_accounting_balanced() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(
            &reg,
            WorkloadConfig {
                seed: 11,
                duration_range: Some((20.0, 200.0)),
                ..WorkloadConfig::default()
            },
        )
        .trace(80);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(0.5);
        cfg.gc_enabled = true;
        cfg.retry_limit = 10;
        cfg.churn = Some(crate::sim::workload::ChurnConfig {
            seed: 5,
            horizon_secs: 120.0,
            joins: 2,
            drains: 1,
            crash_fraction: 0.3,
            outages: 1,
            outage_secs: 20.0,
            ..Default::default()
        });
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.submitted, 80);
        assert_eq!(report.nodes_crashed, 1, "30% of 4 nodes rounds to 1 crash");
        assert_eq!(report.nodes_drained, 1);
        assert_eq!(report.nodes_joined, 2);
        assert!(
            report.accounting_balanced(),
            "completed {} + failed {} + unschedulable {} + lost {} != submitted {}",
            report.completed(),
            report.failed_pulls,
            report.unschedulable,
            report.lost_to_crash,
            report.submitted
        );
        sim.state.check_invariants().unwrap();
    }

    fn render_fingerprint(report: &SimReport, sim: &Simulation) -> String {
        format!("{}\n{}", report.render(), sim.events.render())
    }

    #[test]
    fn sharded_run_is_byte_identical_to_sequential() {
        // The acceptance core: the same churny, GC-heavy timed workload
        // through 1, 2, and 3 lanes must produce bit-identical reports and
        // event logs.
        let run = |shards: usize| {
            let reg = Registry::with_corpus();
            let trace = WorkloadGen::new(
                &reg,
                WorkloadConfig {
                    seed: 17,
                    duration_range: Some((15.0, 150.0)),
                    ..WorkloadConfig::default()
                },
            )
            .trace(70);
            let mut cfg = SimConfig::default();
            cfg.inter_arrival_secs = Some(0.4);
            cfg.gc_enabled = true;
            cfg.retry_limit = 8;
            cfg.shards = shards;
            cfg.churn = Some(crate::sim::workload::ChurnConfig {
                seed: 9,
                horizon_secs: 90.0,
                joins: 2,
                drains: 1,
                crash_fraction: 0.25,
                outages: 1,
                outage_secs: 15.0,
                ..Default::default()
            });
            let mut sim = Simulation::new(nodes(5), reg, cfg);
            let report = sim.run_trace(trace);
            sim.state.check_invariants().unwrap();
            assert!(report.accounting_balanced());
            (render_fingerprint(&report, &sim), sim.events_queued())
        };
        let (seq, ev1) = run(1);
        for shards in [2, 3] {
            let (par, evn) = run(shards);
            assert_eq!(ev1, evn, "events-queued count diverged at {shards} shards");
            assert_eq!(seq, par, "shards={shards} diverged from the sequential engine");
        }
    }

    #[test]
    fn sharded_sequential_protocol_uses_fanout_only_and_matches() {
        // Sequential arrival protocol: windows are disabled (arrival
        // chaining makes pull resolutions coordinator events), but the
        // scheduling fan-out still runs — results must be identical.
        let run = |shards: usize| {
            let reg = Registry::with_corpus();
            let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(12);
            let mut cfg = SimConfig::default();
            cfg.shards = shards;
            let mut sim = Simulation::new(nodes(4), reg, cfg);
            let report = sim.run_trace(trace);
            render_fingerprint(&report, &sim)
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn sharded_more_lanes_than_nodes_is_fine() {
        let run = |shards: usize| {
            let reg = Registry::with_corpus();
            let trace = WorkloadGen::new(
                &reg,
                WorkloadConfig {
                    seed: 3,
                    duration_range: Some((10.0, 60.0)),
                    ..WorkloadConfig::default()
                },
            )
            .trace(20);
            let mut cfg = SimConfig::default();
            cfg.inter_arrival_secs = Some(0.5);
            cfg.gc_enabled = true;
            cfg.shards = shards;
            let mut sim = Simulation::new(nodes(2), reg, cfg);
            let report = sim.run_trace(trace);
            sim.state.check_invariants().unwrap();
            render_fingerprint(&report, &sim)
        };
        assert_eq!(run(1), run(6), "empty lanes must not perturb the merge");
    }

    #[test]
    fn accounting_balances_under_churn_and_pressure() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(
            &reg,
            WorkloadConfig {
                seed: 3,
                duration_range: Some((10.0, 120.0)),
                ..WorkloadConfig::default()
            },
        )
        .trace(60);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(0.5);
        cfg.gc_enabled = true;
        let mut sim = Simulation::new(nodes(2), reg, cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.submitted, 60);
        assert!(
            report.accounting_balanced(),
            "completed {} + failed {} + unschedulable {} != submitted {}",
            report.completed(),
            report.failed_pulls,
            report.unschedulable,
            report.submitted
        );
        sim.state.check_invariants().unwrap();
    }
}
