//! Cloud-edge collaborative layer sharing — the paper's §VII future-work
//! item: "explore cloud-edge collaborative layer sharing to reduce
//! container startup time by transferring layers from other edge nodes."
//!
//! When a missing layer is already cached on a *peer* edge node, the
//! kubelet fetches it over the LAN (typically 10–100× faster than the WAN
//! link to the registry) instead of pulling from the registry. The WAN
//! download cost — the paper's headline metric — drops to only the layers
//! no edge node holds.

use crate::cluster::{ClusterState, NodeId};
use crate::registry::LayerId;
use crate::util::units::Bytes;

/// Partition of a node's missing layers by best available source.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SourcePlan {
    /// Layers only the registry can serve (WAN).
    pub registry_layers: Vec<LayerId>,
    /// Total bytes of the registry-served layers.
    pub registry_bytes: Bytes,
    /// Layers available from a peer edge node (LAN), with the peer chosen.
    pub peer_layers: Vec<(LayerId, NodeId)>,
    /// Total bytes served by peers.
    pub peer_bytes: Bytes,
}

/// Decide, per missing layer, whether a peer edge node can serve it.
/// Peers are chosen by lowest node id among holders (deterministic); a
/// smarter policy (least-loaded peer) plugs in here.
pub fn plan_sources(state: &ClusterState, target: NodeId, missing: &[LayerId]) -> SourcePlan {
    let mut plan = SourcePlan::default();
    for &l in missing {
        let peer = state
            .nodes()
            .iter()
            .find(|n| n.id != target && n.layers.contains(l))
            .map(|n| n.id);
        match peer {
            Some(p) => {
                plan.peer_layers.push((l, p));
                plan.peer_bytes += state.interner.size(l);
            }
            None => {
                plan.registry_layers.push(l);
                plan.registry_bytes += state.interner.size(l);
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, Resources};
    use crate::registry::hub;
    use crate::util::units::Bandwidth;

    fn cluster() -> ClusterState {
        let mut s = ClusterState::new();
        for i in 0..3 {
            s.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(30.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        s
    }

    #[test]
    fn peers_serve_cached_layers() {
        let mut state = cluster();
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let httpd = corpus.iter().find(|m| m.name == "httpd").unwrap();
        let (_, wp_layers) = state.intern_image(wp);
        let (_, httpd_layers) = state.intern_image(httpd);
        state.install_image(NodeId(1), &wp.image_ref(), &wp_layers).unwrap();

        // httpd on node 0: debian+ca-certs+apache come from node 1 (LAN),
        // the unique httpd layer from the registry.
        let missing = state.missing_layers(NodeId(0), &httpd_layers);
        let plan = plan_sources(&state, NodeId(0), &missing);
        assert_eq!(plan.peer_layers.len(), 3);
        assert!(plan.peer_layers.iter().all(|(_, p)| *p == NodeId(1)));
        assert_eq!(plan.registry_layers.len(), 1);
        assert_eq!(plan.registry_bytes + plan.peer_bytes, httpd.total_size);
    }

    #[test]
    fn cold_cluster_is_all_registry() {
        let mut state = cluster();
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        let plan = plan_sources(&state, NodeId(0), &ids);
        assert!(plan.peer_layers.is_empty());
        assert_eq!(plan.registry_bytes, layers.total_bytes(&state.interner));
    }

    #[test]
    fn own_cache_never_counts_as_peer() {
        let mut state = cluster();
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        state.install_image(NodeId(0), &redis.image_ref(), &layers).unwrap();
        // Nothing missing on node 0 anyway; force the question for node 1.
        let plan = plan_sources(&state, NodeId(1), &ids);
        assert_eq!(plan.peer_layers.len(), ids.len());
        // And node 0 asking about its own layers: missing is empty.
        assert!(state.missing_layers(NodeId(0), &layers).is_empty());
    }
}
