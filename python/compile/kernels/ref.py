"""Pure-jnp oracle for the scoring pipeline — the correctness reference
for both the Pallas kernel (L1) and the full model (L2), and the mirror
of the rust NativeScorer (`rust/src/sched/scoring.rs`).

Every formula cites the paper:
  Eq. 2   shared bytes          D_c^n(t)
  Eq. 3   layer sharing score   S_layer = D / total * 100
  Eq. 11  balance score         S_STD = |cpu% - mem%| / 2
  Eq. 12  cpu score             S_CPU = cpu%
  Eq. 13  Iverson gate          S_weight
  Eq. 4   combination           S = w * S_layer + S_K8s
  Eq. 5   argmax
"""

import jax.numpy as jnp

# Mask value for infeasible nodes; matches rust NEG_MASK.
NEG_MASK = -1.0e30


def shared_bytes_ref(present, req, sizes):
    """Eq. 2: shared[n] = sum_l present[n,l] * req[l] * sizes[l]."""
    return present.astype(jnp.float32) @ (req * sizes).astype(jnp.float32)


def score_pipeline_ref(
    present,
    req,
    sizes_mb,
    cpu_used,
    cpu_cap,
    mem_used,
    mem_cap,
    k8s_score,
    feasible,
    params,
):
    """Full Algorithm-1 scoring. params = [w1, w2, h_size, h_cpu, h_std].

    Returns (final_score[N], layer_score[N], omega[N], best[int32]).
    """
    w1 = params[0]
    w2 = params[1]
    h_size = params[2]
    h_cpu = params[3]
    h_std = params[4]

    shared = shared_bytes_ref(present, req, sizes_mb)  # (N,) MB
    total = jnp.sum(req * sizes_mb)  # scalar MB
    layer = jnp.where(total > 0.0, shared / jnp.maximum(total, 1e-30) * 100.0, 0.0)

    cpu_frac = cpu_used / jnp.maximum(cpu_cap, 1e-30)  # Eq. 12
    mem_frac = mem_used / jnp.maximum(mem_cap, 1e-30)
    s_std = jnp.abs(cpu_frac - mem_frac) / 2.0  # Eq. 11

    gate = (shared > h_size) & (cpu_frac < h_cpu) & (s_std < h_std)  # Eq. 13
    omega = jnp.where(gate, w1, w2)

    s = omega * layer + k8s_score  # Eq. 4
    final = jnp.where(feasible > 0.5, s, NEG_MASK)
    best = jnp.argmax(final).astype(jnp.int32)  # Eq. 5
    return final, layer, omega, best
