//! The default Kubernetes scheduler plugins the paper enables (§IV-B) plus
//! the paper's PreFilter/Filter capacity constraints (§III-C).

pub mod balanced_allocation;
pub mod capacity;
pub mod image_locality;
pub mod inter_pod_affinity;
pub mod node_affinity;
pub mod node_resources_fit;
pub mod pod_topology_spread;
pub mod taint_toleration;
pub mod volume_binding;

pub use balanced_allocation::BalancedAllocation;
pub use capacity::NodeCapacity;
pub use image_locality::ImageLocality;
pub use inter_pod_affinity::InterPodAffinity;
pub use node_affinity::{NodeAffinityFilter, NodeAffinityScore};
pub use node_resources_fit::{LeastAllocated, NodeResourcesFit};
pub use pod_topology_spread::PodTopologySpread;
pub use taint_toleration::{TaintTolerationFilter, TaintTolerationScore};
pub use volume_binding::{VolumeBindingFilter, VolumeBindingScore};
