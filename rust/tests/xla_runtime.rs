//! Integration: the XLA scoring backend (AOT JAX/Pallas artifact via PJRT)
//! must agree with the pure-rust NativeScorer on the full Algorithm-1
//! pipeline — the cross-language differential test that pins L1+L2 to L3.
//!
//! Requires the `xla` cargo feature (PJRT toolchain) and `make artifacts`;
//! without the feature the whole suite is compiled out, because the
//! default build ships only the stub scorer.
#![cfg(feature = "xla")]

use lrsched::sched::dynamic_weight::WeightParams;
use lrsched::sched::scoring::{NativeScorer, ScoreInputs, ScoringBackend, NEG_MASK};
use lrsched::runtime::XlaScorer;
use lrsched::util::rng::Pcg;

fn artifacts_dir() -> std::path::PathBuf {
    // cargo test runs from the workspace root.
    let p = std::path::PathBuf::from("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` first"
    );
    p
}

fn random_inputs(rng: &mut Pcg, n: usize, l: usize, density: f64) -> ScoreInputs {
    let mut x = ScoreInputs::zeros(n, l, WeightParams::default());
    for v in x.present.iter_mut() {
        *v = if rng.chance(density) { 1.0 } else { 0.0 };
    }
    for j in 0..l {
        x.req[j] = if rng.chance(0.2) { 1.0 } else { 0.0 };
        x.sizes_mb[j] = rng.f64_range(0.1, 300.0) as f32;
    }
    for i in 0..n {
        x.cpu_cap[i] = 4000.0;
        x.mem_cap[i] = 4.0e9;
        x.cpu_used[i] = rng.f64_range(0.0, 4000.0) as f32;
        x.mem_used[i] = rng.f64_range(0.0, 4.0e9) as f32;
        x.k8s_score[i] = rng.f64_range(0.0, 800.0) as f32;
        x.feasible[i] = if rng.chance(0.9) { 1.0 } else { 0.0 };
    }
    // Guarantee at least one feasible node.
    x.feasible[0] = 1.0;
    x
}

fn assert_outputs_match(x: &ScoreInputs, tag: &str, xla: &mut XlaScorer) {
    let native = NativeScorer.score(x);
    let xla_out = xla.score(x);
    for i in 0..x.n_nodes {
        let (a, b) = (native.final_score[i], xla_out.final_score[i]);
        if a <= NEG_MASK / 2.0 || b <= NEG_MASK / 2.0 {
            assert_eq!(a <= NEG_MASK / 2.0, b <= NEG_MASK / 2.0, "{tag}: mask mismatch at {i}");
            continue;
        }
        let tol = 1e-2_f32.max(a.abs() * 1e-4);
        assert!((a - b).abs() < tol, "{tag}: final[{i}] native={a} xla={b}");
        assert_eq!(native.omega[i], xla_out.omega[i], "{tag}: omega[{i}]");
        assert!(
            (native.layer_score[i] - xla_out.layer_score[i]).abs() < 1e-2,
            "{tag}: layer[{i}]"
        );
    }
    // Argmax may legitimately differ only under fp ties; require the scores
    // of the two winners to be equal within tolerance.
    let (nb, xb) = (native.best, xla_out.best);
    let tol = 1e-2_f32.max(native.final_score[nb].abs() * 1e-4);
    assert!(
        (native.final_score[nb] - xla_out.final_score[xb]).abs() < tol,
        "{tag}: winner scores diverge: native[{nb}]={} xla[{xb}]={}",
        native.final_score[nb],
        xla_out.final_score[xb]
    );
}

#[test]
fn xla_loads_both_variants() {
    let scorer = XlaScorer::load(&artifacts_dir()).expect("load artifacts");
    let names = scorer.variant_names();
    assert!(names.contains(&"small") && names.contains(&"large"), "{names:?}");
}

#[test]
fn xla_matches_native_exact_variant_shapes() {
    let mut xla = XlaScorer::load(&artifacts_dir()).unwrap();
    let mut rng = Pcg::seeded(1);
    for (n, l) in [(16, 256), (64, 1024)] {
        for round in 0..5 {
            let x = random_inputs(&mut rng, n, l, 0.3);
            assert_outputs_match(&x, &format!("{n}x{l} round {round}"), &mut xla);
        }
    }
    assert_eq!(xla.stats.executions, 10);
    assert_eq!(xla.stats.native_fallbacks, 0);
}

#[test]
fn xla_pads_smaller_problems() {
    let mut xla = XlaScorer::load(&artifacts_dir()).unwrap();
    let mut rng = Pcg::seeded(2);
    for (n, l) in [(1, 1), (3, 40), (5, 200), (16, 100), (17, 257), (40, 700)] {
        let x = random_inputs(&mut rng, n, l, 0.5);
        assert_outputs_match(&x, &format!("padded {n}x{l}"), &mut xla);
    }
    // 5 fit in small (n<=16 && l<=256), 1 needs large... verify bookkeeping.
    assert_eq!(xla.stats.executions, 6);
    assert_eq!(xla.stats.native_fallbacks, 0);
}

#[test]
fn xla_falls_back_beyond_largest_variant() {
    let mut xla = XlaScorer::load(&artifacts_dir()).unwrap();
    let mut rng = Pcg::seeded(3);
    let x = random_inputs(&mut rng, 65, 1024, 0.3);
    let out = xla.score(&x);
    assert_eq!(xla.stats.native_fallbacks, 1);
    assert_eq!(out, NativeScorer.score(&x));
}

#[test]
fn xla_handles_degenerate_inputs() {
    let mut xla = XlaScorer::load(&artifacts_dir()).unwrap();
    // All-zero req (unknown image): no NaNs, argmax falls to k8s score.
    let mut x = ScoreInputs::zeros(4, 8, WeightParams::default());
    x.feasible = vec![1.0; 4];
    x.k8s_score = vec![10.0, 40.0, 20.0, 30.0];
    let out = xla.score(&x);
    assert_eq!(out.best, 1);
    assert!(out.final_score.iter().all(|s| s.is_finite()));
    // Single feasible node always wins regardless of score.
    let mut x2 = ScoreInputs::zeros(4, 8, WeightParams::default());
    x2.feasible[2] = 1.0;
    assert_eq!(xla.score(&x2).best, 2);
}
