//! Docker-registry substrate: image/layer metadata (paper Listing 1), the
//! in-process registry with `/v2`-shaped endpoints, the `cache.json`
//! metadata cache, the periodic watcher (§V-1), and the synthetic
//! Docker-Hub image corpus that substitutes for the paper's private
//! registry content.

pub mod cache;
pub mod catalog;
pub mod hub;
pub mod image;
pub mod layer;
pub mod watcher;

pub use cache::MetadataCache;
pub use catalog::{Registry, RegistryError};
pub use image::{ImageMetadata, ImageRef};
pub use layer::{LayerId, LayerInterner, LayerMetadata, LayerSet};
pub use watcher::Watcher;
