//! Kubelet analog — the node agent. After the scheduler binds a pod, the
//! kubelet pulls the missing layers (via [`PullManager`]), installs the
//! image, and starts the container. Also implements image GC: under disk
//! pressure it evicts layers not referenced by any image of a running pod
//! (the paper's Fig. 3d counts deployable containers *without* eviction,
//! so GC is off by default and exercised by the failure-injection tests).

use super::cache::{self, CachePolicyChoice, VictimCtx};
use super::download::{PullManager, PullPlan};
use super::bandwidth::LinkModel;
use super::p2p::Swarm;
use crate::cluster::{ClusterState, Node, NodeId, Pod, PodId};
use crate::registry::{ImageRef, LayerId, LayerInterner, LayerSet};
use crate::util::units::Bytes;
use std::collections::{BTreeMap, HashMap};

/// A pod whose layers are being pulled; the container starts at `ready_at`.
#[derive(Debug, Clone)]
pub struct PendingStart {
    /// The pod being started.
    pub pod: PodId,
    /// Node it is bound to.
    pub node: NodeId,
    /// Image being pulled.
    pub image: ImageRef,
    /// Full layer set the image requires.
    pub layers: LayerSet,
    /// Transfer plan for the missing layers.
    pub plan: PullPlan,
    /// Bytes pulled from the registry over the WAN (the paper's cost).
    pub wan_bytes: Bytes,
    /// Bytes fetched from peer edge nodes over the LAN (§VII extension).
    pub p2p_bytes: Bytes,
    /// Number of layers served by peer seeders.
    pub p2p_layers: usize,
}

/// Image → layer-set store so GC can resolve an image's layers without
/// reaching back to the registry (containerd's image store, per kubelet).
///
/// One store per [`super::Simulation`]: the seed kept this in a
/// process-wide `thread_local!`, which leaked image→layer mappings across
/// simulations (and across tests sharing a thread).
#[derive(Debug, Clone, Default)]
pub struct ImageLayerStore {
    map: HashMap<String, LayerSet>,
}

impl ImageLayerStore {
    /// An empty store.
    pub fn new() -> ImageLayerStore {
        ImageLayerStore::default()
    }

    /// Record an image's layer set (called at install time by the engine).
    pub fn remember(&mut self, image: &ImageRef, layers: &LayerSet) {
        self.map.insert(image.key(), layers.clone());
    }

    /// Layer set of a remembered image.
    pub fn layers(&self, image: &ImageRef) -> Option<&LayerSet> {
        self.map.get(&image.key())
    }

    /// Remembered images.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Is the store empty?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Begin the pull for a freshly bound pod. With a [`Swarm`] view, layers
/// cached on peer edge nodes transfer over the LAN instead of the WAN
/// registry link (cloud-edge collaborative layer sharing, paper §VII):
/// the missing set is first deduped against every in-flight arrival on
/// this node (WAN *and* peer), the fresh layers are split between peer
/// seeders and the registry by [`super::p2p::plan_sources`] (which books
/// the LAN edges), and only the registry share goes through
/// [`PullManager::plan`] — a pull whose layers are all peer-served books
/// **nothing** on the WAN link, leaving `plan.bytes` zero, which is what
/// exempts it from registry-outage stalls in the engine.
pub fn begin_pull(
    state: &ClusterState,
    pulls: &mut PullManager,
    links: &mut LinkModel,
    now: f64,
    pod: PodId,
    node: NodeId,
    image: &ImageRef,
    required: &LayerSet,
    p2p: Option<&Swarm<'_>>,
) -> PendingStart {
    let missing = state.missing_layers(node, required);
    let (pending_plan, wan_bytes, p2p_bytes, p2p_layers) = match p2p {
        None => {
            let bytes: Bytes = missing.iter().map(|&l| state.interner.size(l)).sum();
            let plan = pulls.plan(node.0 as usize, &missing, &state.interner, links, now);
            (plan, bytes, Bytes::ZERO, 0)
        }
        Some(swarm) => {
            let (fresh, wait) = pulls.split_wait(node.0 as usize, &missing, now);
            let sources = super::p2p::plan_sources(
                state,
                swarm.index,
                links,
                swarm.lan_bw,
                swarm.seeder_cap,
                node,
                &fresh,
                now,
            );
            let mut plan =
                pulls.plan(node.0 as usize, &sources.registry_layers, &state.interner, links, now);
            for &(l, _, finish) in &sources.peer_layers {
                pulls.note_peer(node.0 as usize, l, finish);
            }
            plan.ready_at = plan.ready_at.max(wait).max(sources.peer_finish);
            (plan, sources.registry_bytes, sources.peer_bytes, sources.peer_layers.len())
        }
    };
    PendingStart {
        pod,
        node,
        image: image.clone(),
        layers: required.clone(),
        plan: pending_plan,
        wan_bytes,
        p2p_bytes,
        p2p_layers,
    }
}

/// Complete a pull: install the image (charges disk) — call when the clock
/// reaches `plan.ready_at`. Returns bytes actually added to the node disk.
pub fn complete_pull(state: &mut ClusterState, pending: &PendingStart) -> Result<Bytes, crate::cluster::StateError> {
    state.install_image(pending.node, &pending.image, &pending.layers)
}

/// Read access to the image → layer-set memo, abstracted so kubelet GC
/// runs identically against the simulation-wide [`ImageLayerStore`]
/// (sequential engine) and a lane-local [`OverlayImages`] view (sharded
/// engine, where same-window installs are buffered per lane).
pub trait ImageLayersSource {
    /// Layer set of a remembered image, if known.
    fn layers_of(&self, image: &ImageRef) -> Option<&LayerSet>;
}

impl ImageLayersSource for ImageLayerStore {
    fn layers_of(&self, image: &ImageRef) -> Option<&LayerSet> {
        self.layers(image)
    }
}

/// An [`ImageLayersSource`] that checks a lane's not-yet-merged installs
/// before the shared store. Every image cached on a node was installed by
/// a pull *on that node* (same lane), so base + own-lane overlay always
/// reproduces the sequential engine's view (entries are idempotent: an
/// image key always maps to the same layer set).
pub struct OverlayImages<'a> {
    base: &'a ImageLayerStore,
    overlay: &'a [(ImageRef, LayerSet)],
}

impl<'a> OverlayImages<'a> {
    /// View `overlay` (this lane's window-local installs) over `base`.
    pub fn new(base: &'a ImageLayerStore, overlay: &'a [(ImageRef, LayerSet)]) -> OverlayImages<'a> {
        OverlayImages { base, overlay }
    }
}

impl ImageLayersSource for OverlayImages<'_> {
    fn layers_of(&self, image: &ImageRef) -> Option<&LayerSet> {
        self.overlay
            .iter()
            .rev()
            .find(|(i, _)| i == image)
            .map(|(_, s)| s)
            .or_else(|| self.base.layers(image))
    }
}

/// Image GC against one node directly — the body of [`gc_images`], split
/// out so the sharded engine's lanes (which own `&mut Node` slices and a
/// read view of the pod table) evict exactly as the sequential engine
/// does. Evicts images (and their now-unreferenced layers) that no
/// running pod uses until `free_target` bytes are free; the victim order
/// is the [`CachePolicyChoice`]'s (`policy`): the default `PressureSweep`
/// keeps the original oldest-first insertion order, the others score
/// candidates against the node's [`crate::cluster::LayerUse`] metadata at
/// virtual time `now` (`decay` is the popularity time constant). Under
/// the prefetch policy a final pass reclaims *orphan* layers — layers
/// referenced by no cached image and no in-use image (only prefetching
/// creates those), lowest layer id first. Returns bytes freed.
pub fn gc_images_node(
    node: &mut Node,
    pods: &BTreeMap<PodId, Pod>,
    interner: &LayerInterner,
    images: &dyn ImageLayersSource,
    free_target: Bytes,
    policy: CachePolicyChoice,
    decay: f64,
    now: f64,
) -> Bytes {
    let pol = policy.policy();
    let mut freed = Bytes::ZERO;
    loop {
        if node.disk_free() >= free_target {
            break;
        }
        // Images required by running pods on this node.
        let in_use: Vec<ImageRef> = node
            .pods
            .iter()
            .filter_map(|p| pods.get(p))
            .map(|p| p.image.clone())
            .collect();
        // Eviction candidates: cached images not in use, in insertion
        // order (the PressureSweep order, and the tie-break of last
        // resort for every other policy).
        let candidates: Vec<ImageRef> =
            node.images.iter().filter(|img| !in_use.contains(img)).cloned().collect();
        if candidates.is_empty() {
            break; // everything in use; cannot free more
        }
        let empty = LayerSet::new();
        let sets: Vec<&LayerSet> =
            candidates.iter().map(|img| images.layers_of(img).unwrap_or(&empty)).collect();
        // The keep set per candidate (union of every *other* cached
        // image's layers) is only consulted by the scorer-informed
        // policy; skip the quadratic build otherwise.
        let others: Vec<LayerSet> = if policy == CachePolicyChoice::ScorerKeepSet {
            candidates
                .iter()
                .map(|victim| {
                    let mut keep = LayerSet::new();
                    for other in &node.images {
                        if other == victim {
                            continue;
                        }
                        if let Some(set) = images.layers_of(other) {
                            keep.union_with(set);
                        }
                    }
                    keep
                })
                .collect()
        } else {
            vec![LayerSet::new(); candidates.len()]
        };
        let ctxs: Vec<VictimCtx<'_>> = (0..candidates.len())
            .map(|i| VictimCtx {
                layers: sets[i],
                others: &others[i],
                meta: &node.cache_meta,
                interner,
                now,
                decay,
            })
            .collect();
        let victim = match cache::select_victim(pol, &ctxs) {
            Some(i) => candidates[i].clone(),
            None => break,
        };
        drop(ctxs);
        // Layers of the victim that are not shared with any other cached
        // image on this node, resolved through the per-simulation image
        // store (the node only tracks the union of its layers).
        let mut shared_with_others = LayerSet::new();
        for other in node.images.clone() {
            if other == victim {
                continue;
            }
            if let Some(set) = images.layers_of(&other) {
                shared_with_others.union_with(set);
            }
        }
        if let Some(victim_layers) = images.layers_of(&victim) {
            let unique: Vec<_> = victim_layers.difference_ids(&shared_with_others);
            freed += crate::cluster::evict_layers_on(node, interner, &unique);
        }
        node.images.retain(|i| i != &victim);
    }
    if pol.sweeps_orphans() && node.disk_free() < free_target {
        // Orphan pass: prefetched layers never claimed by an installed
        // image (and not part of any in-use image, which may still be
        // mid-pull) are reclaimable, lowest layer id first.
        let mut covered = LayerSet::new();
        for img in &node.images {
            if let Some(set) = images.layers_of(img) {
                covered.union_with(set);
            }
        }
        for p in &node.pods {
            if let Some(pod) = pods.get(p) {
                if let Some(set) = images.layers_of(&pod.image) {
                    covered.union_with(set);
                }
            }
        }
        let orphans: Vec<LayerId> = node.layers.difference_ids(&covered);
        for l in orphans {
            if node.disk_free() >= free_target {
                break;
            }
            freed += crate::cluster::evict_layers_on(node, interner, &[l]);
        }
    }
    freed
}

/// Image GC: evict images (and their now-unreferenced layers) that no
/// running pod uses, in the `policy`'s victim order, until `free_target`
/// bytes are free. Returns bytes freed. (Delegates to
/// [`gc_images_node`].)
pub fn gc_images(
    state: &mut ClusterState,
    images: &ImageLayerStore,
    node: NodeId,
    free_target: Bytes,
    policy: CachePolicyChoice,
    decay: f64,
    now: f64,
) -> Bytes {
    let (nodes, pods, interner) = state.lane_split();
    gc_images_node(&mut nodes[node.0 as usize], pods, interner, images, free_target, policy, decay, now)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, PodBuilder, Resources};
    use crate::registry::hub;
    use crate::util::units::Bandwidth;

    fn setup() -> (ClusterState, PullManager, LinkModel) {
        let mut state = ClusterState::new();
        state.add_node(Node::new(
            NodeId(0),
            "n0",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(1.0),
            Bandwidth::from_mbps(10.0),
        ));
        let pulls = PullManager::new(1);
        let links = LinkModel::new(vec![Bandwidth::from_mbps(10.0)]);
        (state, pulls, links)
    }

    #[test]
    fn pull_then_install() {
        let (mut state, mut pulls, mut links) = setup();
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (_, layers) = state.intern_image(redis);
        let pending = begin_pull(
            &state, &mut pulls, &mut links, 0.0,
            PodId(0), NodeId(0), &redis.image_ref(), &layers, None,
        );
        // redis:7.2 = 64.4 MB at 10 MB/s → 6.44 s.
        assert!((pending.plan.ready_at - redis.total_size.as_mb() / 10.0).abs() < 1e-6);
        let added = complete_pull(&mut state, &pending).unwrap();
        assert_eq!(added, redis.total_size);
        assert!(state.node(NodeId(0)).has_image(&redis.image_ref()));
        state.check_invariants().unwrap();
    }

    #[test]
    fn warm_node_starts_instantly() {
        let (mut state, mut pulls, mut links) = setup();
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (_, layers) = state.intern_image(redis);
        state.install_image(NodeId(0), &redis.image_ref(), &layers).unwrap();
        let pending = begin_pull(
            &state, &mut pulls, &mut links, 5.0,
            PodId(1), NodeId(0), &redis.image_ref(), &layers, None,
        );
        assert_eq!(pending.plan.bytes, Bytes::ZERO);
        assert_eq!(pending.plan.ready_at, 5.0);
    }

    #[test]
    fn peer_only_pull_never_touches_the_wan_link() {
        // Regression: the old p2p path always called PullManager::plan on
        // the (possibly empty) WAN share and never booked the LAN at all.
        use crate::sim::p2p::{Swarm, SwarmIndex};
        let mut state = ClusterState::new();
        for i in 0..2 {
            state.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(30.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (_, layers) = state.intern_image(redis);
        state.install_image(NodeId(1), &redis.image_ref(), &layers).unwrap();
        let mut index = SwarmIndex::new();
        index.mark_dirty(NodeId(1));
        index.sync(&state);
        let swarm = Swarm { index: &index, lan_bw: Bandwidth::from_mbps(100.0), seeder_cap: 4 };
        let mut pulls = PullManager::new(2);
        let mut links = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);

        let pending = begin_pull(
            &state, &mut pulls, &mut links, 0.0,
            PodId(0), NodeId(0), &redis.image_ref(), &layers, Some(&swarm),
        );
        assert_eq!(pending.wan_bytes, Bytes::ZERO);
        assert_eq!(pending.p2p_bytes, redis.total_size);
        assert_eq!(pending.plan.bytes, Bytes::ZERO, "no WAN transfer planned");
        assert!(pending.plan.new_layers.is_empty());
        // 64.4 MB over the 100 MB/s LAN → ready 6.44s / 10 = 0.644s.
        assert!((pending.plan.ready_at - redis.total_size.as_mb() / 100.0).abs() < 1e-6);
        // The WAN downlink was never booked: a registry pull starts now.
        let (s, _) = links.schedule_transfer(0, Bytes::from_mb(10.0), 0.1);
        assert_eq!(s, 0.1, "WAN link untouched by the peer-only pull");
        assert_eq!(links.peak_peer_uploads(), redis.layers.len().min(4));

        // A same-node follower waits on the in-flight peer fetches instead
        // of re-planning them.
        let follow = begin_pull(
            &state, &mut pulls, &mut links, 0.1,
            PodId(1), NodeId(0), &redis.image_ref(), &layers, Some(&swarm),
        );
        assert_eq!(follow.p2p_bytes, Bytes::ZERO);
        assert_eq!(follow.wan_bytes, Bytes::ZERO);
        assert_eq!(follow.plan.ready_at, pending.plan.ready_at);
    }

    #[test]
    fn image_store_is_instance_scoped() {
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let mut state = ClusterState::new();
        let (_, layers) = state.intern_image(redis);
        let mut a = ImageLayerStore::new();
        a.remember(&redis.image_ref(), &layers);
        assert!(a.layers(&redis.image_ref()).is_some());
        // A second store starts empty: no cross-instance leakage.
        let b = ImageLayerStore::new();
        assert!(b.is_empty());
        assert!(b.layers(&redis.image_ref()).is_none());
    }

    #[test]
    fn gc_evicts_unused_images_only() {
        let (mut state, _, _) = setup();
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let nginx = corpus.iter().find(|m| m.name == "nginx").unwrap();
        let (_, rl) = state.intern_image(redis);
        let (_, nl) = state.intern_image(nginx);
        state.install_image(NodeId(0), &redis.image_ref(), &rl).unwrap();
        state.install_image(NodeId(0), &nginx.image_ref(), &nl).unwrap();
        let mut images = ImageLayerStore::new();
        images.remember(&redis.image_ref(), &rl);
        images.remember(&nginx.image_ref(), &nl);
        // nginx is in use by a running pod; redis is idle.
        let mut b = PodBuilder::new();
        let pod = b.build("nginx:1.25", Resources::cores_gb(0.1, 0.1));
        let pid = state.submit_pod(pod);
        state.bind(pid, NodeId(0)).unwrap();

        let before = state.node(NodeId(0)).disk_used;
        let freed = gc_images(
            &mut state,
            &images,
            NodeId(0),
            Bytes::from_gb(1.0),
            CachePolicyChoice::PressureSweep,
            300.0,
            0.0,
        );
        assert!(freed > Bytes::ZERO);
        assert!(state.node(NodeId(0)).disk_used < before);
        assert!(!state.node(NodeId(0)).has_image(&redis.image_ref()));
        assert!(state.node(NodeId(0)).has_image(&nginx.image_ref()));
        // Shared layers (debian base + ca-certs) survive because nginx
        // still references them.
        let shared_base = state.interner.lookup(&hub::digest_for("os.debian12")).unwrap();
        assert!(state.node(NodeId(0)).layers.contains(shared_base));
        state.check_invariants().unwrap();
    }
}
