//! Export experiment results as JSON/CSV for external plotting — the
//! figures in the paper are plots; this module emits the exact series the
//! drivers compute so they can be re-rendered with any toolchain.

use super::{fig3::Fig3, fig4::Fig4, fig5::Fig5, table1::Table1};
use crate::sim::{DecisionDetail, SimReport};
use crate::util::json::Json;

/// A full simulation report as JSON (per-pod records + totals).
pub fn report_to_json(rep: &SimReport) -> Json {
    let mut o = Json::obj();
    o.set("scheduler", Json::Str(rep.scheduler.to_string()))
        .set("submitted", Json::Int(rep.submitted as i64))
        .set("deployed", Json::Int(rep.deployed() as i64))
        .set("completed", Json::Int(rep.completed() as i64))
        .set("unschedulable", Json::Int(rep.unschedulable as i64))
        .set("failed_pulls", Json::Int(rep.failed_pulls as i64))
        .set("retries", Json::Int(rep.retries as i64))
        .set("total_download_mb", Json::Num(rep.total_download().as_mb()))
        .set("total_p2p_mb", Json::Num(rep.total_p2p().as_mb()))
        .set("peak_peer_uploads", Json::Int(rep.peak_peer_uploads as i64))
        .set("total_download_secs", Json::Num(rep.total_download_secs()))
        .set("final_std", Json::Num(rep.final_std()))
        .set("omega1_used", Json::Int(rep.omega1_used as i64))
        .set("omega2_used", Json::Int(rep.omega2_used as i64))
        .set("omega_mid_used", Json::Int(rep.omega_mid_used as i64))
        .set("cache_hit_rate", Json::Num(rep.cache_hit_rate))
        .set("evicted_mb", Json::Num(rep.evicted_bytes.as_mb()))
        .set("prefetched_mb", Json::Num(rep.prefetched_bytes.as_mb()))
        .set(
            "records",
            Json::Arr(
                rep.records
                    .iter()
                    .map(|r| {
                        let mut e = Json::obj();
                        e.set("pod", Json::Int(r.pod.0 as i64))
                            .set("image", Json::Str(r.image.clone()))
                            .set("node", Json::Str(r.node.clone()))
                            .set("download_mb", Json::Num(r.download.as_mb()))
                            .set("p2p_mb", Json::Num(r.p2p.as_mb()))
                            .set("download_secs", Json::Num(r.download_secs))
                            .set("std_after", Json::Num(r.std_after))
                            .set("omega", Json::Num(r.omega))
                            .set("layer_score", Json::Num(r.layer_score));
                        e
                    })
                    .collect(),
            ),
        );
    o
}

/// One `lrsched serve` binding decision as the NDJSON object the
/// protocol emits (`docs/SERVE.md`, "Decision lines"). Keys serialize in
/// sorted order ([`Json::Obj`] is a `BTreeMap`) and floats use the
/// shortest round-trip form, so the same [`DecisionDetail`] always
/// renders to the same bytes — the property the `--shadow` differential
/// and the CI golden diff rest on. `latency_us` is the only field not
/// derived from the deterministic engine; shadow runs pin it to 0.
pub fn decision_to_json(d: &DecisionDetail, latency_us: u64) -> Json {
    let mut o = Json::obj();
    o.set("type", Json::Str("decision".into()))
        .set("t", Json::Num(d.at))
        .set("pod", Json::Int(d.pod.0 as i64))
        .set("pod_name", Json::Str(d.pod_name.clone()))
        .set("image", Json::Str(d.image.clone()))
        .set("node", Json::Str(d.node_name.clone()))
        .set("node_id", Json::Int(d.node.0 as i64))
        .set("final_score", Json::Num(d.final_score))
        .set("layer_score", Json::Num(d.layer_score))
        .set("k8s_score", Json::Num(d.k8s_score))
        .set("omega", Json::Num(d.omega))
        .set(
            "breakdown",
            Json::Arr(
                d.breakdown
                    .iter()
                    .map(|(plugin, score)| {
                        let mut e = Json::obj();
                        e.set("plugin", Json::Str((*plugin).to_string()))
                            .set("score", Json::Num(*score));
                        e
                    })
                    .collect(),
            ),
        )
        .set("wan_bytes", Json::Int(d.wan_bytes.0 as i64))
        .set("p2p_bytes", Json::Int(d.p2p_bytes.0 as i64))
        .set("est_secs", Json::Num(d.est_secs))
        .set("latency_us", Json::Int(latency_us as i64));
    o
}

/// The end-of-session summary line `lrsched serve` emits after EOF or a
/// `shutdown` event (`docs/SERVE.md`, "Summary line"). `decisions` and
/// `skipped_lines` come from the session codec (the report cannot know
/// how many protocol lines were dropped in lenient mode); everything
/// else is the same accounting the `scale` harness prints.
pub fn serve_summary_to_json(
    rep: &SimReport,
    decisions: usize,
    skipped_lines: usize,
    virtual_secs: f64,
) -> Json {
    let mut o = Json::obj();
    o.set("type", Json::Str("summary".into()))
        .set("submitted", Json::Int(rep.submitted as i64))
        .set("started", Json::Int(rep.started as i64))
        .set("failed_pulls", Json::Int(rep.failed_pulls as i64))
        .set("unschedulable", Json::Int(rep.unschedulable as i64))
        .set("lost_to_crash", Json::Int(rep.lost_to_crash as i64))
        .set("retries", Json::Int(rep.retries as i64))
        .set("wakeups", Json::Int(rep.wakeups as i64))
        .set("decisions", Json::Int(decisions as i64))
        .set("skipped_lines", Json::Int(skipped_lines as i64))
        .set("wan_bytes", Json::Int(rep.total_download().0 as i64))
        .set("p2p_bytes", Json::Int(rep.total_p2p().0 as i64))
        .set("cache_hit_rate", Json::Num(rep.cache_hit_rate))
        .set("virtual_secs", Json::Num(virtual_secs));
    o
}

/// Fig. 3 cells as a JSON document for external plotting.
pub fn fig3_to_json(fig: &Fig3) -> Json {
    let mut o = Json::obj();
    o.set("figure", Json::Str("fig3".into())).set(
        "cells",
        Json::Arr(
            fig.cells
                .iter()
                .map(|c| {
                    let mut e = Json::obj();
                    e.set("nodes", Json::Int(c.n_nodes as i64))
                        .set("scheduler", Json::Str(c.scheduler.to_string()))
                        .set("cpu_util", Json::Num(c.cpu_util))
                        .set("disk_mb", Json::Num(c.disk_mb))
                        .set("mem_util", Json::Num(c.mem_util))
                        .set("max_containers", Json::Int(c.max_containers as i64))
                        .set("download_mb", Json::Num(c.download_mb))
                        .set("omega1_used", Json::Int(c.omega1_used as i64))
                        .set("omega2_used", Json::Int(c.omega2_used as i64));
                    e
                })
                .collect(),
        ),
    );
    o
}

/// Fig. 4 series as a JSON document for external plotting.
pub fn fig4_to_json(fig: &Fig4) -> Json {
    let mut o = Json::obj();
    o.set("figure", Json::Str("fig4".into())).set(
        "bandwidths_mbps",
        Json::Arr(fig.bandwidths_mbps.iter().map(|&b| Json::Num(b)).collect()),
    );
    let mut series = Json::obj();
    for (name, vals) in &fig.secs {
        series.set(name, Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect()));
    }
    o.set("download_secs", series);
    o
}

/// Fig. 5 series as a JSON document for external plotting.
pub fn fig5_to_json(fig: &Fig5) -> Json {
    let mut o = Json::obj();
    o.set("figure", Json::Str("fig5".into()));
    let mut series = Json::obj();
    for (name, vals) in &fig.cumulative_mb {
        series.set(name, Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect()));
    }
    o.set("cumulative_mb", series);
    o
}

/// Table I as CSV (one row per container × scheduler).
pub fn table1_to_csv(t: &Table1) -> String {
    let mut out = String::from("container,scheduler,image,node,download_mb,secs,std\n");
    for r in &t.rows {
        out.push_str(&format!(
            "{},{},{},{},{:.1},{:.1},{:.4}\n",
            r.container,
            r.scheduler,
            r.image,
            r.node,
            r.download.as_mb(),
            r.secs,
            r.std
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::{common, fig4, fig5, table1};
    use crate::util::json;

    #[test]
    fn report_json_roundtrips() {
        let trace = common::paper_trace(5, 5);
        let rep = common::run_all(3, &trace, |_| {}).remove(2);
        let j = report_to_json(&rep);
        let parsed = json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("scheduler").unwrap().as_str(), Some("LRScheduler"));
        assert_eq!(parsed.get("records").unwrap().as_arr().unwrap().len(), 5);
        assert!(parsed.get("total_download_mb").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn fig_exports_parse_back() {
        let f4 = fig4::run(5, 5, 3);
        let j = json::parse(&fig4_to_json(&f4).to_string()).unwrap();
        assert_eq!(
            j.get("bandwidths_mbps").unwrap().as_arr().unwrap().len(),
            fig4::BANDWIDTHS_MBPS.len()
        );
        let f5 = fig5::run(5, 5, 3);
        let j5 = json::parse(&fig5_to_json(&f5).to_string()).unwrap();
        assert!(j5.get("cumulative_mb").unwrap().get("Default").is_some());
    }

    #[test]
    fn table1_csv_has_all_rows() {
        let t = table1::run(5, 4, 3);
        let csv = table1_to_csv(&t);
        assert_eq!(csv.lines().count(), 1 + 12); // header + 4 pods × 3 scheds
        assert!(csv.starts_with("container,scheduler"));
    }
}
