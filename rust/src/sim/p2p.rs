//! Peer-swarm layer sharing — the paper's §VII future-work item: "explore
//! cloud-edge collaborative layer sharing to reduce container startup time
//! by transferring layers from other edge nodes" (EdgePier-style).
//!
//! When a missing layer is already cached on a *peer* edge node, the
//! kubelet fetches it over the LAN (typically 10–100× faster than the WAN
//! link to the registry) instead of pulling from the registry. The WAN
//! download cost — the paper's headline metric — drops to only the layers
//! no edge node holds, and a registry outage becomes survivable whenever
//! the swarm holds every missing layer.
//!
//! Two pieces:
//! - [`SwarmIndex`]: a deterministic layer → holders index, kept in sync
//!   with node layer inventories through the `layers_version` counter each
//!   node bumps on membership change. The engine marks nodes dirty when
//!   their inventory may have changed (pull completed, GC evicted, crash,
//!   join) and [`SwarmIndex::sync`] re-diffs only those — replacing the
//!   old O(nodes × missing) full-cluster scan per pull.
//! - [`plan_sources`]: partitions a pull's missing layers between the
//!   registry (WAN) and peer seeders (LAN), picking for each layer the
//!   least-loaded Ready holder under the per-seeder concurrent-upload cap
//!   (ties by node id), and *booking* every peer fetch on both the
//!   downloader's and the seeder's LAN edges as it selects — so later
//!   layers in the same plan see the load they themselves created, and a
//!   saturated swarm falls back to the registry naturally.

use crate::cluster::{ClusterState, NodeId};
use crate::registry::{LayerId, LayerSet};
use crate::sim::bandwidth::LinkModel;
use crate::util::units::{Bandwidth, Bytes};

/// Deterministic layer → holders index over the fleet's layer caches.
///
/// Holder lists are kept sorted by node id, and per-node snapshots are
/// diffed lazily against `Node::layers_version` — syncing is cheap when
/// nothing changed and O(changed layers) when something did. All state is
/// coordinator-side: the sharded engine's lanes never touch it, so plans
/// (and therefore reports) are byte-identical at every shard count.
#[derive(Debug, Clone, Default)]
pub struct SwarmIndex {
    /// Holder node ids per dense layer id, each list sorted ascending.
    holders: Vec<Vec<NodeId>>,
    /// Per-node `(layers_version, layer snapshot)` as of the last sync.
    indexed: Vec<(u64, LayerSet)>,
    /// Nodes whose inventory may have drifted since their last sync.
    dirty: Vec<u32>,
}

impl SwarmIndex {
    /// An empty index (every node cold).
    pub fn new() -> SwarmIndex {
        SwarmIndex::default()
    }

    /// Record that `node`'s layer inventory may have changed (pull
    /// completed, GC evicted, crash wiped, node joined). Cheap and
    /// idempotent; the actual diff happens in [`SwarmIndex::sync`].
    pub fn mark_dirty(&mut self, node: NodeId) {
        if !self.dirty.contains(&node.0) {
            self.dirty.push(node.0);
        }
    }

    /// Re-index every dirty node whose `layers_version` actually moved,
    /// diffing its snapshot against the live layer set. Sorted-position
    /// insertion keeps each holder list ordered by node id regardless of
    /// the order dirty nodes are processed in — the index is a pure
    /// function of the fleet's inventories.
    pub fn sync(&mut self, state: &ClusterState) {
        // Nodes added since the last sync (joins, or the initial
        // population on the first call) are implicitly dirty.
        for i in self.indexed.len()..state.node_count() {
            self.mark_dirty(NodeId(i as u32));
        }
        if self.dirty.is_empty() {
            return;
        }
        let dirty = std::mem::take(&mut self.dirty);
        for id in dirty {
            let idx = id as usize;
            if idx >= state.node_count() {
                continue;
            }
            if self.indexed.len() <= idx {
                self.indexed.resize(idx + 1, (0, LayerSet::new()));
            }
            let node = state.node(NodeId(id));
            let (seen_version, snapshot) = &self.indexed[idx];
            if *seen_version == node.layers_version && snapshot.len() == node.layers.len() {
                continue;
            }
            for l in node.layers.difference_ids(snapshot) {
                let slot = l.0 as usize;
                if self.holders.len() <= slot {
                    self.holders.resize(slot + 1, Vec::new());
                }
                let list = &mut self.holders[slot];
                if let Err(pos) = list.binary_search(&NodeId(id)) {
                    list.insert(pos, NodeId(id));
                }
            }
            for l in snapshot.difference_ids(&node.layers) {
                if let Some(list) = self.holders.get_mut(l.0 as usize) {
                    list.retain(|&n| n != NodeId(id));
                }
            }
            self.indexed[idx] = (node.layers_version, node.layers.clone());
        }
    }

    /// Nodes currently advertising `layer`, ascending by node id.
    pub fn holders(&self, layer: LayerId) -> &[NodeId] {
        self.holders.get(layer.0 as usize).map(Vec::as_slice).unwrap_or(&[])
    }
}

/// Borrowed view of the peer swarm the kubelet consults when planning a
/// pull — the holder index plus the engine's LAN/cap knobs.
#[derive(Debug)]
pub struct Swarm<'a> {
    /// The layer → holders index (synced by the engine before planning).
    pub index: &'a SwarmIndex,
    /// LAN bandwidth peer fetches transfer at.
    pub lan_bw: Bandwidth,
    /// Max concurrent uploads a single seeder serves.
    pub seeder_cap: usize,
}

/// Partition of a node's missing layers by best available source.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SourcePlan {
    /// Layers only the registry can serve (WAN).
    pub registry_layers: Vec<LayerId>,
    /// Total bytes of the registry-served layers.
    pub registry_bytes: Bytes,
    /// Peer-served layers: `(layer, seeder, LAN transfer finish time)`.
    pub peer_layers: Vec<(LayerId, NodeId, f64)>,
    /// Total bytes served by peers.
    pub peer_bytes: Bytes,
    /// Time the last peer fetch lands (0 when nothing is peer-served).
    pub peer_finish: f64,
}

/// Decide, per missing layer, whether a peer edge node can serve it, and
/// book every chosen peer fetch on the topology ledger.
///
/// Seeder choice per layer: among the layer's holders, skip the target
/// itself, non-Ready nodes (a Draining node is about to leave — it must
/// never be the sole source), and seeders already at `seeder_cap`
/// concurrent uploads; of the rest take the least-loaded, ties broken by
/// lowest node id (holder lists are id-sorted and the comparison is
/// strict). Layers with no eligible seeder fall back to the registry.
#[allow(clippy::too_many_arguments)]
pub fn plan_sources(
    state: &ClusterState,
    index: &SwarmIndex,
    links: &mut LinkModel,
    lan_bw: Bandwidth,
    seeder_cap: usize,
    target: NodeId,
    missing: &[LayerId],
    now: f64,
) -> SourcePlan {
    let mut plan = SourcePlan::default();
    for &l in missing {
        let mut best: Option<(usize, NodeId)> = None;
        for &holder in index.holders(l) {
            if holder == target || !state.node(holder).is_schedulable() {
                continue;
            }
            let load = links.active_uploads(holder.0 as usize, now);
            if load >= seeder_cap {
                continue;
            }
            // Strict `<` + id-ascending iteration = ties go to the lowest id.
            if best.map_or(true, |(b, _)| load < b) {
                best = Some((load, holder));
            }
        }
        let size = state.interner.size(l);
        match best {
            Some((_, seeder)) => {
                let (_, finish) = links.schedule_peer_transfer(
                    target.0 as usize,
                    seeder.0 as usize,
                    size,
                    lan_bw,
                    now,
                );
                plan.peer_layers.push((l, seeder, finish));
                plan.peer_bytes += size;
                plan.peer_finish = plan.peer_finish.max(finish);
            }
            None => {
                plan.registry_layers.push(l);
                plan.registry_bytes += size;
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, Resources};
    use crate::registry::hub;
    use crate::util::units::Bandwidth;

    const CAP: usize = 4;

    fn lan() -> Bandwidth {
        Bandwidth::from_mbps(100.0)
    }

    fn cluster(n: u32) -> ClusterState {
        let mut s = ClusterState::new();
        for i in 0..n {
            s.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(30.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        s
    }

    fn synced_index(state: &ClusterState) -> SwarmIndex {
        let mut ix = SwarmIndex::new();
        for n in state.nodes() {
            ix.mark_dirty(n.id);
        }
        ix.sync(state);
        ix
    }

    fn plan(
        state: &ClusterState,
        ix: &SwarmIndex,
        links: &mut LinkModel,
        target: NodeId,
        missing: &[LayerId],
    ) -> SourcePlan {
        plan_sources(state, ix, links, lan(), CAP, target, missing, 0.0)
    }

    fn links_for(state: &ClusterState) -> LinkModel {
        LinkModel::new(vec![Bandwidth::from_mbps(10.0); state.node_count()])
    }

    #[test]
    fn peers_serve_cached_layers() {
        let mut state = cluster(3);
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let httpd = corpus.iter().find(|m| m.name == "httpd").unwrap();
        let (_, wp_layers) = state.intern_image(wp);
        let (_, httpd_layers) = state.intern_image(httpd);
        state.install_image(NodeId(1), &wp.image_ref(), &wp_layers).unwrap();
        let ix = synced_index(&state);
        let mut links = links_for(&state);

        // httpd on node 0: debian+ca-certs+apache come from node 1 (LAN),
        // the unique httpd layer from the registry.
        let missing = state.missing_layers(NodeId(0), &httpd_layers);
        let p = plan(&state, &ix, &mut links, NodeId(0), &missing);
        assert_eq!(p.peer_layers.len(), 3);
        assert!(p.peer_layers.iter().all(|&(_, s, _)| s == NodeId(1)));
        assert_eq!(p.registry_layers.len(), 1);
        assert_eq!(p.registry_bytes + p.peer_bytes, httpd.total_size);
        assert!(p.peer_finish > 0.0, "peer fetches land at a booked time");
    }

    #[test]
    fn cold_cluster_is_all_registry() {
        let mut state = cluster(3);
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        let ix = synced_index(&state);
        let mut links = links_for(&state);
        let p = plan(&state, &ix, &mut links, NodeId(0), &ids);
        assert!(p.peer_layers.is_empty());
        assert_eq!(p.registry_bytes, layers.total_bytes(&state.interner));
        assert_eq!(p.peer_finish, 0.0);
    }

    #[test]
    fn own_cache_never_counts_as_peer() {
        let mut state = cluster(3);
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        state.install_image(NodeId(0), &redis.image_ref(), &layers).unwrap();
        let ix = synced_index(&state);
        let mut links = links_for(&state);
        // Node 1 pulls: node 0 serves everything.
        let p = plan(&state, &ix, &mut links, NodeId(1), &ids);
        assert_eq!(p.peer_layers.len(), ids.len());
        // Node 0 asking about its own layers: missing is empty anyway, and
        // the planner never offers a node its own cache.
        assert!(state.missing_layers(NodeId(0), &layers).is_empty());
        let own = plan(&state, &ix, &mut links, NodeId(0), &ids);
        assert!(own.peer_layers.is_empty(), "sole holder is the target itself");
    }

    #[test]
    fn draining_node_is_never_a_source() {
        // Regression: plan_sources used to ignore NodeStatus entirely, so
        // a Draining (cordoned, about to leave) node could be chosen as
        // the sole source of a layer.
        let mut state = cluster(3);
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        state.install_image(NodeId(1), &redis.image_ref(), &layers).unwrap();
        state.drain_node(NodeId(1));
        let ix = synced_index(&state);
        let mut links = links_for(&state);
        let p = plan(&state, &ix, &mut links, NodeId(0), &ids);
        assert!(p.peer_layers.is_empty(), "draining holder must be skipped");
        assert_eq!(p.registry_layers.len(), ids.len());
    }

    #[test]
    fn least_loaded_ready_holder_wins_ties_by_id() {
        let mut state = cluster(4);
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        for n in [1, 2, 3] {
            state.install_image(NodeId(n), &redis.image_ref(), &layers).unwrap();
        }
        let ix = synced_index(&state);
        let mut links = links_for(&state);
        // Equal load everywhere: lowest id (node 1) takes the first layer
        // — and every later layer too, because seeder load is counted in
        // *concurrent uploads at plan time* and the bookings all start now.
        let p = plan(&state, &ix, &mut links, NodeId(0), &ids[..1]);
        assert_eq!(p.peer_layers[0].1, NodeId(1));
        // Pre-load node 1 with `CAP` uploads: it saturates, node 2 wins.
        for _ in 0..CAP {
            links.schedule_peer_transfer(3, 1, Bytes::from_mb(1000.0), lan(), 0.0);
        }
        let p = plan(&state, &ix, &mut links, NodeId(0), &ids[..1]);
        assert_eq!(p.peer_layers[0].1, NodeId(2), "saturated seeder is skipped");
    }

    #[test]
    fn saturated_swarm_falls_back_to_registry() {
        let mut state = cluster(3);
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        state.install_image(NodeId(1), &redis.image_ref(), &layers).unwrap();
        let ix = synced_index(&state);
        let mut links = links_for(&state);
        // Cap 1, and one layer already books the only seeder: the rest of
        // the image must come from the registry.
        let p = plan_sources(&state, &ix, &mut links, lan(), 1, NodeId(0), &ids, 0.0);
        assert_eq!(p.peer_layers.len(), 1);
        assert_eq!(p.registry_layers.len(), ids.len() - 1);
        assert!(links.peak_peer_uploads() <= 1);
    }

    #[test]
    fn index_follows_install_evict_and_crash() {
        let mut state = cluster(3);
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        let mut ix = synced_index(&state);
        assert!(ix.holders(ids[0]).is_empty());

        state.install_image(NodeId(1), &redis.image_ref(), &layers).unwrap();
        state.install_image(NodeId(2), &redis.image_ref(), &layers).unwrap();
        ix.mark_dirty(NodeId(1));
        ix.mark_dirty(NodeId(2));
        ix.sync(&state);
        assert_eq!(ix.holders(ids[0]), &[NodeId(1), NodeId(2)]);

        // Eviction drops the holder.
        state.evict_layers(NodeId(1), &ids);
        ix.mark_dirty(NodeId(1));
        ix.sync(&state);
        assert_eq!(ix.holders(ids[0]), &[NodeId(2)]);

        // A crash wipes the inventory; the dead node must vanish from
        // every holder list.
        state.crash_node(NodeId(2));
        ix.mark_dirty(NodeId(2));
        ix.sync(&state);
        assert!(ix.holders(ids[0]).is_empty());
    }

    #[test]
    fn sync_is_lazy_and_order_independent() {
        let mut state = cluster(3);
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let nginx = corpus.iter().find(|m| m.name == "nginx").unwrap();
        let (rids, rlayers) = state.intern_image(redis);
        let (_, nlayers) = state.intern_image(nginx);
        state.install_image(NodeId(2), &redis.image_ref(), &rlayers).unwrap();
        state.install_image(NodeId(1), &nginx.image_ref(), &nlayers).unwrap();

        // Dirty order {2,1} vs {1,2} must index identically (sorted lists).
        let mut a = SwarmIndex::new();
        a.mark_dirty(NodeId(2));
        a.mark_dirty(NodeId(1));
        a.sync(&state);
        let mut b = SwarmIndex::new();
        b.mark_dirty(NodeId(1));
        b.mark_dirty(NodeId(2));
        b.sync(&state);
        for &l in &rids {
            assert_eq!(a.holders(l), b.holders(l));
        }
        // Re-sync with an unchanged version is a no-op (snapshot intact).
        a.mark_dirty(NodeId(2));
        a.sync(&state);
        assert_eq!(a.holders(rids[0]), &[NodeId(2)]);
    }
}
