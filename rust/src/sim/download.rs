//! Layer-pull planning: dedup of in-flight pulls per node and transfer
//! booking on the link model. Two pods landing on the same node that need
//! the same missing layer must not download it twice — the second waits on
//! the first pull's completion (content-addressed layer store semantics).

use super::bandwidth::LinkModel;
use crate::registry::{LayerId, LayerInterner};
use crate::util::units::Bytes;
use std::collections::HashMap;

/// A planned pull for one pod on one node.
#[derive(Debug, Clone, PartialEq)]
pub struct PullPlan {
    /// Bytes this pull actually transfers (new layers only).
    pub bytes: Bytes,
    /// Transfer start for the new layers.
    pub start: f64,
    /// Transfer finish (equal to `start` when bytes = 0).
    pub finish: f64,
    /// When *all* required layers are present (waits on other pods'
    /// in-flight pulls too) — the container can start at `ready_at`.
    pub ready_at: f64,
    /// The layers this plan transfers.
    pub new_layers: Vec<LayerId>,
}

/// Tracks in-flight layer pulls per node.
///
/// WAN (registry) and LAN (peer-fetch) arrivals live in separate maps:
/// both dedupe same-node follower pulls ([`PullManager::split_wait`]), but
/// only the WAN map shifts on a registry outage — peer fetches never touch
/// the registry and must stay exempt from its stalls.
#[derive(Debug, Clone, Default)]
pub struct PullManager {
    in_flight: Vec<HashMap<LayerId, f64>>,
    peer_in_flight: Vec<HashMap<LayerId, f64>>,
}

impl PullManager {
    /// A manager for an `n_nodes` fleet with nothing in flight.
    pub fn new(n_nodes: usize) -> PullManager {
        PullManager {
            in_flight: vec![HashMap::new(); n_nodes],
            peer_in_flight: vec![HashMap::new(); n_nodes],
        }
    }

    /// Plan a pull of `missing` layers to `node` starting at `now`.
    pub fn plan(
        &mut self,
        node: usize,
        missing: &[LayerId],
        interner: &LayerInterner,
        links: &mut LinkModel,
        now: f64,
    ) -> PullPlan {
        let mut wait_on_inflight: f64 = now;
        let mut new_layers = Vec::new();
        let mut bytes = Bytes::ZERO;
        for &l in missing {
            if let Some(&finish) = self.in_flight[node].get(&l) {
                wait_on_inflight = wait_on_inflight.max(finish);
            } else {
                new_layers.push(l);
                bytes += interner.size(l);
            }
        }
        let (start, finish) = if bytes > Bytes::ZERO {
            links.schedule_transfer(node, bytes, now)
        } else {
            (now, now)
        };
        for &l in &new_layers {
            self.in_flight[node].insert(l, finish);
        }
        PullPlan { bytes, start, finish, ready_at: finish.max(wait_on_inflight), new_layers }
    }

    /// Split `missing` into layers with no in-flight arrival to `node`
    /// (from either the registry or a peer) and the latest finish among
    /// the in-flight ones — the wait a follower pull must observe. The
    /// p2p path calls this *before* planning sources so an in-flight peer
    /// fetch is never double-booked as a second transfer.
    pub fn split_wait(&self, node: usize, missing: &[LayerId], now: f64) -> (Vec<LayerId>, f64) {
        let mut fresh = Vec::new();
        let mut wait = now;
        for &l in missing {
            if let Some(&finish) = self.in_flight[node].get(&l) {
                wait = wait.max(finish);
            } else if let Some(&finish) = self.peer_in_flight[node].get(&l) {
                wait = wait.max(finish);
            } else {
                fresh.push(l);
            }
        }
        (fresh, wait)
    }

    /// Record a booked peer fetch of `layer` to `node` landing at
    /// `finish`, so same-node followers wait on it instead of
    /// re-downloading.
    pub fn note_peer(&mut self, node: usize, layer: LayerId, finish: f64) {
        self.peer_in_flight[node].insert(layer, finish);
    }

    /// Drop bookkeeping for pulls completed by `now`.
    pub fn gc(&mut self, now: f64) {
        for m in &mut self.in_flight {
            m.retain(|_, &mut finish| finish > now);
        }
        for m in &mut self.peer_in_flight {
            m.retain(|_, &mut finish| finish > now);
        }
    }

    /// Register a node that joined the cluster mid-run (no pulls yet).
    pub fn add_node(&mut self) {
        self.in_flight.push(HashMap::new());
        self.peer_in_flight.push(HashMap::new());
    }

    /// Forget a crashed node's in-flight pulls — WAN and peer alike: the
    /// layers never arrive, and no future pod can wait on them (the node
    /// is down).
    pub fn clear_node(&mut self, node: usize) {
        self.in_flight[node].clear();
        self.peer_in_flight[node].clear();
    }

    /// Delay the in-flight finishes of specific `layers` on `node` — used
    /// when a pull is *planned during* a registry outage: its WAN transfer
    /// cannot move bytes until the window ends, and same-node followers
    /// waiting on these layers must observe the delayed arrival.
    pub fn delay_layers(&mut self, node: usize, layers: &[LayerId], extra: f64) {
        for l in layers {
            if let Some(finish) = self.in_flight[node].get_mut(l) {
                *finish += extra;
            }
        }
    }

    /// Registry outage: push every in-flight *WAN* layer's finish time
    /// past the stall so followers waiting on those layers observe the
    /// delayed arrival. Peer fetches (`peer_in_flight`) are untouched —
    /// LAN transfers don't depend on the registry.
    pub fn stall_in_flight(&mut self, now: f64, extra: f64) {
        for m in &mut self.in_flight {
            for finish in m.values_mut() {
                if *finish > now {
                    *finish += extra;
                }
            }
        }
    }

    /// Layers currently in flight to `node` (WAN and peer).
    pub fn in_flight_count(&self, node: usize) -> usize {
        self.in_flight[node].len() + self.peer_in_flight[node].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Bandwidth;

    fn setup() -> (LayerInterner, LinkModel, PullManager) {
        let mut interner = LayerInterner::new();
        for i in 0..4 {
            interner.intern(&format!("sha256:{i}"), Bytes::from_mb(10.0 * (i + 1) as f64));
        }
        let links = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        let pulls = PullManager::new(2);
        (interner, links, pulls)
    }

    #[test]
    fn plan_transfers_missing_bytes() {
        let (interner, mut links, mut pulls) = setup();
        let plan = pulls.plan(0, &[LayerId(0), LayerId(1)], &interner, &mut links, 0.0);
        assert_eq!(plan.bytes, Bytes::from_mb(30.0));
        assert_eq!(plan.start, 0.0);
        assert_eq!(plan.finish, 3.0);
        assert_eq!(plan.ready_at, 3.0);
        assert_eq!(plan.new_layers.len(), 2);
    }

    #[test]
    fn in_flight_layers_not_redownloaded() {
        let (interner, mut links, mut pulls) = setup();
        let p1 = pulls.plan(0, &[LayerId(0)], &interner, &mut links, 0.0); // 10MB → 1s
        let p2 = pulls.plan(0, &[LayerId(0), LayerId(1)], &interner, &mut links, 0.5);
        assert_eq!(p1.finish, 1.0);
        // p2 transfers only layer 1 (20 MB) but serializes after p1 on the
        // node link: start 1.0 → finish 3.0; waits on layer 0 via p1 (1.0).
        assert_eq!(p2.bytes, Bytes::from_mb(20.0));
        assert_eq!(p2.start, 1.0);
        assert_eq!(p2.finish, 3.0);
        assert_eq!(p2.ready_at, 3.0);
        assert_eq!(p2.new_layers, vec![LayerId(1)]);
    }

    #[test]
    fn zero_missing_is_instant() {
        let (interner, mut links, mut pulls) = setup();
        let plan = pulls.plan(0, &[], &interner, &mut links, 7.0);
        assert_eq!(plan.bytes, Bytes::ZERO);
        assert_eq!(plan.ready_at, 7.0);
    }

    #[test]
    fn waits_on_inflight_even_with_nothing_new() {
        let (interner, mut links, mut pulls) = setup();
        pulls.plan(0, &[LayerId(2)], &interner, &mut links, 0.0); // 30MB → 3s
        let p = pulls.plan(0, &[LayerId(2)], &interner, &mut links, 1.0);
        assert_eq!(p.bytes, Bytes::ZERO);
        assert_eq!(p.ready_at, 3.0, "waits for the other pod's pull");
    }

    #[test]
    fn nodes_are_independent() {
        let (interner, mut links, mut pulls) = setup();
        pulls.plan(0, &[LayerId(3)], &interner, &mut links, 0.0);
        let p = pulls.plan(1, &[LayerId(3)], &interner, &mut links, 0.0);
        assert_eq!(p.bytes, Bytes::from_mb(40.0), "different node re-downloads");
    }

    #[test]
    fn stall_shifts_only_in_flight_finishes() {
        let (interner, mut links, mut pulls) = setup();
        pulls.plan(0, &[LayerId(0)], &interner, &mut links, 0.0); // finish 1.0
        pulls.plan(1, &[LayerId(2)], &interner, &mut links, 0.0); // finish 3.0
        // Outage at t=2 for 10s: node 0's pull already finished, node 1's
        // in-flight pull shifts to 13.0.
        pulls.stall_in_flight(2.0, 10.0);
        let p = pulls.plan(1, &[LayerId(2)], &interner, &mut links, 2.5);
        assert_eq!(p.bytes, Bytes::ZERO);
        assert_eq!(p.ready_at, 13.0, "peer waits for the stalled pull");
        let q = pulls.plan(0, &[LayerId(0)], &interner, &mut links, 2.5);
        assert_eq!(q.ready_at, 2.5, "completed pull was not shifted");
    }

    #[test]
    fn joined_and_crashed_nodes_bookkeeping() {
        let (interner, mut links, mut pulls) = setup();
        pulls.add_node();
        links.add_node(crate::util::units::Bandwidth::from_mbps(10.0));
        assert_eq!(links.node_count(), 3);
        let p = pulls.plan(2, &[LayerId(0)], &interner, &mut links, 0.0);
        assert_eq!(p.bytes, Bytes::from_mb(10.0));
        assert_eq!(pulls.in_flight_count(2), 1);
        pulls.clear_node(2);
        assert_eq!(pulls.in_flight_count(2), 0);
    }

    #[test]
    fn gc_drops_completed() {
        let (interner, mut links, mut pulls) = setup();
        pulls.plan(0, &[LayerId(0)], &interner, &mut links, 0.0); // finish 1.0
        assert_eq!(pulls.in_flight_count(0), 1);
        pulls.gc(0.5);
        assert_eq!(pulls.in_flight_count(0), 1);
        pulls.gc(1.0);
        assert_eq!(pulls.in_flight_count(0), 0);
    }

    #[test]
    fn split_wait_dedupes_against_both_maps() {
        let (interner, mut links, mut pulls) = setup();
        pulls.plan(0, &[LayerId(0)], &interner, &mut links, 0.0); // WAN, finish 1.0
        pulls.note_peer(0, LayerId(1), 4.0); // peer fetch landing at 4.0
        let (fresh, wait) =
            pulls.split_wait(0, &[LayerId(0), LayerId(1), LayerId(2)], 0.5);
        assert_eq!(fresh, vec![LayerId(2)], "in-flight layers are not fresh");
        assert_eq!(wait, 4.0, "waits on the latest in-flight arrival");
        // Nothing in flight → everything fresh, wait = now.
        let (fresh, wait) = pulls.split_wait(1, &[LayerId(0)], 2.0);
        assert_eq!((fresh.len(), wait), (1, 2.0));
    }

    #[test]
    fn peer_entries_survive_outage_stalls() {
        let (interner, mut links, mut pulls) = setup();
        pulls.plan(0, &[LayerId(0)], &interner, &mut links, 0.0); // WAN, finish 1.0
        pulls.note_peer(0, LayerId(1), 2.0);
        pulls.stall_in_flight(0.5, 10.0);
        let (_, wan_wait) = pulls.split_wait(0, &[LayerId(0)], 0.5);
        assert_eq!(wan_wait, 11.0, "WAN arrival shifts by the stall");
        let (_, peer_wait) = pulls.split_wait(0, &[LayerId(1)], 0.5);
        assert_eq!(peer_wait, 2.0, "peer arrival is exempt from the stall");
        // GC and crash-clear cover the peer map too.
        pulls.gc(3.0);
        assert_eq!(pulls.split_wait(0, &[LayerId(1)], 3.0).0.len(), 1);
        pulls.note_peer(0, LayerId(1), 9.0);
        pulls.clear_node(0);
        assert_eq!(pulls.in_flight_count(0), 0);
    }
}
