//! The discrete-event simulation engine — also the API-server facade: it
//! receives pod requests, drives the watcher, invokes the scheduler, binds
//! pods, and runs the kubelet pull/start lifecycle against the link model.
//!
//! Two arrival modes reproduce the paper's protocols:
//! - **Sequential** (`inter_arrival_secs = None`): deploy, wait until the
//!   container is ready, then submit the next pod — §VI-B's measurement
//!   protocol for Table I / Fig. 5.
//! - **Timed arrivals** (`Some(dt)`): pods arrive every `dt` seconds and
//!   pulls overlap — the load-test mode used by the concurrency tests.

use super::bandwidth::LinkModel;
use super::clock::Clock;
use super::download::PullManager;
use super::kubelet::{self, PendingStart};
use super::metrics::{self, ClusterSnapshot, PodRecord};
use crate::cluster::{ClusterState, EventKind, EventLog, Node, Pod};
use crate::registry::{MetadataCache, Registry, Watcher};
use crate::sched::rl::{RlParams, RlScheduler};
use crate::sched::{CycleContext, FrameworkConfig, LrScheduler, WeightParams};
use crate::sched::scoring::ScoringBackend;
use crate::util::units::{Bandwidth, Bytes};

/// Which of the paper's three schedulers to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerChoice {
    /// Kubernetes default plugins only.
    Default,
    /// Layer scheduler with static ω = 4.
    Layer,
    /// The paper's LRScheduler (dynamic ω).
    LR,
    /// Contextual-bandit scheduler — the paper's §VII future-work
    /// direction (long-term optimization via reinforcement learning).
    Rl,
}

impl SchedulerChoice {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerChoice::Default => "Default",
            SchedulerChoice::Layer => "Layer",
            SchedulerChoice::LR => "LRScheduler",
            SchedulerChoice::Rl => "RLScheduler",
        }
    }

    pub fn all() -> [SchedulerChoice; 3] {
        [SchedulerChoice::Default, SchedulerChoice::Layer, SchedulerChoice::LR]
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub scheduler: SchedulerChoice,
    pub params: WeightParams,
    pub framework: FrameworkConfig,
    /// Override every node's bandwidth (Fig. 4 sweeps this).
    pub bandwidth_mbps: Option<f64>,
    /// Optional shared registry uplink cap.
    pub registry_uplink_mbps: Option<f64>,
    /// None ⇒ sequential protocol; Some(dt) ⇒ timed arrivals.
    pub inter_arrival_secs: Option<f64>,
    /// Enable kubelet image GC under disk pressure.
    pub gc_enabled: bool,
    /// GC sweep trigger: disk usage fraction (kubelet
    /// ImageGCHighThresholdPercent analog).
    pub gc_high_pct: f64,
    /// GC sweep target: evict unused images until usage ≤ this fraction
    /// (ImageGCLowThresholdPercent analog).
    pub gc_low_pct: f64,
    /// Cloud-edge collaborative layer sharing (paper §VII): when set,
    /// layers cached on peer edge nodes transfer at this LAN bandwidth
    /// instead of being re-downloaded from the registry.
    pub p2p_lan_mbps: Option<f64>,
    /// Registry watcher poll interval (paper §V-1 default: 10 s).
    pub watcher_interval_secs: f64,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            scheduler: SchedulerChoice::LR,
            params: WeightParams::default(),
            framework: FrameworkConfig::default(),
            bandwidth_mbps: None,
            registry_uplink_mbps: None,
            inter_arrival_secs: None,
            gc_enabled: false,
            gc_high_pct: 0.85,
            gc_low_pct: 0.70,
            p2p_lan_mbps: None,
            watcher_interval_secs: crate::registry::watcher::DEFAULT_POLL_SECS,
        }
    }
}

/// Aggregated outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub scheduler: &'static str,
    pub records: Vec<PodRecord>,
    pub snapshots: Vec<ClusterSnapshot>,
    pub unschedulable: usize,
    pub failed_pulls: usize,
    pub omega1_used: u64,
    pub omega2_used: u64,
    pub omega_trace: Vec<f64>,
}

impl SimReport {
    pub fn total_download(&self) -> Bytes {
        self.records.iter().map(|r| r.download).sum()
    }

    pub fn total_download_secs(&self) -> f64 {
        self.records.iter().map(|r| r.download_secs).sum()
    }

    pub fn final_std(&self) -> f64 {
        self.snapshots.last().map(|s| s.std_score).unwrap_or(0.0)
    }

    pub fn deployed(&self) -> usize {
        self.records.len()
    }
}

/// The scheduler driving a simulation: the paper's Algorithm-1 family or
/// the §VII learning-based extension.
enum SchedImpl {
    Lr(LrScheduler),
    Rl(RlScheduler),
}

impl SchedImpl {
    fn build(cfg: &SimConfig) -> SchedImpl {
        let framework = cfg.framework.build("sim");
        match cfg.scheduler {
            SchedulerChoice::Default => SchedImpl::Lr(LrScheduler::default_scheduler(framework)),
            SchedulerChoice::Layer => SchedImpl::Lr(LrScheduler::layer_scheduler(framework)),
            SchedulerChoice::LR => {
                let mut s = LrScheduler::lr_scheduler(framework);
                s.params = cfg.params;
                SchedImpl::Lr(s)
            }
            SchedulerChoice::Rl => {
                SchedImpl::Rl(RlScheduler::new(framework, RlParams::default(), 2024))
            }
        }
    }
}

/// The simulator.
pub struct Simulation {
    pub state: ClusterState,
    pub registry: Registry,
    pub cache: MetadataCache,
    watcher: Watcher,
    pub clock: Clock,
    links: LinkModel,
    pulls: PullManager,
    scheduler: SchedImpl,
    pending: Vec<PendingStart>,
    /// (termination time, pod) for finite-duration pods.
    terminations: Vec<(f64, crate::cluster::PodId)>,
    pub events: EventLog,
    pub records: Vec<PodRecord>,
    pub snapshots: Vec<ClusterSnapshot>,
    pub unschedulable: usize,
    pub failed_pulls: usize,
    cfg: SimConfig,
}

impl Simulation {
    pub fn new(nodes: Vec<Node>, registry: Registry, cfg: SimConfig) -> Simulation {
        let mut state = ClusterState::new();
        let mut bws = Vec::new();
        for mut n in nodes {
            if let Some(mbps) = cfg.bandwidth_mbps {
                n.bandwidth = Bandwidth::from_mbps(mbps);
            }
            bws.push(n.bandwidth);
            state.add_node(n);
        }
        let mut links = LinkModel::new(bws);
        if let Some(up) = cfg.registry_uplink_mbps {
            links.registry_uplink = Some(Bandwidth::from_mbps(up));
        }
        let scheduler = SchedImpl::build(&cfg);
        let n_nodes = state.node_count();
        Simulation {
            state,
            registry,
            cache: MetadataCache::new("/tmp/lrsched-sim-cache.json"),
            watcher: Watcher::new(cfg.watcher_interval_secs),
            clock: Clock::new(),
            links,
            pulls: PullManager::new(n_nodes),
            scheduler,
            pending: Vec::new(),
            terminations: Vec::new(),
            events: EventLog::new(),
            records: Vec::new(),
            snapshots: Vec::new(),
            unschedulable: 0,
            failed_pulls: 0,
            cfg,
        }
    }

    /// Install the XLA scoring backend (otherwise native math runs).
    /// The RL scheduler has no dense-scoring path; it keeps native math.
    pub fn with_backend(mut self, backend: Box<dyn ScoringBackend>) -> Simulation {
        self.scheduler = match SchedImpl::build(&self.cfg) {
            SchedImpl::Lr(s) => SchedImpl::Lr(s.with_backend(backend)),
            rl @ SchedImpl::Rl(_) => rl,
        };
        self
    }

    /// Complete every pending pull with `ready_at <= now`, then release
    /// finite-duration pods whose run ended by `now`.
    fn complete_due_pulls(&mut self, now: f64) {
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].plan.ready_at <= now {
                let p = self.pending.swap_remove(i);
                self.finish_pull(p);
            } else {
                i += 1;
            }
        }
        self.pulls.gc(now);
        let mut j = 0;
        while j < self.terminations.len() {
            if self.terminations[j].0 <= now {
                let (_, pod) = self.terminations.swap_remove(j);
                // Resources release; layers stay cached until GC needs them.
                let _ = self.state.unbind(pod);
            } else {
                j += 1;
            }
        }
    }

    /// Kubelet image GC: when a node crosses the high disk-usage threshold
    /// (kubelet's ImageGCHighThresholdPercent analog, 85%), evict unused
    /// images down to the low threshold (70%).
    fn gc_pressure_sweep(&mut self) {
        if !self.cfg.gc_enabled {
            return;
        }
        let now = self.clock.now();
        for i in 0..self.state.node_count() {
            let node = crate::cluster::NodeId(i as u32);
            let n = self.state.node(node);
            let (disk, used) = (n.disk.0 as f64, n.disk_used.0 as f64);
            if disk > 0.0 && used / disk > self.cfg.gc_high_pct {
                // Free down to the low-threshold usage.
                let target = Bytes((disk * (1.0 - self.cfg.gc_low_pct)) as u64);
                let freed = kubelet::gc_images(&mut self.state, node, target);
                if freed > Bytes::ZERO {
                    self.events.record(
                        now,
                        crate::cluster::PodId(u64::MAX), // node-level event
                        EventKind::Evicted { node, bytes: freed },
                    );
                }
            }
        }
    }

    fn finish_pull(&mut self, p: PendingStart) {
        if self.cfg.gc_enabled {
            let need = p.layers.difference_bytes(
                &self.state.node(p.node).layers,
                &self.state.interner,
            );
            if need > self.state.node(p.node).disk_free() {
                let freed = kubelet::gc_images(&mut self.state, p.node, need);
                if freed > Bytes::ZERO {
                    self.events.record(
                        p.plan.ready_at,
                        p.pod,
                        EventKind::Evicted { node: p.node, bytes: freed },
                    );
                }
            }
        }
        match kubelet::complete_pull(&mut self.state, &p) {
            Ok(_) => {
                kubelet::remember_image_layers(&p.image, &p.layers);
                self.events.record(
                    p.plan.ready_at,
                    p.pod,
                    EventKind::PullFinished { node: p.node, secs: p.plan.ready_at - p.plan.start },
                );
                self.events
                    .record(p.plan.ready_at, p.pod, EventKind::Started { node: p.node });
            }
            Err(e) => {
                // Disk overcommitted by concurrent binds: the pod wedges
                // (ImagePullBackOff analog). Counted, surfaced in events.
                self.failed_pulls += 1;
                self.events.record(
                    p.plan.ready_at,
                    p.pod,
                    EventKind::Unschedulable { reason: format!("pull failed: {e}") },
                );
            }
        }
    }

    /// Deploy one pod at the current virtual time. Returns false if the
    /// scheduler found no feasible node.
    pub fn deploy(&mut self, pod: Pod) -> bool {
        let now = self.clock.now();
        self.watcher.tick(now, &self.registry, &mut self.cache);
        self.complete_due_pulls(now);
        self.gc_pressure_sweep();

        let pid = self.state.submit_pod(pod.clone());
        self.events.record(now, pid, EventKind::Submitted);

        let (meta, required, bytes) = CycleContext::prepare(&mut self.state, &self.cache, &pod);
        let ctx = CycleContext::new(&self.state, &pod, meta, required.clone(), bytes);
        let decision = match &mut self.scheduler {
            SchedImpl::Lr(s) => s.schedule(&ctx),
            SchedImpl::Rl(s) => s.schedule(&ctx).map(|node| {
                // Build an equivalent decision record for the RL pick.
                let n = ctx.state.node(node);
                let local = crate::sched::layer_score::local_bytes(&ctx, n);
                crate::sched::Decision {
                    node,
                    final_score: 0.0,
                    layer_score: crate::sched::layer_score::layer_sharing_score(
                        local,
                        ctx.required_bytes,
                    ),
                    k8s_score: 0.0,
                    omega: 0.0,
                    download_cost: crate::sched::layer_score::download_cost(&ctx, n),
                }
            }),
        };
        let decision = match decision {
            Ok(d) => d,
            Err(u) => {
                drop(ctx);
                self.unschedulable += 1;
                self.events
                    .record(now, pid, EventKind::Unschedulable { reason: u.to_string() });
                return false;
            }
        };
        drop(ctx);

        self.events.record(
            now,
            pid,
            EventKind::Scheduled { node: decision.node, score: decision.final_score },
        );
        self.state.bind(pid, decision.node).expect("bind after schedule");

        let pending = kubelet::begin_pull(
            &self.state,
            &mut self.pulls,
            &mut self.links,
            now,
            pid,
            decision.node,
            &pod.image,
            &required,
            self.cfg.p2p_lan_mbps.map(Bandwidth::from_mbps),
        );
        self.events.record(
            now,
            pid,
            EventKind::PullStarted {
                node: decision.node,
                bytes: pending.plan.bytes,
                layers: pending.plan.new_layers.len(),
            },
        );
        let (wan_bytes, p2p_bytes) = (pending.wan_bytes, pending.p2p_bytes);
        let ready_at = pending.plan.ready_at;
        let download_secs = ready_at - now;
        self.pending.push(pending);
        if let Some(d) = pod.duration_secs {
            self.terminations.push((ready_at + d, pid));
        }

        if self.cfg.inter_arrival_secs.is_none() {
            // Sequential protocol: wait for the container to be ready.
            self.clock.advance_to(ready_at);
            self.complete_due_pulls(ready_at);
        }

        let std_after = metrics::cluster_std(&self.state);
        if let SchedImpl::Rl(s) = &mut self.scheduler {
            // Online reward: the paper's two objectives as one scalar.
            s.learn(wan_bytes.as_mb(), std_after);
        }
        self.records.push(PodRecord {
            pod: pid,
            image: pod.image.key(),
            node: self.state.node(decision.node).name.clone(),
            download: wan_bytes,
            p2p: p2p_bytes,
            download_secs,
            std_after,
            omega: decision.omega,
            layer_score: decision.layer_score,
            final_score: decision.final_score,
            at: now,
        });
        self.snapshots.push(metrics::snapshot(&self.state, self.clock.now()));
        true
    }

    /// Run a whole trace; timed mode advances the clock between arrivals.
    pub fn run_trace(&mut self, pods: Vec<Pod>) -> SimReport {
        for pod in pods {
            self.deploy(pod);
            if let Some(dt) = self.cfg.inter_arrival_secs {
                let t = self.clock.now() + dt;
                self.clock.advance_to(t);
            }
        }
        // Drain outstanding pulls.
        let drain_at = self
            .pending
            .iter()
            .map(|p| p.plan.ready_at)
            .fold(self.clock.now(), f64::max);
        self.clock.advance_to(drain_at);
        self.complete_due_pulls(drain_at);
        self.report()
    }

    pub fn report(&self) -> SimReport {
        let (w1, w2, trace) = match &self.scheduler {
            SchedImpl::Lr(s) => (
                s.stats.omega1_used,
                s.stats.omega2_used,
                s.stats.omega_trace.clone(),
            ),
            SchedImpl::Rl(_) => (0, 0, Vec::new()),
        };
        SimReport {
            scheduler: self.cfg.scheduler.label(),
            records: self.records.clone(),
            snapshots: self.snapshots.clone(),
            unschedulable: self.unschedulable,
            failed_pulls: self.failed_pulls,
            omega1_used: w1,
            omega2_used: w2,
            omega_trace: trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use crate::cluster::Resources;
    use crate::sim::workload::{WorkloadConfig, WorkloadGen};

    fn nodes(n: u32) -> Vec<Node> {
        (0..n)
            .map(|i| {
                Node::new(
                    NodeId(i),
                    &format!("worker{}", i + 1),
                    Resources::cores_gb(4.0, 4.0),
                    Bytes::from_gb(30.0),
                    Bandwidth::from_mbps(10.0),
                )
            })
            .collect()
    }

    #[test]
    fn sequential_run_deploys_everything() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        let mut sim = Simulation::new(nodes(4), reg, SimConfig::default());
        let report = sim.run_trace(trace);
        assert_eq!(report.deployed(), 10);
        assert_eq!(report.unschedulable, 0);
        assert_eq!(report.failed_pulls, 0);
        assert!(report.total_download() > Bytes::ZERO);
        sim.state.check_invariants().unwrap();
        // Clock advanced by the total download time.
        assert!(sim.clock.now() > 0.0);
    }

    #[test]
    fn repeat_images_download_less() {
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let first = gen.next_pod();
        // Same image five times.
        let mut pods = vec![first.clone()];
        for _ in 0..4 {
            let mut p = gen.next_pod();
            p.image = first.image.clone();
            pods.push(p);
        }
        let mut sim = Simulation::new(nodes(3), reg, SimConfig::default());
        let report = sim.run_trace(pods);
        // After the first few placements every node can hold the image, so
        // at least one later deployment is a zero-byte pull.
        assert!(report.records.iter().skip(1).any(|r| r.download == Bytes::ZERO));
    }

    #[test]
    fn lr_downloads_less_than_default() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(20);
        let mut total = std::collections::HashMap::new();
        for choice in SchedulerChoice::all() {
            let mut cfg = SimConfig::default();
            cfg.scheduler = choice;
            let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
            let report = sim.run_trace(trace.clone());
            assert_eq!(report.deployed(), 20, "{choice:?}");
            total.insert(choice.label(), report.total_download());
        }
        assert!(
            total["LRScheduler"] < total["Default"],
            "LR {} !< Default {}",
            total["LRScheduler"],
            total["Default"]
        );
        // Layer (static ω=4) also beats Default; its ordering vs. LR varies
        // per trace (the paper's Table I shows the same per-step flips).
        assert!(
            total["Layer"] < total["Default"],
            "Layer {} !< Default {}",
            total["Layer"],
            total["Default"]
        );
        let _ = reg;
    }

    #[test]
    fn timed_arrivals_overlap_pulls() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(8);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.deployed(), 8);
        // Arrivals every 1s while pulls take tens of seconds ⇒ the clock
        // at the last arrival is ~8s but the drain runs far past it.
        assert!(sim.clock.now() > 8.0);
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn omega_stats_recorded_for_lr_only() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(12);
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
        let report = sim.run_trace(trace.clone());
        assert_eq!(report.omega1_used + report.omega2_used, 12);
        assert_eq!(report.omega_trace.len(), 12);

        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::Default;
        let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.omega1_used + report.omega2_used, 0);
    }

    #[test]
    fn unschedulable_pods_counted_not_fatal() {
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let mut big = gen.next_pod();
        big.requests = Resources::cores_gb(64.0, 64.0);
        let ok = gen.next_pod();
        let mut sim = Simulation::new(nodes(2), reg, SimConfig::default());
        let report = sim.run_trace(vec![big, ok]);
        assert_eq!(report.unschedulable, 1);
        assert_eq!(report.deployed(), 1);
    }
}
