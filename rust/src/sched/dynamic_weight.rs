//! The resource-adaptive dynamic weight — paper Eqs. (11)–(13) and the
//! ω policies of §IV-B ("Values of dynamic weights").
//!
//!   S_STD(t)    = |p_n(t)/p_n − e_n(t)/e_n| / 2                  (Eq. 11)
//!   S_CPU(t)    = p_n(t)/p_n                                     (Eq. 12)
//!   S_Weight(t) = [D_c^n(t) > h_size]·[S_CPU < h_CPU]·[S_STD < h_STD]
//!                                                                (Eq. 13)
//! ω = ω₁ when the gate is 1 (node idle, balanced, already sharing layers);
//! ω = ω₂ otherwise.

use crate::cluster::Node;
use crate::util::units::Bytes;

/// Thresholds and weights from the paper's §VI-A settings.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightParams {
    /// ω when the Eq. 13 gate passes (favor layer sharing).
    pub omega1: f64,
    /// ω otherwise (favor resource balance).
    pub omega2: f64,
    /// h_size in MB (the paper's D_c^n(t) > h_size with h_size = 10).
    pub h_size_mb: f64,
    /// h_CPU threshold on Eq. 12.
    pub h_cpu: f64,
    /// h_STD threshold on Eq. 11.
    pub h_std: f64,
}

impl Default for WeightParams {
    /// §VI-A: ω₁=2, ω₂=0.5, h_size=10, h_CPU=0.6, h_STD=0.16.
    fn default() -> WeightParams {
        WeightParams { omega1: 2.0, omega2: 0.5, h_size_mb: 10.0, h_cpu: 0.6, h_std: 0.16 }
    }
}

/// Eq. (11): node resource-balance score.
pub fn std_score(node: &Node) -> f64 {
    let (cpu, mem) = node.utilisation();
    (cpu - mem).abs() / 2.0
}

/// Eq. (12): CPU consumption score.
pub fn cpu_score(node: &Node) -> f64 {
    node.utilisation().0
}

/// Eq. (13): the Iverson-bracket gate. `local_bytes` is D_c^n(t).
pub fn weight_gate(params: &WeightParams, node: &Node, local_bytes: Bytes) -> bool {
    local_bytes.as_mb() > params.h_size_mb
        && cpu_score(node) < params.h_cpu
        && std_score(node) < params.h_std
}

/// ω policies — the scalability axis of §IV-B ("we can set different values
/// for ω₁ and ω₂ … add more conditions or piecewise functions … or set a
/// function ω = f(S_weight)").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WeightPolicy {
    /// The paper's Algorithm 1: ω₁ if the gate passes, else ω₂.
    TwoLevel,
    /// Three-level piecewise: full gate → ω₁; partial (layers present and
    /// CPU low, balance ignored) → (ω₁+ω₂)/2; else ω₂.
    ThreeLevel,
    /// Continuous ω = ω₂ + (ω₁−ω₂)·g where g ∈ [0,1] blends how far each
    /// condition is inside its threshold.
    Linear,
    /// Static ω (the "Layer scheduler" baseline uses Static with ω = 4).
    Static(f64),
}

/// Compute ω for one node under a policy.
pub fn weight_for(
    policy: WeightPolicy,
    params: &WeightParams,
    node: &Node,
    local_bytes: Bytes,
) -> f64 {
    match policy {
        WeightPolicy::Static(w) => w,
        WeightPolicy::TwoLevel => {
            if weight_gate(params, node, local_bytes) {
                params.omega1
            } else {
                params.omega2
            }
        }
        WeightPolicy::ThreeLevel => {
            if weight_gate(params, node, local_bytes) {
                params.omega1
            } else if local_bytes.as_mb() > params.h_size_mb && cpu_score(node) < params.h_cpu {
                (params.omega1 + params.omega2) / 2.0
            } else {
                params.omega2
            }
        }
        WeightPolicy::Linear => {
            // Each condition contributes its headroom fraction in [0,1].
            let g_size = if local_bytes.as_mb() > params.h_size_mb { 1.0 } else { 0.0 };
            let g_cpu = ((params.h_cpu - cpu_score(node)) / params.h_cpu).clamp(0.0, 1.0);
            let g_std = ((params.h_std - std_score(node)) / params.h_std).clamp(0.0, 1.0);
            let g = g_size * g_cpu * g_std;
            params.omega2 + (params.omega1 - params.omega2) * g
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, NodeId, PodId, Resources};
    use crate::util::units::{Bandwidth, Bytes};

    fn node_with_load(cpu_cores: f64, mem_gb: f64) -> Node {
        let mut n = Node::new(
            NodeId(0),
            "n",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        );
        n.assign(PodId(0), Resources::cores_gb(cpu_cores, mem_gb));
        n
    }

    #[test]
    fn eq11_eq12_formulas() {
        let n = node_with_load(2.0, 1.0); // cpu 50%, mem 25%
        assert!((std_score(&n) - 0.125).abs() < 1e-12);
        assert!((cpu_score(&n) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gate_requires_all_three() {
        let p = WeightParams::default();
        let idle = node_with_load(1.0, 1.0); // cpu 25%, std 0
        let big = Bytes::from_mb(50.0);
        let small = Bytes::from_mb(5.0);
        assert!(weight_gate(&p, &idle, big));
        assert!(!weight_gate(&p, &idle, small)); // layers below h_size
        let busy = node_with_load(3.0, 3.0); // cpu 75% ≥ h_cpu
        assert!(!weight_gate(&p, &busy, big));
        let skewed = node_with_load(2.0, 0.0); // std 0.25 ≥ h_std
        assert!(!weight_gate(&p, &skewed, big));
    }

    #[test]
    fn two_level_policy_matches_paper() {
        let p = WeightParams::default();
        let idle = node_with_load(1.0, 1.0);
        let busy = node_with_load(3.0, 3.0);
        let big = Bytes::from_mb(50.0);
        assert_eq!(weight_for(WeightPolicy::TwoLevel, &p, &idle, big), 2.0);
        assert_eq!(weight_for(WeightPolicy::TwoLevel, &p, &busy, big), 0.5);
    }

    #[test]
    fn static_policy_ignores_state() {
        let p = WeightParams::default();
        let busy = node_with_load(4.0, 4.0);
        assert_eq!(weight_for(WeightPolicy::Static(4.0), &p, &busy, Bytes::ZERO), 4.0);
    }

    #[test]
    fn three_level_middle_case() {
        let p = WeightParams::default();
        let skewed = node_with_load(2.0, 0.0); // cpu ok, std bad
        let big = Bytes::from_mb(50.0);
        assert_eq!(weight_for(WeightPolicy::ThreeLevel, &p, &skewed, big), 1.25);
        let busy = node_with_load(3.0, 3.0);
        assert_eq!(weight_for(WeightPolicy::ThreeLevel, &p, &busy, big), 0.5);
    }

    #[test]
    fn linear_policy_interpolates() {
        let p = WeightParams::default();
        let idle = node_with_load(0.0, 0.0);
        let big = Bytes::from_mb(50.0);
        // Fully idle: g = 1 → ω₁.
        assert!((weight_for(WeightPolicy::Linear, &p, &idle, big) - 2.0).abs() < 1e-12);
        // No local layers: g = 0 → ω₂.
        assert!((weight_for(WeightPolicy::Linear, &p, &idle, Bytes::ZERO) - 0.5).abs() < 1e-12);
        // Partial load lands strictly between.
        let mid = node_with_load(1.2, 1.0);
        let w = weight_for(WeightPolicy::Linear, &p, &mid, big);
        assert!(w > 0.5 && w < 2.0, "got {w}");
    }
}
