//! PJRT wrapper — thin layer over the `xla` crate: one CPU client per
//! process, HLO-text loading (the AOT interchange format, see
//! `python/compile/aot.py`), compile-once semantics.
//!
//! Imports go through [`super::ffi`], so this file type-checks in CI
//! against the vendored shim (`--features xla`) and binds to the real
//! crates only with `--features xla,xla-external`.

use super::ffi::anyhow::{Context, Result};
use super::ffi::xla;
use std::path::Path;

/// The PJRT client. Compilation happens once at startup; `execute` is the
/// only per-cycle call.
pub struct PjRt {
    client: xla::PjRtClient,
}

impl PjRt {
    /// A PJRT client on the CPU platform.
    pub fn cpu() -> Result<PjRt> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjRt { client })
    }

    /// Platform name reported by the client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Devices visible to the client.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the (tuple) output literal.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs).context("executing")?;
        let literal = result[0][0].to_literal_sync().context("fetching result")?;
        Ok(literal)
    }
}
