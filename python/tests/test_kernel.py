"""L1 correctness: the Pallas shared-bytes kernel vs. the pure-jnp oracle.

This is the core numeric signal — if Eq. 2 is wrong every score in the
system is wrong. Hypothesis sweeps shapes and value distributions.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import shared_bytes_ref
from compile.kernels.shared_bytes import shared_bytes


def rng(seed):
    return np.random.default_rng(seed)


def random_case(r, n, l, density=0.3):
    present = (r.random((n, l)) < density).astype(np.float32)
    req = (r.random(l) < density).astype(np.float32)
    sizes = (r.random(l) * 500.0).astype(np.float32)
    return present, req, sizes


def test_tiny_hand_case():
    present = jnp.array([[1.0, 0.0, 1.0], [0.0, 1.0, 0.0]])
    req = jnp.array([1.0, 1.0, 0.0])
    sizes = jnp.array([10.0, 20.0, 30.0])
    out = shared_bytes(present, req, sizes, block_n=2, block_l=3)
    np.testing.assert_allclose(np.asarray(out), [10.0, 20.0])


def test_zero_required_is_zero():
    r = rng(0)
    present, _, sizes = random_case(r, 8, 256)
    req = np.zeros(256, dtype=np.float32)
    out = shared_bytes(jnp.asarray(present), jnp.asarray(req), jnp.asarray(sizes))
    np.testing.assert_allclose(np.asarray(out), np.zeros(8))


def test_full_presence_equals_total():
    r = rng(1)
    _, req, sizes = random_case(r, 4, 256)
    present = np.ones((4, 256), dtype=np.float32)
    out = shared_bytes(jnp.asarray(present), jnp.asarray(req), jnp.asarray(sizes))
    total = float(np.sum(req * sizes))
    np.testing.assert_allclose(np.asarray(out), np.full(4, total), rtol=1e-5)


@pytest.mark.parametrize("n,l", [(8, 256), (16, 256), (8, 512), (64, 1024), (16, 256)])
def test_matches_ref_at_variant_shapes(n, l):
    r = rng(n * 1000 + l)
    present, req, sizes = random_case(r, n, l)
    got = shared_bytes(jnp.asarray(present), jnp.asarray(req), jnp.asarray(sizes))
    want = shared_bytes_ref(jnp.asarray(present), jnp.asarray(req), jnp.asarray(sizes))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("bn,bl", [(1, 256), (2, 128), (4, 64), (8, 32), (16, 256)])
def test_block_shape_invariance(bn, bl):
    """Tiling must not change the result (double-buffer/tile sweep)."""
    r = rng(42)
    present, req, sizes = random_case(r, 16, 256)
    args = (jnp.asarray(present), jnp.asarray(req), jnp.asarray(sizes))
    base = shared_bytes(*args, block_n=16, block_l=256)
    tiled = shared_bytes(*args, block_n=bn, block_l=bl)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(base), rtol=1e-5, atol=1e-3)


def test_indivisible_shape_raises():
    with pytest.raises(ValueError):
        shared_bytes(jnp.zeros((5, 256)), jnp.zeros(256), jnp.zeros(256), block_n=2)
    with pytest.raises(ValueError):
        shared_bytes(jnp.zeros((8, 100)), jnp.zeros(100), jnp.zeros(100), block_l=64)


@settings(max_examples=30, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    l_blocks=st.integers(1, 4),
    bn=st.sampled_from([1, 2, 4, 8]),
    bl=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**16),
    density=st.floats(0.0, 1.0),
)
def test_hypothesis_sweep(n_blocks, l_blocks, bn, bl, seed, density):
    n, l = n_blocks * bn, l_blocks * bl
    r = rng(seed)
    present, req, sizes = random_case(r, n, l, density)
    got = shared_bytes(
        jnp.asarray(present), jnp.asarray(req), jnp.asarray(sizes), block_n=bn, block_l=bl
    )
    want = np.asarray(present) @ (req * sizes)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_dtype_tolerance_int_presence(seed):
    """Presence matrices arrive as 0/1 ints from the rust bitsets."""
    r = rng(seed)
    present = r.integers(0, 2, (8, 64)).astype(np.int32)
    req = r.integers(0, 2, 64).astype(np.int32)
    sizes = (r.random(64) * 100).astype(np.float32)
    got = shared_bytes(
        jnp.asarray(present), jnp.asarray(req, dtype=jnp.float32), jnp.asarray(sizes),
        block_n=8, block_l=64,
    )
    want = present.astype(np.float64) @ (req * sizes)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)
