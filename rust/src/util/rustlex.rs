//! Token-level Rust lexer for the in-repo determinism lint (`detlint`).
//!
//! Hand-written in the house style of the gzip inflate: no dependencies,
//! a single forward scan, and exhaustive unit tests. It is *not* a full
//! Rust parser — it produces a flat token stream that is exactly
//! comment-, string-, lifetime-, and raw-string-aware, which is all the
//! lint rules in [`crate::lint`] need: they pattern-match short token
//! sequences (`Instant :: now`, `ident . keys (`) and must never be
//! fooled by the same characters appearing inside a comment or a string
//! literal.
//!
//! Fidelity notes (deliberate simplifications, safe for linting):
//! - Multi-char operators are joined by maximal munch over a fixed table
//!   (`::`, `+=`, `..=`, …); everything else is a single-char punct.
//! - `'a'` vs `'a` is disambiguated by the closing quote; escaped char
//!   literals (`'\n'`, `'\u{1F600}'`) are consumed as one token.
//! - Line numbers are 1-based and survive `\`-newline string
//!   continuations, multi-line raw strings, and nested block comments.

/// Token classification — just enough structure for rule matching.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`for`, `unsafe`, `HashMap`, …).
    Ident,
    /// Numeric literal (integer or float, any base, with suffixes).
    Num,
    /// String literal: `"…"`, `r"…"`, `r#"…"#`, `b"…"`, and combinations.
    Str,
    /// Character or byte literal: `'x'`, `'\n'`, `b'x'`.
    Char,
    /// Lifetime: `'a`, `'static`, `'_`.
    Lifetime,
    /// Punctuation / operator (joined for the fixed multi-char table).
    Punct,
    /// Line (`//…`) or block (`/*…*/`, nested) comment, docs included.
    Comment,
}

/// One lexed token with its 1-based starting line.
#[derive(Debug, Clone)]
pub struct Tok {
    /// What kind of token this is.
    pub kind: TokKind,
    /// The exact source text of the token.
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

impl Tok {
    /// Is this a code token (anything but a comment)?
    pub fn is_code(&self) -> bool {
        self.kind != TokKind::Comment
    }
}

/// Multi-char operators, longest first (maximal munch).
const JOINED: &[&str] = &[
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=",
    "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>", "..",
];

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into a flat token stream. Never fails: unterminated strings
/// or comments extend to end-of-input (the lint runs on work-in-progress
/// files, so hard errors would be worse than a best-effort tail).
pub fn lex(src: &str) -> Vec<Tok> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let text = |lo: usize, hi: usize| -> String { cs[lo..hi.min(n)].iter().collect() };

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Line comment (also doc `///` and `//!`).
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let lo = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Comment, text: text(lo, i), line });
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let (lo, start_line) = (i, line);
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Comment, text: text(lo, i), line: start_line });
            continue;
        }
        // Raw strings: r"…", r#"…"#, br"…", rb"…" (any hash depth).
        if c == 'r' || c == 'b' {
            let mut j = i;
            if (cs[j] == 'b' && j + 1 < n && cs[j + 1] == 'r')
                || (cs[j] == 'r' && j + 1 < n && cs[j + 1] == 'b')
            {
                j += 2;
            } else if cs[j] == 'r' {
                j += 1;
            } else {
                j = usize::MAX; // plain `b` handled by the string branch below
            }
            if j != usize::MAX {
                let mut hashes = 0usize;
                while j < n && cs[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && cs[j] == '"' {
                    let (lo, start_line) = (i, line);
                    j += 1;
                    // Scan for `"` followed by `hashes` hashes.
                    'scan: while j < n {
                        if cs[j] == '\n' {
                            line += 1;
                        } else if cs[j] == '"' {
                            let mut k = 0usize;
                            while k < hashes && j + 1 + k < n && cs[j + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                j += 1 + hashes;
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    toks.push(Tok { kind: TokKind::Str, text: text(lo, j), line: start_line });
                    i = j;
                    continue;
                }
            }
        }
        // Strings, including `b"…"`.
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let (lo, start_line) = (i, line);
            i += if c == 'b' { 2 } else { 1 };
            while i < n {
                if cs[i] == '\\' {
                    // `\<newline>` line continuations still advance lines.
                    if i + 1 < n && cs[i + 1] == '\n' {
                        line += 1;
                    }
                    i += 2;
                } else if cs[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Str, text: text(lo, i), line: start_line });
            continue;
        }
        // Lifetime vs char literal (and byte chars b'x').
        if c == '\'' || (c == 'b' && i + 1 < n && cs[i + 1] == '\'') {
            let lo = i;
            if c == 'b' {
                i += 1; // consume the `b`; fall through as a char literal
            }
            // Escaped char: '\…' up to the closing quote (skip the
            // escaped character itself so `'\''` closes correctly).
            if i + 1 < n && cs[i + 1] == '\\' {
                let mut j = i + 3;
                while j < n && cs[j] != '\'' {
                    j += 1;
                }
                toks.push(Tok { kind: TokKind::Char, text: text(lo, j + 1), line });
                i = (j + 1).min(n);
                continue;
            }
            // 'x' is a char iff a closing quote follows one character.
            if cs[lo] == '\'' && i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\'' {
                toks.push(Tok { kind: TokKind::Char, text: text(lo, i + 3), line });
                i += 3;
                continue;
            }
            if cs[lo] == 'b' && i + 2 < n && cs[i + 2] == '\'' {
                toks.push(Tok { kind: TokKind::Char, text: text(lo, i + 3), line });
                i += 3;
                continue;
            }
            // Otherwise a lifetime: '<ident>.
            let mut j = i + 1;
            while j < n && is_ident_cont(cs[j]) {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Lifetime, text: text(lo, j), line });
            i = j;
            continue;
        }
        // Numbers (coarse: consumes suffixes; float part via `.digit`).
        if c.is_ascii_digit() {
            let lo = i;
            while i < n && (cs[i].is_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            if i + 1 < n && cs[i] == '.' && cs[i + 1].is_ascii_digit() {
                i += 1;
                while i < n
                    && (cs[i].is_alphanumeric()
                        || cs[i] == '_'
                        || ((cs[i] == '+' || cs[i] == '-')
                            && (cs[i - 1] == 'e' || cs[i - 1] == 'E')))
                {
                    i += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Num, text: text(lo, i), line });
            continue;
        }
        // Identifiers and keywords.
        if is_ident_start(c) {
            let lo = i;
            while i < n && is_ident_cont(cs[i]) {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: text(lo, i), line });
            continue;
        }
        // Joined punctuation, maximal munch.
        let mut matched = false;
        for op in JOINED {
            let oc: Vec<char> = op.chars().collect();
            if i + oc.len() <= n && cs[i..i + oc.len()] == oc[..] {
                toks.push(Tok { kind: TokKind::Punct, text: (*op).to_string(), line });
                i += oc.len();
                matched = true;
                break;
            }
        }
        if !matched {
            toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_keywords_and_puncts() {
        let t = kinds("for x in &map { x += 1; }");
        let texts: Vec<&str> = t.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(texts, vec!["for", "x", "in", "&", "map", "{", "x", "+=", "1", ";", "}"]);
        assert_eq!(t[0].0, TokKind::Ident);
        assert_eq!(t[7].0, TokKind::Punct); // joined `+=`
    }

    #[test]
    fn comments_swallow_code_lookalikes() {
        let t = lex("a // Instant::now() in a comment\nb /* unsafe { /* nested */ } */ c");
        let code: Vec<&str> =
            t.iter().filter(|t| t.is_code()).map(|t| t.text.as_str()).collect();
        assert_eq!(code, vec!["a", "b", "c"]);
        // The block comment keeps its full (nested) text.
        assert!(t.iter().any(|t| t.kind == TokKind::Comment && t.text.contains("nested")));
    }

    #[test]
    fn strings_hide_tokens_and_count_lines() {
        let t = lex("let s = \"for x in map.keys() {\"; done");
        let code: Vec<&str> =
            t.iter().filter(|t| t.is_code()).map(|t| t.text.as_str()).collect();
        assert_eq!(code, vec!["let", "s", "=", "\"for x in map.keys() {\"", ";", "done"]);
        // `\`-newline continuation: `done` is on line 2 of the source.
        let t = lex("let s = \"a\\\nb\"; done");
        let done = t.iter().find(|t| t.text == "done").unwrap();
        assert_eq!(done.line, 2);
        // A real newline inside a string also advances the count.
        let t = lex("let s = \"a\nb\"; done");
        assert_eq!(t.iter().find(|t| t.text == "done").unwrap().line, 2);
    }

    #[test]
    fn raw_strings_with_hashes() {
        let t = lex(r###"let s = r#"quote " inside"#; x"###);
        let s = t.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert!(s.text.contains("quote"));
        assert!(t.iter().any(|t| t.text == "x"));
        let t = lex("let b = br\"bytes\"; y");
        assert!(t.iter().any(|t| t.kind == TokKind::Str && t.text == "br\"bytes\""));
        assert!(t.iter().any(|t| t.text == "y"));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let t = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(t.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'a"));
        assert!(t.iter().any(|(k, s)| *k == TokKind::Char && s == "'x'"));
        let t = kinds(r"let c = '\n'; let u = '\u{1F600}'; let b = b'x';");
        assert_eq!(t.iter().filter(|(k, _)| *k == TokKind::Char).count(), 3);
    }

    #[test]
    fn numbers_including_floats() {
        let t = kinds("1.5e-9 + 0x_ff - 42u64 .. 1.0");
        let nums: Vec<&str> = t
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(nums, vec!["1.5e-9", "0x_ff", "42u64", "1.0"]);
        // `1..2` stays an integer, `..`, integer — not a float.
        let t = kinds("1..2");
        let texts: Vec<&str> = t.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(texts, vec!["1", "..", "2"]);
    }

    #[test]
    fn line_numbers_survive_everything() {
        let src = "line1\n/* a\nb\nc */\nline5 \"x\ny\" line6_on_6\n'z' last";
        let t = lex(src);
        assert_eq!(t.iter().find(|t| t.text == "line1").unwrap().line, 1);
        assert_eq!(t.iter().find(|t| t.text == "line5").unwrap().line, 5);
        assert_eq!(t.iter().find(|t| t.text == "line6_on_6").unwrap().line, 6);
        assert_eq!(t.iter().find(|t| t.text == "last").unwrap().line, 7);
    }

    #[test]
    fn unterminated_input_does_not_panic() {
        lex("let s = \"open");
        lex("/* open");
        lex("r#\"open");
        lex("'");
    }
}
