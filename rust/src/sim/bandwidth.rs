//! Link model between the registry and each edge node.
//!
//! The paper's model is T = C_c^n(t) / b_n (§III-B): each node has its own
//! downlink; pulls on one node serialize (Docker pulls a layer stream), and
//! pulls on different nodes proceed independently. An optional registry
//! uplink cap models a constrained private registry shared by all nodes —
//! an ablation the paper's future work hints at.

use crate::util::units::{Bandwidth, Bytes};

#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Per-node downlink.
    node_bw: Vec<Bandwidth>,
    /// Time each node's link becomes free.
    node_free_at: Vec<f64>,
    /// Optional shared registry uplink (None = unconstrained).
    pub registry_uplink: Option<Bandwidth>,
    registry_free_at: f64,
}

impl LinkModel {
    pub fn new(node_bw: Vec<Bandwidth>) -> LinkModel {
        let n = node_bw.len();
        LinkModel { node_bw, node_free_at: vec![0.0; n], registry_uplink: None, registry_free_at: 0.0 }
    }

    pub fn bandwidth(&self, node: usize) -> Bandwidth {
        self.node_bw[node]
    }

    pub fn set_bandwidth(&mut self, node: usize, bw: Bandwidth) {
        self.node_bw[node] = bw;
    }

    /// Register the link of a node that joined the cluster mid-run.
    pub fn add_node(&mut self, bw: Bandwidth) {
        self.node_bw.push(bw);
        self.node_free_at.push(0.0);
    }

    pub fn node_count(&self) -> usize {
        self.node_bw.len()
    }

    /// Delay the most recent booking on `node` by `extra` seconds — used
    /// when a transfer is *planned during* a registry outage (the booking
    /// just made by `schedule_transfer` is the latest on both the node
    /// link and, if capped, the registry uplink).
    pub fn delay_booking(&mut self, node: usize, extra: f64) {
        self.node_free_at[node] += extra;
        if self.registry_uplink.is_some() {
            self.registry_free_at += extra;
        }
    }

    /// Registry outage: every transfer still in flight at `now` (link busy
    /// past `now`) pauses for `extra` seconds — bookings shift so transfers
    /// planned after the outage queue behind the resumed ones.
    pub fn stall_in_flight(&mut self, now: f64, extra: f64) {
        for t in self.node_free_at.iter_mut() {
            if *t > now {
                *t += extra;
            }
        }
        if self.registry_free_at > now {
            self.registry_free_at += extra;
        }
    }

    /// Schedule a transfer of `bytes` to `node` starting no earlier than
    /// `now`; returns (start, finish) and books the link.
    pub fn schedule_transfer(&mut self, node: usize, bytes: Bytes, now: f64) -> (f64, f64) {
        let mut start = now.max(self.node_free_at[node]);
        if self.registry_uplink.is_some() {
            start = start.max(self.registry_free_at);
        }
        let mut secs = self.node_bw[node].transfer_secs(bytes);
        if let Some(up) = self.registry_uplink {
            secs = secs.max(up.transfer_secs(bytes));
        }
        let finish = start + secs;
        self.node_free_at[node] = finish;
        if self.registry_uplink.is_some() {
            self.registry_free_at = finish;
        }
        (start, finish)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_node_links_are_independent() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        let (s0, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, f1) = lm.schedule_transfer(1, Bytes::from_mb(50.0), 0.0);
        assert_eq!((s0, f0), (0.0, 10.0));
        assert_eq!((s1, f1), (0.0, 5.0));
    }

    #[test]
    fn same_node_transfers_serialize() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0)]);
        let (_, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, f1) = lm.schedule_transfer(0, Bytes::from_mb(10.0), 2.0);
        assert_eq!(f0, 10.0);
        assert_eq!(s1, 10.0); // waits for the first pull
        assert_eq!(f1, 11.0);
    }

    #[test]
    fn registry_uplink_serializes_across_nodes() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        let (_, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, _) = lm.schedule_transfer(1, Bytes::from_mb(10.0), 0.0);
        assert_eq!(s1, f0, "second node waits on the registry uplink");
    }

    #[test]
    fn slow_uplink_dominates() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(100.0)]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        let (_, f) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        assert_eq!(f, 10.0, "uplink is the bottleneck");
    }

    #[test]
    fn joined_node_gets_fresh_link() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0)]);
        lm.add_node(Bandwidth::from_mbps(20.0));
        assert_eq!(lm.node_count(), 2);
        let (s, f) = lm.schedule_transfer(1, Bytes::from_mb(40.0), 100.0);
        assert_eq!((s, f), (100.0, 102.0));
    }

    #[test]
    fn outage_stall_shifts_busy_links_only() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0); // busy until 10
        lm.schedule_transfer(1, Bytes::from_mb(10.0), 0.0); // busy until 1
        lm.stall_in_flight(2.0, 5.0);
        // Node 0 was mid-transfer: its link frees 5s later; node 1 had
        // already finished and is unaffected.
        let (s0, _) = lm.schedule_transfer(0, Bytes::from_mb(10.0), 2.0);
        assert_eq!(s0, 15.0);
        let (s1, _) = lm.schedule_transfer(1, Bytes::from_mb(10.0), 2.0);
        assert_eq!(s1, 2.0);
    }
}
