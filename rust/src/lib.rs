//! # lrsched — LRScheduler reproduction
//!
//! A layer-aware, resource-adaptive container scheduler for edge computing,
//! reproducing Tang et al., *LRScheduler* (MSN 2024), as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: a Kubernetes-scheduling-framework analog with the
//!   paper's LRScheduler plugin, a Docker-registry substrate, an edge-cluster
//!   discrete-event simulator, and the experiment harnesses for every figure
//!   and table in the paper's evaluation.
//! - **L2/L1 (`python/compile/`)**: the batched node-scoring pipeline
//!   (layer-sharing score, resource scores, Iverson-gated dynamic weights)
//!   as a JAX graph wrapping a Pallas kernel, AOT-lowered to HLO text.
//! - **Runtime (`runtime`)**: loads the AOT artifacts via PJRT (`xla` crate)
//!   and serves them on the scheduling hot path; a pure-rust scorer provides
//!   the always-available fallback and the differential-testing oracle.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod cli;
pub mod cluster;
pub mod exp;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod testing;
pub mod registry;
pub mod util;
