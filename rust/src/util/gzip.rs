//! Gzip (RFC 1952) + DEFLATE (RFC 1951) decompression, dependency-free.
//!
//! The trace importer accepts `--trace foo.csv.gz`; real cluster traces
//! ship gzipped (Alibaba `batch_task.csv.gz` is ~2 GB compressed). The
//! crate is dependency-free by design (see `src/util/`), so instead of
//! pulling in `flate2` this module implements the inflate side of the
//! format directly: a bit-level reader, canonical-Huffman decoding (the
//! counting scheme from zlib's `puff`), all three block types, and the
//! CRC-32/ISIZE trailer check. Decompression is one-shot into a `Vec` —
//! the importer then streams lines from the buffer exactly as it does
//! from a plain file.

use std::fmt;

/// Why a gzip stream failed to decompress.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GzipError {
    /// Input ended before the stream was complete.
    Truncated,
    /// The two-byte gzip magic (`1f 8b`) is missing.
    BadMagic,
    /// Structurally valid gzip, but a feature this decoder rejects
    /// (e.g. a compression method other than DEFLATE).
    Unsupported(&'static str),
    /// The DEFLATE stream is internally inconsistent.
    Corrupt(&'static str),
    /// The decompressed bytes do not match the stored CRC-32.
    CrcMismatch,
    /// The decompressed length does not match the stored ISIZE.
    SizeMismatch,
}

impl fmt::Display for GzipError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GzipError::Truncated => write!(f, "gzip stream truncated"),
            GzipError::BadMagic => write!(f, "not a gzip stream (bad magic)"),
            GzipError::Unsupported(what) => write!(f, "unsupported gzip feature: {what}"),
            GzipError::Corrupt(what) => write!(f, "corrupt deflate stream: {what}"),
            GzipError::CrcMismatch => write!(f, "gzip CRC-32 mismatch"),
            GzipError::SizeMismatch => write!(f, "gzip ISIZE mismatch"),
        }
    }
}

impl std::error::Error for GzipError {}

/// CRC-32 (IEEE 802.3, reflected, as gzip uses) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, slot) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
        }
        *slot = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// LSB-first bit reader over a byte slice.
struct BitReader<'a> {
    data: &'a [u8],
    /// Next unread byte.
    pos: usize,
    bitbuf: u32,
    bitcnt: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> BitReader<'a> {
        BitReader { data, pos: 0, bitbuf: 0, bitcnt: 0 }
    }

    /// Read `n <= 16` bits, LSB-first.
    fn bits(&mut self, n: u32) -> Result<u32, GzipError> {
        while self.bitcnt < n {
            let byte = *self.data.get(self.pos).ok_or(GzipError::Truncated)? as u32;
            self.pos += 1;
            self.bitbuf |= byte << self.bitcnt;
            self.bitcnt += 8;
        }
        let v = self.bitbuf & ((1u32 << n) - 1);
        self.bitbuf >>= n;
        self.bitcnt -= n;
        Ok(v)
    }

    /// Discard the partial byte (stored blocks start byte-aligned). At
    /// most 7 bits are ever buffered, so this never loses a whole byte.
    fn align_byte(&mut self) {
        self.bitbuf = 0;
        self.bitcnt = 0;
    }

    /// Read one raw byte (caller must be byte-aligned).
    fn byte(&mut self) -> Result<u8, GzipError> {
        debug_assert_eq!(self.bitcnt, 0, "byte read while unaligned");
        let b = *self.data.get(self.pos).ok_or(GzipError::Truncated)?;
        self.pos += 1;
        Ok(b)
    }
}

/// A canonical Huffman code in the count/symbol form of zlib's `puff`:
/// `counts[l]` codes of length `l`, symbols sorted by (length, symbol).
struct Huffman {
    counts: [u16; 16],
    symbols: Vec<u16>,
}

impl Huffman {
    fn build(lengths: &[u8]) -> Result<Huffman, GzipError> {
        let mut counts = [0u16; 16];
        for &l in lengths {
            if l > 15 {
                return Err(GzipError::Corrupt("code length > 15"));
            }
            counts[l as usize] += 1;
        }
        counts[0] = 0;
        // Reject over-subscribed codes (incomplete ones are legal: a
        // single-distance-code block uses one).
        let mut left: i32 = 1;
        for len in 1..16 {
            left <<= 1;
            left -= counts[len] as i32;
            if left < 0 {
                return Err(GzipError::Corrupt("oversubscribed huffman code"));
            }
        }
        let mut offs = [0u16; 16];
        for len in 1..15 {
            offs[len + 1] = offs[len] + counts[len];
        }
        let n_symbols = lengths.iter().filter(|&&l| l != 0).count();
        let mut symbols = vec![0u16; n_symbols];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbols[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { counts, symbols })
    }

    /// Decode one symbol, one bit at a time (adequate for trace-sized
    /// inputs; a table-driven fast path can come later if profiles ask).
    fn decode(&self, br: &mut BitReader<'_>) -> Result<u16, GzipError> {
        let mut code: u32 = 0;
        let mut first: u32 = 0;
        let mut index: u32 = 0;
        for len in 1..16 {
            code |= br.bits(1)?;
            let count = self.counts[len] as u32;
            if code < first + count {
                return Ok(self.symbols[(index + (code - first)) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err(GzipError::Corrupt("invalid huffman code"))
    }
}

const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

/// Decode one Huffman-coded block body into `out`.
fn inflate_block(
    br: &mut BitReader<'_>,
    out: &mut Vec<u8>,
    litlen: &Huffman,
    dist: &Huffman,
) -> Result<(), GzipError> {
    loop {
        let sym = litlen.decode(br)?;
        if sym < 256 {
            out.push(sym as u8);
        } else if sym == 256 {
            return Ok(());
        } else {
            let idx = (sym - 257) as usize;
            if idx >= LEN_BASE.len() {
                return Err(GzipError::Corrupt("invalid length symbol"));
            }
            let len = LEN_BASE[idx] as usize + br.bits(LEN_EXTRA[idx] as u32)? as usize;
            let dsym = dist.decode(br)? as usize;
            if dsym >= DIST_BASE.len() {
                return Err(GzipError::Corrupt("invalid distance symbol"));
            }
            let d = DIST_BASE[dsym] as usize + br.bits(DIST_EXTRA[dsym] as u32)? as usize;
            if d == 0 || d > out.len() {
                return Err(GzipError::Corrupt("distance beyond window"));
            }
            let start = out.len() - d;
            // Byte-by-byte: overlapping copies replicate recent output.
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
}

/// Inflate a raw DEFLATE stream into `out`.
fn inflate(br: &mut BitReader<'_>, out: &mut Vec<u8>) -> Result<(), GzipError> {
    loop {
        let bfinal = br.bits(1)?;
        let btype = br.bits(2)?;
        match btype {
            0 => {
                // Stored: byte-aligned LEN/NLEN + raw copy.
                br.align_byte();
                let len = br.byte()? as usize | ((br.byte()? as usize) << 8);
                let nlen = br.byte()? as usize | ((br.byte()? as usize) << 8);
                if len ^ nlen != 0xFFFF {
                    return Err(GzipError::Corrupt("stored-block length check"));
                }
                for _ in 0..len {
                    let b = br.byte()?;
                    out.push(b);
                }
            }
            1 => {
                // Fixed Huffman tables (RFC 1951 §3.2.6).
                let mut litlen_lens = [0u8; 288];
                for (i, l) in litlen_lens.iter_mut().enumerate() {
                    *l = match i {
                        0..=143 => 8,
                        144..=255 => 9,
                        256..=279 => 7,
                        _ => 8,
                    };
                }
                let litlen = Huffman::build(&litlen_lens)?;
                let dist = Huffman::build(&[5u8; 30])?;
                inflate_block(br, out, &litlen, &dist)?;
            }
            2 => {
                // Dynamic tables: code-length code, then the two codes.
                let hlit = br.bits(5)? as usize + 257;
                let hdist = br.bits(5)? as usize + 1;
                let hclen = br.bits(4)? as usize + 4;
                const ORDER: [usize; 19] =
                    [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];
                let mut cl_lens = [0u8; 19];
                for &slot in ORDER.iter().take(hclen) {
                    cl_lens[slot] = br.bits(3)? as u8;
                }
                let cl = Huffman::build(&cl_lens)?;
                let mut lens = vec![0u8; hlit + hdist];
                let mut i = 0;
                while i < lens.len() {
                    let sym = cl.decode(br)?;
                    match sym {
                        0..=15 => {
                            lens[i] = sym as u8;
                            i += 1;
                        }
                        16 | 17 | 18 => {
                            let (fill, rep) = match sym {
                                16 => {
                                    if i == 0 {
                                        return Err(GzipError::Corrupt(
                                            "length repeat with no previous length",
                                        ));
                                    }
                                    (lens[i - 1], 3 + br.bits(2)? as usize)
                                }
                                17 => (0, 3 + br.bits(3)? as usize),
                                _ => (0, 11 + br.bits(7)? as usize),
                            };
                            if i + rep > lens.len() {
                                return Err(GzipError::Corrupt("too many code lengths"));
                            }
                            for slot in lens.iter_mut().skip(i).take(rep) {
                                *slot = fill;
                            }
                            i += rep;
                        }
                        _ => return Err(GzipError::Corrupt("invalid code-length symbol")),
                    }
                }
                if lens[256] == 0 {
                    return Err(GzipError::Corrupt("missing end-of-block code"));
                }
                let litlen = Huffman::build(&lens[..hlit])?;
                let dist = Huffman::build(&lens[hlit..])?;
                inflate_block(br, out, &litlen, &dist)?;
            }
            _ => return Err(GzipError::Corrupt("reserved block type")),
        }
        if bfinal == 1 {
            return Ok(());
        }
    }
}

/// Decompress a gzip file: one or more concatenated members (RFC 1952
/// §2.2 — `cat a.gz b.gz`, pigz, and bgzip all produce multi-member
/// files), each a header + DEFLATE body + CRC-32/ISIZE trailer. Both
/// trailer fields are verified per member. The whole plaintext lands in
/// one `Vec` (bounded by the inflated size; a streaming inflate is a
/// ROADMAP follow-on for traces larger than memory).
pub fn decompress(data: &[u8]) -> Result<Vec<u8>, GzipError> {
    let mut out = Vec::with_capacity(data.len().saturating_mul(3));
    let mut pos = 0usize;
    loop {
        pos = decompress_member(data, pos, &mut out)?;
        if pos >= data.len() {
            return Ok(out);
        }
        // Anything after a trailer must be another member (its magic is
        // re-checked by the next iteration); trailing garbage errors.
    }
}

/// Decompress the gzip member starting at `start`, appending its
/// plaintext to `out`. Returns the offset just past the member's trailer.
fn decompress_member(data: &[u8], start: usize, out: &mut Vec<u8>) -> Result<usize, GzipError> {
    let data = &data[start..];
    if data.len() < 2 {
        return Err(GzipError::Truncated);
    }
    if data[0] != 0x1f || data[1] != 0x8b {
        return Err(GzipError::BadMagic);
    }
    if data.len() < 10 {
        return Err(GzipError::Truncated);
    }
    if data[2] != 8 {
        return Err(GzipError::Unsupported("compression method is not DEFLATE"));
    }
    let flg = data[3];
    // MTIME(4) + XFL + OS already covered by the 10-byte header.
    let mut pos = 10usize;
    if flg & 0x04 != 0 {
        // FEXTRA: u16-le length + payload.
        let lo = *data.get(pos).ok_or(GzipError::Truncated)? as usize;
        let hi = *data.get(pos + 1).ok_or(GzipError::Truncated)? as usize;
        pos += 2 + (lo | (hi << 8));
    }
    for flag in [0x08u8, 0x10] {
        // FNAME / FCOMMENT: NUL-terminated strings.
        if flg & flag != 0 {
            loop {
                let b = *data.get(pos).ok_or(GzipError::Truncated)?;
                pos += 1;
                if b == 0 {
                    break;
                }
            }
        }
    }
    if flg & 0x02 != 0 {
        pos += 2; // FHCRC
    }
    if pos > data.len() {
        return Err(GzipError::Truncated);
    }
    let member_out = out.len();
    let mut br = BitReader::new(&data[pos..]);
    inflate(&mut br, out)?;
    // Trailer: CRC-32 then ISIZE (mod 2^32), both little-endian, starting
    // at the next byte boundary (the reader never buffers a whole byte).
    let trailer = &data[pos..];
    if trailer.len() < br.pos + 8 {
        return Err(GzipError::Truncated);
    }
    let t = &trailer[br.pos..br.pos + 8];
    let crc = u32::from_le_bytes([t[0], t[1], t[2], t[3]]);
    let isize_ = u32::from_le_bytes([t[4], t[5], t[6], t[7]]);
    if crc32(&out[member_out..]) != crc {
        return Err(GzipError::CrcMismatch);
    }
    if (out.len() - member_out) as u32 != isize_ {
        return Err(GzipError::SizeMismatch);
    }
    Ok(start + pos + br.pos + 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A handcrafted gzip member: one stored block holding "hello".
    fn hello_gz() -> Vec<u8> {
        let mut v = vec![
            0x1f, 0x8b, 0x08, 0x00, // magic, deflate, no flags
            0x00, 0x00, 0x00, 0x00, // mtime = 0
            0x00, 0x03, // xfl, os = unix
            0x01, // bfinal=1, btype=00 (stored)
            0x05, 0x00, 0xfa, 0xff, // LEN=5, NLEN=!5
        ];
        v.extend_from_slice(b"hello");
        v.extend_from_slice(&0x3610_a686u32.to_le_bytes()); // crc32("hello")
        v.extend_from_slice(&5u32.to_le_bytes()); // isize
        v
    }

    #[test]
    fn stored_block_roundtrip() {
        assert_eq!(decompress(&hello_gz()).unwrap(), b"hello");
    }

    #[test]
    fn multi_member_files_concatenate() {
        // RFC 1952 §2.2: a gzip file is a *series* of members
        // (`cat a.gz b.gz`, pigz, bgzip). All members must inflate, each
        // with its own verified trailer.
        let mut two = hello_gz();
        two.extend_from_slice(&hello_gz());
        assert_eq!(decompress(&two).unwrap(), b"hellohello");
        // Trailing garbage after the last member is an error, not silence.
        let mut garbage = hello_gz();
        garbage.extend_from_slice(b"tail");
        assert!(decompress(&garbage).is_err());
    }

    #[test]
    fn real_deflate_fixture_roundtrip() {
        // Produced by Python's gzip (dynamic-Huffman blocks) from the
        // bundled Alibaba fixture; must inflate to the exact plain bytes.
        let gz = include_bytes!("../../tests/fixtures/alibaba_mini.csv.gz");
        let plain = include_bytes!("../../tests/fixtures/alibaba_mini.csv");
        assert_eq!(decompress(gz).unwrap(), plain);
    }

    #[test]
    fn crc_detects_payload_corruption() {
        let mut gz = hello_gz();
        let idx = gz.len() - 9; // last payload byte ("o")
        gz[idx] ^= 0x20;
        assert_eq!(decompress(&gz), Err(GzipError::CrcMismatch));
    }

    #[test]
    fn truncation_and_magic_errors() {
        assert_eq!(decompress(&[]), Err(GzipError::Truncated));
        assert_eq!(decompress(&[0x1f, 0x8b, 0x08]), Err(GzipError::Truncated));
        assert_eq!(decompress(b"plain,csv,data"), Err(GzipError::BadMagic));
        let mut gz = hello_gz();
        gz.truncate(gz.len() - 4);
        assert_eq!(decompress(&gz), Err(GzipError::Truncated));
    }

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"hello"), 0x3610_a686);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
