//! Figure 3 — "Performance with different number of nodes": six panels
//! over 3/4/5 worker nodes × {Default, Layer, LRScheduler}:
//!   (a) CPU usage   (b) disk usage   (c) memory usage
//!   (d) max containers without image eviction
//!   (e) download cost   (f) dynamic-weight behaviour (ω₁/ω₂ usage)

use super::common;
use super::report;
use crate::cluster::Resources;
use crate::registry::Registry;
use crate::sim::{SchedulerChoice, SimConfig, Simulation, WorkloadConfig, WorkloadGen};
use crate::util::units::Bytes;

/// One (node count, scheduler) cell of the figure.
#[derive(Debug, Clone)]
pub struct Fig3Cell {
    /// Worker-node count of this run (3/4/5).
    pub n_nodes: usize,
    /// Scheduler label.
    pub scheduler: &'static str,
    /// (a) mean CPU utilisation across nodes at the end of the run.
    pub cpu_util: f64,
    /// (b) total disk used by image layers, MB.
    pub disk_mb: f64,
    /// (c) mean memory utilisation.
    pub mem_util: f64,
    /// (d) containers deployed before the first disk-capacity rejection.
    pub max_containers: usize,
    /// (e) total download cost, MB.
    pub download_mb: f64,
    /// (f) ω usage counts (0/0 for Default).
    pub omega1_used: u64,
    /// (f) ω₂ usage count.
    pub omega2_used: u64,
}

/// The full figure: one cell per (node count, scheduler) pair.
#[derive(Debug, Clone)]
pub struct Fig3 {
    /// Cells in (node count, scheduler) iteration order.
    pub cells: Vec<Fig3Cell>,
}

/// Deploy containers until disk capacity rejects one (Fig. 3d: "maximum
/// number of containers that can be deployed … without image eviction").
///
/// Containers are modeled per §III-A: a container is its image *plus a
/// unique writable layer* — so every deployment consumes disk even when
/// the image layers are fully shared. The probe registers one derived
/// image per container (base layers + a 64 MB writable layer) and deploys
/// until Eq. 6 rejects; layer-aware scheduling dedups the base layers and
/// therefore fits more containers.
fn max_containers(choice: SchedulerChoice, n_nodes: usize, seed: u64) -> usize {
    use crate::registry::{ImageMetadata, LayerMetadata};
    const WRITABLE_MB: f64 = 64.0;
    const CAP: usize = 4000;

    let mut registry = Registry::with_corpus();
    let bases: Vec<ImageMetadata> = registry.all_manifests().cloned().collect();
    let mut rng = crate::util::rng::Pcg::new(seed, 3);
    for i in 0..CAP {
        let base = rng.pick(&bases);
        let mut layers = base.layers.clone();
        layers.push(LayerMetadata {
            digest: format!("sha256:writable-{i:06}"),
            size: Bytes::from_mb(WRITABLE_MB),
        });
        registry.push(ImageMetadata::new(
            &format!("sha256:wl-{i:06}"),
            &format!("container-{i:06}"),
            "v1",
            layers,
        ));
    }

    let mut cfg = SimConfig::default();
    cfg.scheduler = choice;
    // One watcher poll at t=0 suffices (the 4k synthetic manifests are
    // static); re-polling every 10 sim-seconds would dominate the probe.
    cfg.watcher_interval_secs = f64::INFINITY;
    // Lift CPU/memory/maxPods so disk (Eq. 6) is the binding constraint.
    let nodes: Vec<_> = common::paper_nodes(n_nodes)
        .into_iter()
        .map(|mut n| {
            n.capacity.memory = crate::util::units::Bytes::from_gb(100_000.0);
            n.capacity.cpu = crate::util::units::MilliCpu::from_cores(100_000.0);
            n.with_max_containers(usize::MAX)
        })
        .collect();
    let mut sim = Simulation::new(nodes, registry, cfg);
    let mut builder = crate::cluster::PodBuilder::new();
    let mut deployed = 0;
    for i in 0..CAP {
        let pod = builder.build(
            &format!("container-{i:06}:v1"),
            Resources::new(crate::util::units::MilliCpu(10), Bytes(1_000_000)),
        );
        if !sim.deploy(pod) {
            break;
        }
        deployed += 1;
    }
    deployed
}

/// Regenerate the figure's data for a seeded workload.
pub fn run(seed: u64, n_pods: usize) -> Fig3 {
    let mut cells = Vec::new();
    for n_nodes in [3usize, 4, 5] {
        let trace = common::paper_trace(seed, n_pods);
        for report in common::run_all(n_nodes, &trace, |_| {}) {
            let last = report.snapshots.last().expect("nonempty run");
            let choice = match report.scheduler {
                "Default" => SchedulerChoice::Default,
                "Layer" => SchedulerChoice::Layer,
                _ => SchedulerChoice::LR,
            };
            cells.push(Fig3Cell {
                n_nodes,
                scheduler: report.scheduler,
                cpu_util: last.cpu_util,
                disk_mb: last.disk_used.as_mb(),
                mem_util: last.mem_util,
                max_containers: max_containers(choice, n_nodes, seed),
                download_mb: report.total_download().as_mb(),
                omega1_used: report.omega1_used,
                omega2_used: report.omega2_used,
            });
        }
    }
    Fig3 { cells }
}

impl Fig3 {
    /// Cell lookup (panics when absent).
    pub fn cell(&self, n_nodes: usize, scheduler: &str) -> &Fig3Cell {
        self.cells
            .iter()
            .find(|c| c.n_nodes == n_nodes && c.scheduler == scheduler)
            .expect("cell exists")
    }

    /// Disk-usage reduction vs. Default, averaged over node counts
    /// (the paper reports Layer −44%, LRScheduler −23%).
    pub fn disk_reduction_vs_default(&self, scheduler: &str) -> f64 {
        let mut total = 0.0;
        let mut k = 0;
        for n in [3usize, 4, 5] {
            let d = self.cell(n, "Default").disk_mb;
            let s = self.cell(n, scheduler).disk_mb;
            if d > 0.0 {
                total += 1.0 - s / d;
                k += 1;
            }
        }
        total / k as f64
    }

    /// Render the figure as an aligned text table.
    pub fn print(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .cells
            .iter()
            .map(|c| {
                vec![
                    c.n_nodes.to_string(),
                    c.scheduler.to_string(),
                    format!("{:.1}%", c.cpu_util * 100.0),
                    report::f1(c.disk_mb),
                    format!("{:.1}%", c.mem_util * 100.0),
                    c.max_containers.to_string(),
                    report::f1(c.download_mb),
                    format!("{}/{}", c.omega1_used, c.omega2_used),
                ]
            })
            .collect();
        let mut out = String::from("Fig. 3 — performance with different number of nodes\n");
        out.push_str(&report::table(
            &["nodes", "scheduler", "cpu(a)", "disk MB(b)", "mem(c)", "max#(d)", "dl MB(e)", "w1/w2(f)"],
            &rows,
        ));
        out.push_str(&format!(
            "\ndisk reduction vs Default: Layer {:.0}%, LRScheduler {:.0}%  (paper: 44%, 23%)\n",
            self.disk_reduction_vs_default("Layer") * 100.0,
            self.disk_reduction_vs_default("LRScheduler") * 100.0,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds() {
        let fig = run(42, 20);
        assert_eq!(fig.cells.len(), 9);
        for n in [3usize, 4, 5] {
            let def = fig.cell(n, "Default");
            let layer = fig.cell(n, "Layer");
            let lr = fig.cell(n, "LRScheduler");
            // (b)/(e): layer-aware schedulers download and store less.
            assert!(lr.download_mb < def.download_mb, "n={n}");
            assert!(layer.disk_mb < def.disk_mb, "n={n}");
            assert!(lr.disk_mb < def.disk_mb, "n={n}");
            // (a)/(c): CPU and memory usage are within a few points of each
            // other (same pods land somewhere).
            assert!((lr.cpu_util - def.cpu_util).abs() < 0.25, "n={n}");
            assert!((lr.mem_util - def.mem_util).abs() < 0.25, "n={n}");
            // (d): layer sharing lets more containers fit before disk fills.
            assert!(lr.max_containers >= def.max_containers, "n={n}");
            // (f): LR actually exercises both weights over 20 pods.
            assert_eq!(lr.omega1_used + lr.omega2_used, 20);
            assert_eq!(def.omega1_used + def.omega2_used, 0);
        }
    }
}
