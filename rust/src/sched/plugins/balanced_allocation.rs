//! NodeResourcesBalancedAllocation — the default plugin the paper combines
//! with (§I, [23]): prefer nodes whose CPU and memory utilisation would be
//! most *balanced* after placing the pod.
//!
//! Upstream formula: with fractions f_i = (used_i + req_i) / cap_i,
//! score = (1 − std(f)) × 100 where std is the population standard
//! deviation over the resource dimensions.

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{ScorePlugin, MAX_NODE_SCORE};

/// NodeResourcesBalancedAllocation: favor nodes whose CPU and memory
/// utilisation stay close to each other after placement.
pub struct BalancedAllocation;

impl ScorePlugin for BalancedAllocation {
    fn name(&self) -> &'static str {
        "NodeResourcesBalancedAllocation"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        let after = node.used.checked_add(&ctx.pod.requests);
        let (cpu, mem) = after.fraction_of(&node.capacity);
        let (cpu, mem) = (cpu.min(1.0), mem.min(1.0));
        let mean = (cpu + mem) / 2.0;
        let variance = ((cpu - mean).powi(2) + (mem - mean).powi(2)) / 2.0;
        (1.0 - variance.sqrt()) * MAX_NODE_SCORE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::{Bandwidth, Bytes};

    fn node() -> Node {
        Node::new(
            NodeId(0),
            "n",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        )
    }

    #[test]
    fn perfectly_balanced_scores_100() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::cores_gb(1.0, 1.0));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        // 25% cpu, 25% mem after placement → zero deviation.
        assert!((BalancedAllocation.score(&ctx, &node()) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn imbalance_lowers_score() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::cores_gb(2.0, 0.0));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        // 50% cpu, 0% mem → std = 0.25 → score 75.
        let s = BalancedAllocation.score(&ctx, &node());
        assert!((s - 75.0).abs() < 1e-9);
    }

    #[test]
    fn balanced_beats_lopsided() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::cores_gb(0.5, 0.5));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let even = node();
        let mut lopsided = node();
        lopsided.used = Resources::cores_gb(3.0, 0.0);
        assert!(BalancedAllocation.score(&ctx, &even) > BalancedAllocation.score(&ctx, &lopsided));
    }
}
