//! VolumeBinding — "verifies if the node can bind the requested volumes,
//! prioritizing the smallest volume that meets the required size"
//! (paper §IV-B).
//!
//! Filter: the sum of the pod's claims must fit the node's remaining volume
//! capacity. Score: tighter fit scores higher (bin-packing preference for
//! the smallest satisfying volume), neutral 100 when the pod has no claims.

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{FilterPlugin, FilterResult, ScorePlugin, MAX_NODE_SCORE};
use crate::util::units::Bytes;

fn claimed(ctx: &CycleContext) -> Bytes {
    ctx.pod.volume_claims.iter().map(|c| c.size).sum()
}

/// VolumeBinding filter: claimed volumes must fit the node's volume
/// capacity.
pub struct VolumeBindingFilter;

impl FilterPlugin for VolumeBindingFilter {
    fn name(&self) -> &'static str {
        "VolumeBinding"
    }

    fn filter(&self, ctx: &CycleContext, node: &Node) -> FilterResult {
        let need = claimed(ctx);
        if need > node.volume_capacity {
            return FilterResult::Reject(format!(
                "volume claims {} exceed capacity {}",
                need, node.volume_capacity
            ));
        }
        FilterResult::Pass
    }
}

/// VolumeBinding score: favor nodes with more volume headroom.
pub struct VolumeBindingScore;

impl ScorePlugin for VolumeBindingScore {
    fn name(&self) -> &'static str {
        "VolumeBinding"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        let need = claimed(ctx);
        if need == Bytes::ZERO {
            return MAX_NODE_SCORE; // no claims: every node is equally fine
        }
        if node.volume_capacity == Bytes::ZERO || need > node.volume_capacity {
            return 0.0;
        }
        // Fit ratio: claims / capacity — 1.0 is a perfect (smallest) fit.
        MAX_NODE_SCORE * (need.0 as f64 / node.volume_capacity.0 as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::Bandwidth;

    fn node_with_volume(id: u32, gb: f64) -> Node {
        let mut n = Node::new(
            NodeId(id),
            &format!("n{id}"),
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        );
        n.volume_capacity = Bytes::from_gb(gb);
        n
    }

    #[test]
    fn filter_rejects_oversize_claims() {
        let state = ClusterState::new();
        let pod = PodBuilder::new()
            .build("mysql:8.2", Resources::ZERO)
            .with_volume(Bytes::from_gb(10.0));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        assert!(matches!(
            VolumeBindingFilter.filter(&ctx, &node_with_volume(0, 5.0)),
            FilterResult::Reject(_)
        ));
        assert_eq!(
            VolumeBindingFilter.filter(&ctx, &node_with_volume(1, 20.0)),
            FilterResult::Pass
        );
    }

    #[test]
    fn tighter_fit_scores_higher() {
        let state = ClusterState::new();
        let pod = PodBuilder::new()
            .build("mysql:8.2", Resources::ZERO)
            .with_volume(Bytes::from_gb(10.0));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let tight = VolumeBindingScore.score(&ctx, &node_with_volume(0, 12.0));
        let loose = VolumeBindingScore.score(&ctx, &node_with_volume(1, 100.0));
        assert!(tight > loose);
        assert!(tight <= 100.0);
    }

    #[test]
    fn no_claims_is_neutral() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis:7.2", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        assert_eq!(VolumeBindingScore.score(&ctx, &node_with_volume(0, 1.0)), 100.0);
        assert_eq!(
            VolumeBindingFilter.filter(&ctx, &node_with_volume(0, 0.0)),
            FilterResult::Pass
        );
    }
}
