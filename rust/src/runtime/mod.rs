//! The PJRT runtime: loads the AOT-compiled JAX/Pallas scoring artifacts
//! (HLO text) and serves them on the scheduling hot path. Python never
//! runs here — `make artifacts` is the only build-time Python step.
//!
//! The real runtime needs the external `xla` + `anyhow` crates and is
//! compiled only with `--features xla`. The default build ships a stub
//! [`XlaScorer`] with the same surface whose loaders report the backend as
//! unavailable, so every caller (CLI `--backend xla`, benches, e2e tests)
//! degrades to the native scorer instead of failing to compile.

#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub mod scorer;

#[cfg(feature = "xla")]
pub use pjrt::PjRt;
#[cfg(feature = "xla")]
pub use scorer::XlaScorer;

#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaScorer;
