//! The PJRT runtime: loads the AOT-compiled JAX/Pallas scoring artifacts
//! (HLO text) and serves them on the scheduling hot path. Python never
//! runs here — `make artifacts` is the only build-time Python step.
//!
//! Three build shapes (see `ffi.rs`):
//! - default: the PJRT code is compiled out; a stub [`XlaScorer`] with
//!   the same surface reports the backend unavailable, so every caller
//!   (CLI `--backend xla`, benches, e2e tests) degrades to the native
//!   scorer instead of failing to compile;
//! - `--features xla`: the *real* `pjrt.rs`/`scorer.rs` compile against
//!   the vendored type-level shim in `ffi.rs` (CI checks this, so the
//!   PJRT path cannot rot unbuilt) and still report unavailable at
//!   runtime;
//! - `--features xla,xla-external`: binds to the real external `xla` +
//!   `anyhow` crates (added to `[dependencies]` by hand) for an actual
//!   PJRT backend.

#[cfg(feature = "xla")]
pub mod ffi;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub mod scorer;

#[cfg(feature = "xla")]
pub use pjrt::PjRt;
#[cfg(feature = "xla")]
pub use scorer::XlaScorer;

#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaScorer;
