//! The streaming arrival pipeline: every workload enters the engine
//! through one pull-based interface.
//!
//! [`ArrivalSource`] is the spine of the constant-memory ingestion path:
//! instead of materializing a whole trace as `Vec<(offset, Pod)>` and
//! enqueuing every arrival up front (one heap entry per pod — gigabytes
//! at multi-million-pod scale), [`crate::sim::Simulation::run_source`]
//! pulls **one arrival at a time**, only when the virtual clock reaches
//! it. Three producers implement the trait:
//!
//! - [`WorkloadSource`] — the synthetic Zipf/churn generator
//!   ([`crate::sim::workload::WorkloadGen`]), made lazy: pods are built
//!   at pull time instead of pre-materialized.
//! - [`crate::sim::trace::TraceSource`] — the Alibaba/Azure/Borg trace
//!   importers, streaming line-by-line over any reader (through the
//!   streaming gzip decoder for `.csv.gz`) with a bounded reorder
//!   buffer.
//! - [`VecSource`] — the buffered compatibility adapter wrapping an
//!   explicit `Vec<(offset, Pod)>`; it is what
//!   [`crate::sim::Simulation::run_arrivals`] uses, and the reference
//!   the differential tests hold the streaming path byte-identical to.
//! - [`StreamSource`] — the live half of `lrsched serve`: a shared
//!   queue a [`StreamHandle`] pushes protocol-delivered pods into while
//!   the engine pulls from the other end, so an online session drives
//!   the *same* arrival pipeline as a batch replay.
//!
//! **Contract:** offsets are seconds relative to replay start, must be
//! finite, and must be non-decreasing across successive pulls — the
//! engine schedules each arrival as it learns about it and cannot
//! reorder the future. `VecSource` establishes the invariant by
//! clamping negative offsets to zero and stable-sorting; the trace
//! sources establish it with their reorder buffer; the workload source
//! is monotone by construction.

use super::workload::WorkloadGen;
use crate::cluster::Pod;

/// A pull-based producer of timed pod arrivals (see the module docs for
/// the offset contract).
pub trait ArrivalSource {
    /// The next `(arrival-offset, pod)` pair, or `None` when the
    /// workload is exhausted. Offsets are seconds from replay start,
    /// finite and non-decreasing.
    fn next_arrival(&mut self) -> Option<(f64, Pod)>;
}

/// Buffered adapter: replays an explicit `Vec<(offset, Pod)>` as an
/// [`ArrivalSource`]. Negative offsets clamp to zero and the vector is
/// stable-sorted by clamped offset, reproducing exactly the order the
/// event heap would have popped the same arrivals in when they were all
/// enqueued up front (equal offsets keep their vector order).
pub struct VecSource {
    /// Sorted arrivals, consumed front to back.
    items: std::vec::IntoIter<(f64, Pod)>,
}

impl VecSource {
    /// Wrap (and normalize) an explicit arrival list.
    pub fn new(mut arrivals: Vec<(f64, Pod)>) -> VecSource {
        for (off, _) in &mut arrivals {
            *off = off.max(0.0);
        }
        // Stable: equal offsets keep the input order, matching the event
        // queue's FIFO tie-break at equal (time, class).
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite arrival offsets"));
        VecSource { items: arrivals.into_iter() }
    }
}

impl ArrivalSource for VecSource {
    fn next_arrival(&mut self) -> Option<(f64, Pod)> {
        self.items.next()
    }
}

/// Lazy synthetic workload: `count` pods from a [`WorkloadGen`], arriving
/// every `dt` seconds. Pod `i` is generated when pulled (identical to
/// `gen.trace(count)` pre-materialized — the generator is deterministic —
/// but without holding `count` pods in memory).
pub struct WorkloadSource {
    gen: WorkloadGen,
    dt: f64,
    next: usize,
    count: usize,
}

impl WorkloadSource {
    /// Wrap `gen`, emitting `count` pods at a fixed `dt`-second cadence.
    pub fn new(gen: WorkloadGen, dt: f64, count: usize) -> WorkloadSource {
        assert!(dt.is_finite() && dt >= 0.0, "arrival cadence must be finite and non-negative");
        WorkloadSource { gen, dt, next: 0, count }
    }
}

impl ArrivalSource for WorkloadSource {
    fn next_arrival(&mut self) -> Option<(f64, Pod)> {
        if self.next >= self.count {
            return None;
        }
        let i = self.next;
        self.next += 1;
        Some((i as f64 * self.dt, self.gen.next_pod()))
    }
}

/// The engine end of a live serve session: an [`ArrivalSource`] fed
/// incrementally through its paired [`StreamHandle`]. Construction hands
/// back both halves; the source goes into
/// [`crate::sim::Simulation::open_stream`] and the handle stays with the
/// session loop, which pushes one pod per protocol event and then pumps
/// the engine. Returning `None` here means "no arrival *yet*" — unlike
/// the batch sources, exhaustion is signalled by the session closing the
/// stream, not by the source.
pub struct StreamSource {
    queue: std::rc::Rc<std::cell::RefCell<std::collections::VecDeque<(f64, Pod)>>>,
}

/// The feeding end of a [`StreamSource`] (see there). Offsets follow the
/// [`ArrivalSource`] contract: seconds from session start, finite,
/// non-decreasing — the protocol codec enforces monotone timestamps
/// before anything reaches this handle.
pub struct StreamHandle {
    queue: std::rc::Rc<std::cell::RefCell<std::collections::VecDeque<(f64, Pod)>>>,
}

impl StreamSource {
    /// Create a connected `(source, handle)` pair.
    pub fn channel() -> (StreamSource, StreamHandle) {
        let queue = std::rc::Rc::new(std::cell::RefCell::new(std::collections::VecDeque::new()));
        (StreamSource { queue: queue.clone() }, StreamHandle { queue })
    }
}

impl StreamHandle {
    /// Queue one arrival for the engine to pull (clamping a negative
    /// offset to zero, like [`VecSource`]).
    pub fn push(&self, offset: f64, pod: Pod) {
        self.queue.borrow_mut().push_back((offset.max(0.0), pod));
    }

    /// Arrivals pushed but not yet pulled by the engine.
    pub fn pending(&self) -> usize {
        self.queue.borrow().len()
    }
}

impl ArrivalSource for StreamSource {
    fn next_arrival(&mut self) -> Option<(f64, Pod)> {
        self.queue.borrow_mut().pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{PodBuilder, Resources};
    use crate::registry::Registry;
    use crate::sim::workload::WorkloadConfig;

    fn drain(src: &mut dyn ArrivalSource) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some((off, pod)) = src.next_arrival() {
            out.push((off, pod.id.0));
        }
        out
    }

    #[test]
    fn vec_source_clamps_and_stable_sorts() {
        let mut b = PodBuilder::new();
        let arrivals = vec![
            (5.0, b.build("redis:7.2", Resources::ZERO)),   // id 0
            (-1.0, b.build("redis:7.2", Resources::ZERO)),  // id 1 → clamps to 0
            (0.0, b.build("redis:7.2", Resources::ZERO)),   // id 2, ties with id 1
            (2.0, b.build("redis:7.2", Resources::ZERO)),   // id 3
        ];
        let mut src = VecSource::new(arrivals);
        let order = drain(&mut src);
        // Clamped-equal offsets keep vector order (1 before 2).
        assert_eq!(order, vec![(0.0, 1), (0.0, 2), (2.0, 3), (5.0, 0)]);
        assert!(src.next_arrival().is_none(), "exhausted source stays exhausted");
    }

    #[test]
    fn workload_source_matches_materialized_trace() {
        let reg = Registry::with_corpus();
        let cfg = WorkloadConfig::default();
        let expected = WorkloadGen::new(&reg, cfg.clone()).trace(12);
        let mut src = WorkloadSource::new(WorkloadGen::new(&reg, cfg), 0.3, 12);
        let mut n = 0;
        while let Some((off, pod)) = src.next_arrival() {
            assert_eq!(off, n as f64 * 0.3);
            assert_eq!(pod.image, expected[n].image, "pod {n}");
            assert_eq!(pod.requests, expected[n].requests, "pod {n}");
            n += 1;
        }
        assert_eq!(n, 12);
    }
}
