//! The scheduling framework — extension points, plugin traits, and the
//! filter → score → normalize → weighted-sum pipeline, mirroring the
//! Kubernetes scheduling framework the paper builds on (§I, [20]):
//! "The filter extension point eliminates nodes that cannot run the
//! container. The score plugin then ranks the remaining nodes. The
//! scheduler calls each scoring extension point for every node."

use super::context::CycleContext;
use crate::cluster::{Node, NodeId};
use crate::sim::shard::{par_fill, LanePool};

/// Maximum plugin score, as in Kubernetes (`framework.MaxNodeScore`).
pub const MAX_NODE_SCORE: f64 = 100.0;

/// Outcome of a filter plugin for one node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FilterResult {
    /// Node can host the pod.
    Pass,
    /// Node rejected with a human-readable reason (surfaces in events).
    Reject(String),
}

/// Filter extension point (also covers PreFilter checks — with single-pod
/// cycles the distinction is only a caching optimization upstream).
/// Plugins must be `Send + Sync`: the sharded engine fans the per-node
/// filter pass across worker threads (plugins are stateless structs, so
/// this is free).
pub trait FilterPlugin: Send + Sync {
    /// Plugin name as surfaced in rejection reasons.
    fn name(&self) -> &'static str;
    /// Can `node` host the cycle's pod?
    fn filter(&self, ctx: &CycleContext, node: &Node) -> FilterResult;
}

/// Score extension point. `score` returns a raw value per node; `normalize`
/// then maps the raw vector to [0, MAX_NODE_SCORE] (identity by default,
/// matching plugins that already emit 0–100). `Send + Sync` for the same
/// reason as [`FilterPlugin`]: per-node `score` calls fan out across
/// worker threads in the sharded engine (`normalize` stays coordinator-
/// side, it couples nodes).
pub trait ScorePlugin: Send + Sync {
    /// Plugin name as surfaced in score breakdowns.
    fn name(&self) -> &'static str;
    /// Raw score for one node.
    fn score(&self, ctx: &CycleContext, node: &Node) -> f64;
    /// Map the raw vector to [0, MAX_NODE_SCORE] (identity by default).
    fn normalize(&self, _ctx: &CycleContext, _scores: &mut [f64]) {}
}

/// Rescale a raw score vector so its max maps to MAX_NODE_SCORE — the
/// default NormalizeScore shape used by several upstream plugins.
pub fn normalize_by_max(scores: &mut [f64]) {
    let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if max > 0.0 {
        for s in scores.iter_mut() {
            *s = *s / max * MAX_NODE_SCORE;
        }
    }
}

/// Invert + rescale: lowest raw value gets MAX_NODE_SCORE (for plugins
/// where raw = badness, e.g. intolerable taints, topology skew).
pub fn normalize_inverse(scores: &mut [f64]) {
    let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if max > 0.0 {
        for s in scores.iter_mut() {
            *s = (max - *s) / max * MAX_NODE_SCORE;
        }
    } else {
        for s in scores.iter_mut() {
            *s = MAX_NODE_SCORE;
        }
    }
}

/// Why a pod could not be scheduled.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Unschedulable {
    /// (node name, rejecting plugin, reason) per filtered node.
    pub rejections: Vec<(String, &'static str, String)>,
}

impl std::fmt::Display for Unschedulable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "0/{} nodes available", self.rejections.len())?;
        for (node, plugin, reason) in &self.rejections {
            write!(f, "; {node}: {plugin}: {reason}")?;
        }
        Ok(())
    }
}

/// A scheduler framework profile: ordered filters plus weighted scorers.
pub struct Framework {
    /// Profile name (e.g. `default`, `lrscheduler`).
    pub profile_name: String,
    filters: Vec<Box<dyn FilterPlugin>>,
    scorers: Vec<(Box<dyn ScorePlugin>, f64)>,
}

/// Per-node score detail for observability and the experiment reports.
/// The winning node's `breakdown` travels with the bind — it is carried
/// on [`crate::sim::DecisionDetail`] and exported, plugin by plugin, on
/// every `lrsched serve` decision line (`docs/SERVE.md`).
#[derive(Debug, Clone)]
pub struct NodeScore {
    /// The scored node.
    pub node: NodeId,
    /// Weighted sum over all score plugins after normalization.
    pub total: f64,
    /// (plugin name, normalized score) breakdown.
    pub breakdown: Vec<(&'static str, f64)>,
}

impl Framework {
    /// An empty profile.
    pub fn new(profile_name: &str) -> Framework {
        Framework { profile_name: profile_name.to_string(), filters: Vec::new(), scorers: Vec::new() }
    }

    /// Builder: append a filter plugin.
    pub fn add_filter(mut self, plugin: Box<dyn FilterPlugin>) -> Framework {
        self.filters.push(plugin);
        self
    }

    /// Builder: append a score plugin with its weight.
    pub fn add_scorer(mut self, plugin: Box<dyn ScorePlugin>, weight: f64) -> Framework {
        self.scorers.push((plugin, weight));
        self
    }

    /// Names of the registered score plugins, in order.
    pub fn scorer_names(&self) -> Vec<&'static str> {
        self.scorers.iter().map(|(p, _)| p.name()).collect()
    }

    /// Run the filter extension points. Returns feasible node ids, or the
    /// full rejection list when none pass.
    pub fn feasible(&self, ctx: &CycleContext) -> Result<Vec<NodeId>, Unschedulable> {
        let mut feasible = Vec::new();
        let mut rejections = Vec::new();
        'nodes: for node in ctx.state.nodes() {
            // NodeUnschedulable analog: drained/crashed nodes never pass,
            // regardless of profile (hard lifecycle constraint, so it lives
            // in the framework rather than a toggleable plugin).
            if !node.is_schedulable() {
                let why = if node.is_up() { "node is draining" } else { "node is down" };
                rejections.push((node.name.clone(), "NodeUnschedulable", why.to_string()));
                continue 'nodes;
            }
            for f in &self.filters {
                if let FilterResult::Reject(reason) = f.filter(ctx, node) {
                    rejections.push((node.name.clone(), f.name(), reason));
                    continue 'nodes;
                }
            }
            feasible.push(node.id);
        }
        if feasible.is_empty() {
            Err(Unschedulable { rejections })
        } else {
            Ok(feasible)
        }
    }

    /// Run score + normalize + weighted sum over `feasible`. This is the
    /// default-scheduler score S_K8s of Eq. (4).
    pub fn score(&self, ctx: &CycleContext, feasible: &[NodeId]) -> Vec<NodeScore> {
        let mut totals: Vec<NodeScore> = feasible
            .iter()
            .map(|&n| NodeScore { node: n, total: 0.0, breakdown: Vec::new() })
            .collect();
        let mut raw = vec![0.0f64; feasible.len()];
        for (plugin, weight) in &self.scorers {
            for (i, &nid) in feasible.iter().enumerate() {
                raw[i] = plugin.score(ctx, ctx.state.node(nid));
            }
            plugin.normalize(ctx, &mut raw);
            for (i, ns) in totals.iter_mut().enumerate() {
                debug_assert!(
                    (-1e-9..=MAX_NODE_SCORE + 1e-9).contains(&raw[i]),
                    "{} emitted out-of-range score {}",
                    plugin.name(),
                    raw[i]
                );
                ns.total += weight * raw[i];
                ns.breakdown.push((plugin.name(), raw[i]));
            }
        }
        totals
    }

    /// Filter + score in one call.
    pub fn run(&self, ctx: &CycleContext) -> Result<Vec<NodeScore>, Unschedulable> {
        let feasible = self.feasible(ctx)?;
        Ok(self.score(ctx, &feasible))
    }

    /// [`Framework::feasible`], with the per-node filter pass fanned out
    /// across `pool`. Per-node filter outcomes are pure functions of
    /// (plugins, ctx, node) and land at fixed indices, and the feasible /
    /// rejection lists are then assembled in node order on the calling
    /// thread — so the result is bit-identical to the sequential pass.
    pub fn feasible_with_pool(
        &self,
        ctx: &CycleContext,
        pool: &LanePool,
    ) -> Result<Vec<NodeId>, Unschedulable> {
        let nodes = ctx.state.nodes();
        let mut verdicts: Vec<Option<(&'static str, String)>> = vec![None; nodes.len()];
        par_fill(pool, &mut verdicts, &|i, out| {
            let node = &nodes[i];
            *out = if !node.is_schedulable() {
                // NodeUnschedulable analog, exactly as in `feasible`.
                let why = if node.is_up() { "node is draining" } else { "node is down" };
                Some(("NodeUnschedulable", why.to_string()))
            } else {
                let mut rejection = None;
                for f in &self.filters {
                    if let FilterResult::Reject(reason) = f.filter(ctx, node) {
                        rejection = Some((f.name(), reason));
                        break;
                    }
                }
                rejection
            };
        });
        let mut feasible = Vec::new();
        let mut rejections = Vec::new();
        for (node, verdict) in nodes.iter().zip(verdicts) {
            match verdict {
                None => feasible.push(node.id),
                Some((plugin, reason)) => rejections.push((node.name.clone(), plugin, reason)),
            }
        }
        if feasible.is_empty() {
            Err(Unschedulable { rejections })
        } else {
            Ok(feasible)
        }
    }

    /// [`Framework::score`], with the raw per-node `score` calls of every
    /// plugin fanned out across `pool` in one pass. Normalization and the
    /// weighted accumulation — the parts that couple nodes — run on the
    /// calling thread over the same vectors in the same order, so totals
    /// and breakdowns are bit-identical to the sequential pass.
    pub fn score_with_pool(
        &self,
        ctx: &CycleContext,
        feasible: &[NodeId],
        pool: &LanePool,
    ) -> Vec<NodeScore> {
        let m = self.scorers.len();
        // One flat row-major (node × plugin) matrix: the sequential pass
        // makes two allocations per cycle and the fan-out must not add
        // per-node ones on the hot path.
        let mut raw_matrix = vec![0.0f64; feasible.len() * m];
        crate::sim::shard::par_fill_rows(pool, &mut raw_matrix, m, &|i, row| {
            let node = ctx.state.node(feasible[i]);
            for (p_idx, (plugin, _)) in self.scorers.iter().enumerate() {
                row[p_idx] = plugin.score(ctx, node);
            }
        });
        let mut totals: Vec<NodeScore> = feasible
            .iter()
            .map(|&n| NodeScore { node: n, total: 0.0, breakdown: Vec::new() })
            .collect();
        let mut raw = vec![0.0f64; feasible.len()];
        for (p_idx, (plugin, weight)) in self.scorers.iter().enumerate() {
            for i in 0..feasible.len() {
                raw[i] = raw_matrix[i * m + p_idx];
            }
            plugin.normalize(ctx, &mut raw);
            for (i, ns) in totals.iter_mut().enumerate() {
                debug_assert!(
                    (-1e-9..=MAX_NODE_SCORE + 1e-9).contains(&raw[i]),
                    "{} emitted out-of-range score {}",
                    plugin.name(),
                    raw[i]
                );
                ns.total += weight * raw[i];
                ns.breakdown.push((plugin.name(), raw[i]));
            }
        }
        totals
    }
}

/// Pick the argmax by total score; ties break by node id for determinism
/// (upstream uses reservoir sampling — determinism matters more here for
/// reproducible experiments).
pub fn select_best(scores: &[NodeScore]) -> Option<&NodeScore> {
    scores
        .iter()
        .max_by(|a, b| match a.total.partial_cmp(&b.total).unwrap() {
            std::cmp::Ordering::Equal => b.node.0.cmp(&a.node.0),
            o => o,
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, Pod, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::{Bandwidth, Bytes};

    struct RejectOdd;
    impl FilterPlugin for RejectOdd {
        fn name(&self) -> &'static str {
            "RejectOdd"
        }
        fn filter(&self, _ctx: &CycleContext, node: &Node) -> FilterResult {
            if node.id.0 % 2 == 1 {
                FilterResult::Reject("odd".into())
            } else {
                FilterResult::Pass
            }
        }
    }

    struct IdScore;
    impl ScorePlugin for IdScore {
        fn name(&self) -> &'static str {
            "IdScore"
        }
        fn score(&self, _ctx: &CycleContext, node: &Node) -> f64 {
            node.id.0 as f64
        }
        fn normalize(&self, _ctx: &CycleContext, scores: &mut [f64]) {
            normalize_by_max(scores);
        }
    }

    fn setup(n: u32) -> (ClusterState, Pod) {
        let mut state = ClusterState::new();
        for i in 0..n {
            state.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(20.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        let pod = PodBuilder::new().build("redis:7.2", Resources::cores_gb(0.5, 0.5));
        (state, pod)
    }

    fn ctx<'a>(state: &'a ClusterState, pod: &'a Pod) -> CycleContext<'a> {
        CycleContext::new(state, pod, None, LayerSet::new(), Bytes::ZERO)
    }

    #[test]
    fn filter_then_score() {
        let (state, pod) = setup(4);
        let c = ctx(&state, &pod);
        let fw = Framework::new("test")
            .add_filter(Box::new(RejectOdd))
            .add_scorer(Box::new(IdScore), 1.0);
        let scores = fw.run(&c).unwrap();
        let ids: Vec<u32> = scores.iter().map(|s| s.node.0).collect();
        assert_eq!(ids, vec![0, 2]);
        // normalize_by_max: node2 -> 100, node0 -> 0.
        assert_eq!(scores[1].total, 100.0);
        assert_eq!(scores[0].total, 0.0);
        assert_eq!(select_best(scores.as_slice()).unwrap().node, NodeId(2));
    }

    #[test]
    fn all_filtered_is_unschedulable() {
        let (mut state, pod) = setup(0);
        state.add_node(Node::new(
            NodeId(0),
            "only-odd-like",
            Resources::cores_gb(1.0, 1.0),
            Bytes::from_gb(1.0),
            Bandwidth::from_mbps(1.0),
        ));
        struct RejectAll;
        impl FilterPlugin for RejectAll {
            fn name(&self) -> &'static str {
                "RejectAll"
            }
            fn filter(&self, _: &CycleContext, _: &Node) -> FilterResult {
                FilterResult::Reject("no".into())
            }
        }
        let c = ctx(&state, &pod);
        let fw = Framework::new("test").add_filter(Box::new(RejectAll));
        let err = fw.run(&c).unwrap_err();
        assert_eq!(err.rejections.len(), 1);
        assert!(err.to_string().contains("RejectAll"));
    }

    #[test]
    fn draining_and_down_nodes_never_feasible() {
        let (mut state, pod) = setup(3);
        state.drain_node(NodeId(0));
        state.crash_node(NodeId(2));
        let c = ctx(&state, &pod);
        let fw = Framework::new("test"); // no plugins: only the lifecycle gate
        let feasible = fw.feasible(&c).unwrap();
        assert_eq!(feasible, vec![NodeId(1)]);

        let mut state2 = state.clone();
        state2.drain_node(NodeId(1));
        let c2 = ctx(&state2, &pod);
        let err = fw.feasible(&c2).unwrap_err();
        assert_eq!(err.rejections.len(), 3);
        assert!(err.rejections.iter().all(|(_, p, _)| *p == "NodeUnschedulable"));
        assert!(err.to_string().contains("draining"));
        assert!(err.to_string().contains("down"));
    }

    #[test]
    fn weights_scale_scores() {
        let (state, pod) = setup(2);
        let c = ctx(&state, &pod);
        let fw = Framework::new("test").add_scorer(Box::new(IdScore), 2.0);
        let scores = fw.run(&c).unwrap();
        assert_eq!(scores[1].total, 200.0);
    }

    #[test]
    fn tie_break_prefers_lower_node_id() {
        struct Flat;
        impl ScorePlugin for Flat {
            fn name(&self) -> &'static str {
                "Flat"
            }
            fn score(&self, _: &CycleContext, _: &Node) -> f64 {
                50.0
            }
        }
        let (state, pod) = setup(3);
        let c = ctx(&state, &pod);
        let fw = Framework::new("test").add_scorer(Box::new(Flat), 1.0);
        let scores = fw.run(&c).unwrap();
        assert_eq!(select_best(&scores).unwrap().node, NodeId(0));
    }

    #[test]
    fn pooled_passes_match_sequential_bit_for_bit() {
        use crate::sim::shard::LanePool;
        let (mut state, pod) = setup(9);
        state.drain_node(NodeId(4));
        let c = ctx(&state, &pod);
        let fw = Framework::new("test")
            .add_filter(Box::new(RejectOdd))
            .add_scorer(Box::new(IdScore), 1.5);
        let pool = LanePool::new(3);

        let seq = fw.feasible(&c).unwrap();
        let par = fw.feasible_with_pool(&c, &pool).unwrap();
        assert_eq!(seq, par);

        let s_seq = fw.score(&c, &seq);
        let s_par = fw.score_with_pool(&c, &par, &pool);
        assert_eq!(s_seq.len(), s_par.len());
        for (a, b) in s_seq.iter().zip(&s_par) {
            assert_eq!(a.node, b.node);
            assert_eq!(a.total.to_bits(), b.total.to_bits(), "totals must be bit-identical");
            assert_eq!(a.breakdown, b.breakdown);
        }

        // All-rejected: the pooled pass produces the same rejection list.
        struct RejectAll2;
        impl FilterPlugin for RejectAll2 {
            fn name(&self) -> &'static str {
                "RejectAll2"
            }
            fn filter(&self, _: &CycleContext, _: &Node) -> FilterResult {
                FilterResult::Reject("no".into())
            }
        }
        let fw2 = Framework::new("test").add_filter(Box::new(RejectAll2));
        let e_seq = fw2.feasible(&c).unwrap_err();
        let e_par = fw2.feasible_with_pool(&c, &pool).unwrap_err();
        assert_eq!(e_seq, e_par);
    }

    #[test]
    fn normalize_helpers() {
        let mut v = vec![1.0, 2.0, 4.0];
        normalize_by_max(&mut v);
        assert_eq!(v, vec![25.0, 50.0, 100.0]);
        let mut w = vec![0.0, 3.0, 6.0];
        normalize_inverse(&mut w);
        assert_eq!(w, vec![100.0, 50.0, 0.0]);
        let mut z = vec![0.0, 0.0];
        normalize_inverse(&mut z);
        assert_eq!(z, vec![100.0, 100.0]);
    }
}
