//! Compile-time facade over the external `xla` and `anyhow` crates.
//!
//! The PJRT runtime (`pjrt.rs` + `scorer.rs`) is feature-gated behind
//! `xla`, but its external crates are deliberately not declared as cargo
//! dependencies (default builds must resolve offline). Before this
//! facade, that meant the PJRT code only compiled on machines that had
//! hand-added the crates — it could rot silently. Now `pjrt.rs` and
//! `scorer.rs` import through here:
//!
//! - `--features xla` (CI's `cargo check --features xla`): the vendored
//!   shim below provides the exact API surface the runtime uses, with
//!   every constructor reporting the backend unavailable at runtime — so
//!   the real PJRT code *type-checks* on every CI run without network
//!   access, and behaves like the no-feature stub if executed.
//! - `--features xla,xla-external` (real deployments): re-exports the
//!   real crates, which the operator adds to `[dependencies]` alongside
//!   `make artifacts`, exactly as before.

/// Error message every shim constructor returns.
#[cfg(not(feature = "xla-external"))]
const UNAVAILABLE: &str =
    "PJRT unavailable: built with the vendored xla shim (enable the `xla-external` feature \
     and add the xla/anyhow crates for a real backend)";

#[cfg(feature = "xla-external")]
pub use ::anyhow;
#[cfg(feature = "xla-external")]
pub use ::xla;

/// Vendored mini-`anyhow`: the `Result`/`Context`/`bail!` subset the
/// runtime uses.
#[cfg(not(feature = "xla-external"))]
pub mod anyhow {
    /// A boxed, context-wrapped error string.
    #[derive(Debug)]
    pub struct Error(pub String);

    impl std::fmt::Display for Error {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for Error {}

    impl From<super::xla::Error> for Error {
        fn from(e: super::xla::Error) -> Error {
            Error(e.0)
        }
    }

    /// `anyhow::Result` analog.
    pub type Result<T, E = Error> = std::result::Result<T, E>;

    /// `anyhow::Context` analog for `Result` and `Option`.
    pub trait Context<T> {
        /// Wrap the error with a static context message.
        fn context<C: std::fmt::Display>(self, c: C) -> Result<T>;
        /// Wrap the error with a lazily built context message.
        fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
    }

    impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
        fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
            self.map_err(|e| Error(format!("{c}: {e}")))
        }
        fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
            self.map_err(|e| Error(format!("{}: {e}", f())))
        }
    }

    impl<T> Context<T> for Option<T> {
        fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
            self.ok_or_else(|| Error(c.to_string()))
        }
        fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
            self.ok_or_else(|| Error(f().to_string()))
        }
    }

    pub use crate::runtime_bail as bail;
}

/// `anyhow::bail!` analog for the vendored shim (exported at crate root
/// by `#[macro_export]`, re-imported as `ffi::anyhow::bail`).
#[cfg(not(feature = "xla-external"))]
#[macro_export]
macro_rules! runtime_bail {
    ($($arg:tt)*) => {
        return Err($crate::runtime::ffi::anyhow::Error(format!($($arg)*)))
    };
}

/// Vendored type-level shim of the `xla` crate surface the runtime uses.
/// Every loader fails with [`UNAVAILABLE`]; methods that can only be
/// reached through a loader are therefore unreachable at runtime but keep
/// the real call sites type-checked.
#[cfg(not(feature = "xla-external"))]
pub mod xla {
    use super::UNAVAILABLE;

    /// Shim error (mirrors `xla::Error` as a message).
    #[derive(Debug)]
    pub struct Error(pub String);

    impl std::fmt::Display for Error {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for Error {}

    fn unavailable<T>() -> Result<T, Error> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    /// Shim of `xla::PjRtClient`.
    pub struct PjRtClient;

    impl PjRtClient {
        /// Mirrors `PjRtClient::cpu`; always unavailable in the shim.
        pub fn cpu() -> Result<PjRtClient, Error> {
            unavailable()
        }
        /// Platform name (unreachable: no client can be constructed).
        pub fn platform_name(&self) -> String {
            "shim".to_string()
        }
        /// Device count (unreachable: no client can be constructed).
        pub fn device_count(&self) -> usize {
            0
        }
        /// Mirrors `PjRtClient::compile`; always unavailable.
        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            unavailable()
        }
    }

    /// Shim of `xla::HloModuleProto`.
    pub struct HloModuleProto;

    impl HloModuleProto {
        /// Mirrors `HloModuleProto::from_text_file`; always unavailable.
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            unavailable()
        }
    }

    /// Shim of `xla::XlaComputation`.
    pub struct XlaComputation;

    impl XlaComputation {
        /// Mirrors `XlaComputation::from_proto`.
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    /// Shim of `xla::PjRtLoadedExecutable`.
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        /// Mirrors `PjRtLoadedExecutable::execute`; always unavailable.
        pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            unavailable()
        }
    }

    /// Shim of `xla::PjRtBuffer`.
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        /// Mirrors `PjRtBuffer::to_literal_sync`; always unavailable.
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            unavailable()
        }
    }

    /// Shim of `xla::Literal`.
    pub struct Literal;

    impl Literal {
        /// Mirrors `Literal::vec1` (constructible: literals are built
        /// before any client exists).
        pub fn vec1(_values: &[f32]) -> Literal {
            Literal
        }
        /// Mirrors `Literal::reshape`.
        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Ok(Literal)
        }
        /// Mirrors `Literal::copy_raw_from`; always unavailable.
        pub fn copy_raw_from(&mut self, _values: &[f32]) -> Result<(), Error> {
            unavailable()
        }
        /// Mirrors `Literal::to_tuple4`; always unavailable.
        pub fn to_tuple4(&self) -> Result<(Literal, Literal, Literal, Literal), Error> {
            unavailable()
        }
        /// Mirrors `Literal::to_vec`; always unavailable.
        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            unavailable()
        }
        /// Mirrors `Literal::get_first_element`; always unavailable.
        pub fn get_first_element<T>(&self) -> Result<T, Error> {
            unavailable()
        }
    }
}

#[cfg(all(test, not(feature = "xla-external")))]
mod tests {
    use super::anyhow::{Context, Result};

    fn fails() -> Result<u32> {
        let client = super::xla::PjRtClient::cpu().context("creating client")?;
        Ok(client.device_count() as u32)
    }

    #[test]
    fn shim_constructors_report_unavailable() {
        let err = fails().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("creating client"), "{msg}");
        assert!(msg.contains("PJRT unavailable"), "{msg}");
    }

    #[test]
    fn bail_macro_returns_error() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                super::anyhow::bail!("flagged {}", 42);
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 42");
    }
}
