//! Topology ledger: per-edge bandwidth bookings between the registry and
//! each edge node, and between nodes on the LAN.
//!
//! The paper's model is T = C_c^n(t) / b_n (§III-B): each node has its own
//! WAN downlink; pulls on one node serialize (Docker pulls a layer stream),
//! and pulls on different nodes proceed independently. An optional registry
//! uplink cap models a constrained private registry shared by all nodes —
//! an ablation the paper's future work hints at.
//!
//! The LAN side models EdgePier-style peer layer sharing: each node also
//! has a LAN port on which *its own* peer fetches serialize (the downloader
//! edge), and each seeder holds one upload slot per concurrent peer
//! transfer it serves (the seeder edge). The engine caps concurrent upload
//! slots per seeder (`SimConfig::p2p_seeder_cap`); planners consult
//! [`LinkModel::active_uploads`] before picking a seeder. LAN bookings are
//! deliberately *not* shifted by [`LinkModel::stall_in_flight`] — peer
//! transfers never touch the registry, so registry outages don't stall
//! them.

use crate::util::units::{Bandwidth, Bytes};

/// The per-node link and shared-uplink booking ledger.
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Per-node downlink.
    node_bw: Vec<Bandwidth>,
    /// Time each node's link becomes free.
    node_free_at: Vec<f64>,
    /// Optional shared registry uplink (None = unconstrained).
    pub registry_uplink: Option<Bandwidth>,
    /// Per-transfer bookings on the shared uplink, `(node, finish)`.
    /// Tracking provenance (instead of one scalar free-at time) lets a
    /// crashed node's in-flight transfer release the uplink
    /// ([`LinkModel::release_node`]) instead of leaving a phantom booking
    /// later pulls queue behind.
    uplink_bookings: Vec<(usize, f64)>,
    /// Time each node's LAN port becomes free (downloader side of a peer
    /// fetch; independent of the WAN downlink above).
    lan_free_at: Vec<f64>,
    /// Per-transfer upload-slot bookings, `(seeder, downloader, finish)`
    /// — the seeder side of a peer fetch. Concurrency-counted (a seeder
    /// serves up to the engine's cap at once), not serialized. Tracking
    /// the downloader lets a crash on *either* end release the slot
    /// ([`LinkModel::release_node`]) instead of pinning the seeder's
    /// capacity under a dead transfer.
    peer_uploads: Vec<(usize, usize, f64)>,
    /// Highest concurrent upload count ever observed on any seeder —
    /// the test hook for the "never serves more than the cap" criterion.
    peak_uploads: usize,
}

impl LinkModel {
    /// Build the ledger for a fleet with the given per-node downlinks.
    pub fn new(node_bw: Vec<Bandwidth>) -> LinkModel {
        let n = node_bw.len();
        LinkModel {
            node_bw,
            node_free_at: vec![0.0; n],
            registry_uplink: None,
            uplink_bookings: Vec::new(),
            lan_free_at: vec![0.0; n],
            peer_uploads: Vec::new(),
            peak_uploads: 0,
        }
    }

    /// Downlink bandwidth of `node`.
    pub fn bandwidth(&self, node: usize) -> Bandwidth {
        self.node_bw[node]
    }

    /// Override the downlink bandwidth of `node`.
    pub fn set_bandwidth(&mut self, node: usize, bw: Bandwidth) {
        self.node_bw[node] = bw;
    }

    /// Earliest time the shared uplink is free (max live booking).
    fn uplink_free_at(&self) -> f64 {
        self.uplink_bookings.iter().map(|&(_, f)| f).fold(0.0, f64::max)
    }

    /// Register the link of a node that joined the cluster mid-run.
    pub fn add_node(&mut self, bw: Bandwidth) {
        self.node_bw.push(bw);
        self.node_free_at.push(0.0);
        self.lan_free_at.push(0.0);
    }

    /// Number of registered node links.
    pub fn node_count(&self) -> usize {
        self.node_bw.len()
    }

    /// Delay the most recent booking on `node` by `extra` seconds — used
    /// when a transfer is *planned during* a registry outage (the booking
    /// just made by `schedule_transfer` is the latest on both the node
    /// link and, if capped, the registry uplink).
    pub fn delay_booking(&mut self, node: usize, extra: f64) {
        self.node_free_at[node] += extra;
        if let Some((_, finish)) =
            self.uplink_bookings.iter_mut().rev().find(|(n, _)| *n == node)
        {
            *finish += extra;
        }
    }

    /// Registry outage: every transfer still in flight at `now` (link busy
    /// past `now`) pauses for `extra` seconds — bookings shift so transfers
    /// planned after the outage queue behind the resumed ones.
    pub fn stall_in_flight(&mut self, now: f64, extra: f64) {
        for t in self.node_free_at.iter_mut() {
            if *t > now {
                *t += extra;
            }
        }
        for (_, finish) in self.uplink_bookings.iter_mut() {
            if *finish > now {
                *finish += extra;
            }
        }
    }

    /// A node crashed: drop every piece of its link state — uplink
    /// bookings, the WAN downlink busy time, the LAN port busy time, and
    /// any upload slots it was seeding — so nothing dead keeps occupying
    /// shared capacity and a future *rejoin* of the slot can't inherit
    /// phantom busy time. Transfers already planned keep their
    /// (pessimistic) times — history is not rewritten — but every pull
    /// planned after the crash sees full capacity. Clearing the free-at
    /// clocks to 0 also makes [`LinkModel::stall_in_flight`] a no-op for
    /// the dead node (nothing is "busy past now" anymore).
    pub fn release_node(&mut self, node: usize) {
        self.uplink_bookings.retain(|&(n, _)| n != node);
        self.node_free_at[node] = 0.0;
        self.lan_free_at[node] = 0.0;
        self.peer_uploads.retain(|&(s, d, _)| s != node && d != node);
    }

    /// Schedule a transfer of `bytes` to `node` starting no earlier than
    /// `now`; returns (start, finish) and books the link.
    pub fn schedule_transfer(&mut self, node: usize, bytes: Bytes, now: f64) -> (f64, f64) {
        let mut start = now.max(self.node_free_at[node]);
        if self.registry_uplink.is_some() {
            start = start.max(self.uplink_free_at());
        }
        let mut secs = self.node_bw[node].transfer_secs(bytes);
        if let Some(up) = self.registry_uplink {
            secs = secs.max(up.transfer_secs(bytes));
        }
        let finish = start + secs;
        self.node_free_at[node] = finish;
        if self.registry_uplink.is_some() {
            // Prune settled bookings first so the ledger stays O(in-flight).
            self.uplink_bookings.retain(|&(_, f)| f > now);
            self.uplink_bookings.push((node, finish));
        }
        (start, finish)
    }

    // --- LAN edges (peer swarm) ------------------------------------------

    /// Upload slots `seeder` is serving at `now` (bookings still in
    /// flight). Planners compare this against the per-seeder cap before
    /// selecting the node as a source.
    pub fn active_uploads(&self, seeder: usize, now: f64) -> usize {
        self.peer_uploads.iter().filter(|&&(s, _, f)| s == seeder && f > now).count()
    }

    /// Schedule a peer layer transfer of `bytes` from `seeder` to
    /// `downloader` over the LAN at `lan_bw`, starting no earlier than
    /// `now`; returns `(start, finish)` and books both edges: the
    /// downloader's LAN port serializes (like the WAN downlink), and the
    /// seeder gains one upload slot until `finish`.
    pub fn schedule_peer_transfer(
        &mut self,
        downloader: usize,
        seeder: usize,
        bytes: Bytes,
        lan_bw: Bandwidth,
        now: f64,
    ) -> (f64, f64) {
        let start = now.max(self.lan_free_at[downloader]);
        let finish = start + lan_bw.transfer_secs(bytes);
        self.lan_free_at[downloader] = finish;
        // Prune settled slots so the ledger stays O(in-flight).
        self.peer_uploads.retain(|&(_, _, f)| f > now);
        self.peer_uploads.push((seeder, downloader, finish));
        let active = self.active_uploads(seeder, now);
        if active > self.peak_uploads {
            self.peak_uploads = active;
        }
        (start, finish)
    }

    /// Highest concurrent upload count ever booked on any single seeder —
    /// with a per-seeder cap of C this must never exceed C (asserted by
    /// the swarm test suite).
    pub fn peak_peer_uploads(&self) -> usize {
        self.peak_uploads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_node_links_are_independent() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        let (s0, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, f1) = lm.schedule_transfer(1, Bytes::from_mb(50.0), 0.0);
        assert_eq!((s0, f0), (0.0, 10.0));
        assert_eq!((s1, f1), (0.0, 5.0));
    }

    #[test]
    fn same_node_transfers_serialize() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0)]);
        let (_, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, f1) = lm.schedule_transfer(0, Bytes::from_mb(10.0), 2.0);
        assert_eq!(f0, 10.0);
        assert_eq!(s1, 10.0); // waits for the first pull
        assert_eq!(f1, 11.0);
    }

    #[test]
    fn registry_uplink_serializes_across_nodes() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        let (_, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, _) = lm.schedule_transfer(1, Bytes::from_mb(10.0), 0.0);
        assert_eq!(s1, f0, "second node waits on the registry uplink");
    }

    #[test]
    fn slow_uplink_dominates() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(100.0)]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        let (_, f) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        assert_eq!(f, 10.0, "uplink is the bottleneck");
    }

    #[test]
    fn joined_node_gets_fresh_link() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0)]);
        lm.add_node(Bandwidth::from_mbps(20.0));
        assert_eq!(lm.node_count(), 2);
        let (s, f) = lm.schedule_transfer(1, Bytes::from_mb(40.0), 100.0);
        assert_eq!((s, f), (100.0, 102.0));
    }

    #[test]
    fn crash_releases_uplink_booking() {
        // Regression (ROADMAP churn follow-on): a crashed node's in-flight
        // transfer must release the shared registry uplink instead of
        // leaving a phantom scalar booking other nodes queue behind.
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        let (_, f0) = lm.schedule_transfer(0, Bytes::from_mb(1000.0), 0.0);
        assert_eq!(f0, 100.0);
        // Node 0 crashes at t=5; its transfer dies with it.
        lm.release_node(0);
        let (s1, f1) = lm.schedule_transfer(1, Bytes::from_mb(10.0), 5.0);
        assert_eq!((s1, f1), (5.0, 6.0), "uplink capacity back to baseline");
    }

    #[test]
    fn release_keeps_other_nodes_bookings() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 3]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0); // uplink to 10
        let (_, f1) = lm.schedule_transfer(1, Bytes::from_mb(100.0), 0.0); // to 20
        lm.release_node(0);
        // Node 1's live transfer still occupies the uplink.
        let (s2, _) = lm.schedule_transfer(2, Bytes::from_mb(10.0), 1.0);
        assert_eq!(s2, f1, "surviving booking still serializes the uplink");
    }

    #[test]
    fn outage_stall_shifts_busy_links_only() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0); // busy until 10
        lm.schedule_transfer(1, Bytes::from_mb(10.0), 0.0); // busy until 1
        lm.stall_in_flight(2.0, 5.0);
        // Node 0 was mid-transfer: its link frees 5s later; node 1 had
        // already finished and is unaffected.
        let (s0, _) = lm.schedule_transfer(0, Bytes::from_mb(10.0), 2.0);
        assert_eq!(s0, 15.0);
        let (s1, _) = lm.schedule_transfer(1, Bytes::from_mb(10.0), 2.0);
        assert_eq!(s1, 2.0);
    }

    #[test]
    fn release_clears_node_link_state() {
        // Regression: release_node used to drop only the uplink bookings,
        // leaving node_free_at busy forever — a rejoin of the slot would
        // inherit phantom busy time, and stall_in_flight kept shifting the
        // dead node's booking on every outage.
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.schedule_transfer(0, Bytes::from_mb(1000.0), 0.0); // busy until 100
        lm.release_node(0);
        // A stall after the crash must not resurrect the dead booking.
        lm.stall_in_flight(5.0, 30.0);
        let (s0, f0) = lm.schedule_transfer(0, Bytes::from_mb(10.0), 6.0);
        assert_eq!((s0, f0), (6.0, 7.0), "link at baseline after the crash");
    }

    #[test]
    fn release_clears_lan_and_upload_slots() {
        let lan = Bandwidth::from_mbps(100.0);
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 3]);
        // Node 0 downloads from seeder 1; node 1 also seeds node 2.
        lm.schedule_peer_transfer(0, 1, Bytes::from_mb(1000.0), lan, 0.0);
        lm.schedule_peer_transfer(2, 1, Bytes::from_mb(1000.0), lan, 0.0);
        assert_eq!(lm.active_uploads(1, 1.0), 2);
        lm.release_node(1);
        assert_eq!(lm.active_uploads(1, 1.0), 0, "crashed seeder frees its slots");
        lm.release_node(0);
        let (s, _) = lm.schedule_peer_transfer(0, 2, Bytes::from_mb(10.0), lan, 1.0);
        assert_eq!(s, 1.0, "crashed downloader's LAN port is free again");
        // That fetch booked a slot on seeder 2; the downloader crashing
        // mid-transfer must release it (no phantom slot pinning the
        // seeder's capacity until the dead transfer's original finish).
        assert_eq!(lm.active_uploads(2, 1.05), 1);
        lm.release_node(0);
        assert_eq!(lm.active_uploads(2, 1.05), 0, "dead downloader frees the slot");
    }

    #[test]
    fn peer_transfers_serialize_on_downloader_lan_port() {
        let lan = Bandwidth::from_mbps(100.0);
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 3]);
        let (s0, f0) = lm.schedule_peer_transfer(0, 1, Bytes::from_mb(200.0), lan, 0.0);
        assert_eq!((s0, f0), (0.0, 2.0));
        // Same downloader, different seeder: queues on the LAN port.
        let (s1, f1) = lm.schedule_peer_transfer(0, 2, Bytes::from_mb(100.0), lan, 1.0);
        assert_eq!((s1, f1), (2.0, 3.0));
        // Different downloader: independent port.
        let (s2, _) = lm.schedule_peer_transfer(2, 1, Bytes::from_mb(100.0), lan, 1.0);
        assert_eq!(s2, 1.0);
    }

    #[test]
    fn peer_lan_is_independent_of_wan_downlink() {
        let lan = Bandwidth::from_mbps(100.0);
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0); // WAN busy until 10
        let (s, _) = lm.schedule_peer_transfer(0, 1, Bytes::from_mb(100.0), lan, 0.0);
        assert_eq!(s, 0.0, "LAN port does not queue behind the WAN downlink");
    }

    #[test]
    fn upload_slots_count_concurrency_and_expire() {
        let lan = Bandwidth::from_mbps(100.0);
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 4]);
        lm.schedule_peer_transfer(0, 3, Bytes::from_mb(100.0), lan, 0.0); // until 1
        lm.schedule_peer_transfer(1, 3, Bytes::from_mb(200.0), lan, 0.0); // until 2
        lm.schedule_peer_transfer(2, 3, Bytes::from_mb(300.0), lan, 0.0); // until 3
        assert_eq!(lm.active_uploads(3, 0.5), 3);
        assert_eq!(lm.active_uploads(3, 1.5), 2, "finished uploads free their slot");
        assert_eq!(lm.active_uploads(3, 3.5), 0);
        assert_eq!(lm.peak_peer_uploads(), 3);
    }

    #[test]
    fn outage_stall_leaves_lan_bookings_alone() {
        let lan = Bandwidth::from_mbps(100.0);
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.schedule_peer_transfer(0, 1, Bytes::from_mb(500.0), lan, 0.0); // until 5
        lm.stall_in_flight(1.0, 30.0);
        let (s, _) = lm.schedule_peer_transfer(0, 1, Bytes::from_mb(100.0), lan, 1.0);
        assert_eq!(s, 5.0, "peer transfers are exempt from registry outages");
    }
}
