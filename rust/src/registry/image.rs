//! Image metadata — the `ImageMetadata` structure from the paper's
//! Listing 1, plus image references (`name:tag`).

use super::layer::LayerMetadata;
use crate::util::json::Json;
use crate::util::units::Bytes;
use std::fmt;

/// An image reference `repo/name:tag` as written in a pod spec.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ImageRef {
    /// Repository/name part (may include a registry host prefix).
    pub name: String,
    /// Tag (defaults to `latest` when parsing).
    pub tag: String,
}

impl ImageRef {
    /// Construct from explicit name and tag.
    pub fn new(name: &str, tag: &str) -> ImageRef {
        ImageRef { name: name.to_string(), tag: tag.to_string() }
    }

    /// Parse `name[:tag]`; the tag defaults to `latest` as in Docker.
    pub fn parse(s: &str) -> ImageRef {
        // The digest form name@sha256:… is not used by the paper's workload.
        match s.rsplit_once(':') {
            // A ':' inside a registry host port (host:5000/img) is not a tag;
            // only split when the suffix has no '/'.
            Some((name, tag)) if !tag.contains('/') => ImageRef::new(name, tag),
            _ => ImageRef::new(s, "latest"),
        }
    }

    /// `name` without a leading repository prefix (paper's
    /// `NameWithoutRepo`), e.g. `registry.local/library/redis` → `redis`.
    pub fn name_without_repo(&self) -> &str {
        self.name.rsplit('/').next().unwrap_or(&self.name)
    }

    /// Canonical `name:tag` key.
    pub fn key(&self) -> String {
        format!("{}:{}", self.name, self.tag)
    }
}

impl fmt::Display for ImageRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.name, self.tag)
    }
}

/// Registry-side metadata for one image (paper Listing 1 `ImageMetadata`).
#[derive(Debug, Clone, PartialEq)]
pub struct ImageMetadata {
    /// Manifest digest (paper `Id`).
    pub id: String,
    /// Image name.
    pub name: String,
    /// Image tag.
    pub tag: String,
    /// Sum of layer sizes.
    pub total_size: Bytes,
    /// The layer stack, base first.
    pub layers: Vec<LayerMetadata>,
}

impl ImageMetadata {
    /// Construct, computing `total_size` from the layers.
    pub fn new(id: &str, name: &str, tag: &str, layers: Vec<LayerMetadata>) -> ImageMetadata {
        let total_size = layers.iter().map(|l| l.size).sum();
        ImageMetadata {
            id: id.to_string(),
            name: name.to_string(),
            tag: tag.to_string(),
            total_size,
            layers,
        }
    }

    /// The `name:tag` reference for this manifest.
    pub fn image_ref(&self) -> ImageRef {
        ImageRef::new(&self.name, &self.tag)
    }

    /// `name` without a leading repository prefix (paper's
    /// `NameWithoutRepo`).
    pub fn name_without_repo(&self) -> &str {
        self.image_ref();
        self.name.rsplit('/').next().unwrap_or(&self.name)
    }

    /// Serialize in the shape of the paper's cache.json entries.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::Str(self.id.clone()))
            .set("name", Json::Str(self.name.clone()))
            .set(
                "name_without_repo",
                Json::Str(self.name_without_repo().to_string()),
            )
            .set("tag", Json::Str(self.tag.clone()))
            .set("total_size", Json::Int(self.total_size.0 as i64))
            .set(
                "l_meta",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            let mut lo = Json::obj();
                            lo.set("size", Json::Int(l.size.0 as i64))
                                .set("layer", Json::Str(l.digest.clone()));
                            lo
                        })
                        .collect(),
                ),
            );
        o
    }

    /// Parse a `cache.json` entry; None on malformed/inconsistent data.
    pub fn from_json(v: &Json) -> Option<ImageMetadata> {
        let layers = v
            .get("l_meta")?
            .as_arr()?
            .iter()
            .map(|l| {
                Some(LayerMetadata {
                    digest: l.get("layer")?.as_str()?.to_string(),
                    size: Bytes(l.get("size")?.as_i64()? as u64),
                })
            })
            .collect::<Option<Vec<_>>>()?;
        let meta = ImageMetadata::new(
            v.get("id")?.as_str()?,
            v.get("name")?.as_str()?,
            v.get("tag")?.as_str()?,
            layers,
        );
        // total_size is recomputed from layers; verify the recorded value
        // if present (detects hand-edited cache files).
        if let Some(ts) = v.get("total_size").and_then(|t| t.as_i64()) {
            if ts as u64 != meta.total_size.0 {
                return None;
            }
        }
        Some(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ImageMetadata {
        ImageMetadata::new(
            "sha256:manifest0",
            "registry.local/library/redis",
            "7.2",
            vec![
                LayerMetadata { digest: "sha256:base".into(), size: Bytes::from_mb(29.0) },
                LayerMetadata { digest: "sha256:app".into(), size: Bytes::from_mb(88.0) },
            ],
        )
    }

    #[test]
    fn image_ref_parsing() {
        assert_eq!(ImageRef::parse("redis:7.2"), ImageRef::new("redis", "7.2"));
        assert_eq!(ImageRef::parse("redis"), ImageRef::new("redis", "latest"));
        assert_eq!(
            ImageRef::parse("registry.local:5000/redis"),
            ImageRef::new("registry.local:5000/redis", "latest")
        );
        assert_eq!(
            ImageRef::parse("registry.local:5000/redis:7"),
            ImageRef::new("registry.local:5000/redis", "7")
        );
    }

    #[test]
    fn name_without_repo() {
        assert_eq!(sample().name_without_repo(), "redis");
        assert_eq!(ImageRef::parse("redis:7").name_without_repo(), "redis");
    }

    #[test]
    fn total_size_is_layer_sum() {
        assert_eq!(sample().total_size, Bytes::from_mb(117.0));
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        let j = m.to_json();
        assert_eq!(ImageMetadata::from_json(&j), Some(m));
        // Paper field names present:
        assert!(j.get("l_meta").is_some());
        assert!(j.get("name_without_repo").is_some());
        assert_eq!(j.get("tag").unwrap().as_str(), Some("7.2"));
    }

    #[test]
    fn from_json_rejects_inconsistent_total() {
        let mut j = sample().to_json();
        j.set("total_size", Json::Int(1));
        assert_eq!(ImageMetadata::from_json(&j), None);
    }

    #[test]
    fn image_ref_key_display() {
        let r = ImageRef::new("ghost", "5");
        assert_eq!(r.key(), "ghost:5");
        assert_eq!(r.to_string(), "ghost:5");
    }
}
