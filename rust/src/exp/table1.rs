//! Table I — "Performance analysis for 20 containers": per-container
//! download size (MB), download time (s), and cluster STD for each of the
//! three schedulers on the same 20-pod trace.

use super::common;
use super::report;
use crate::util::units::Bytes;

/// One row: container `i` as placed by one scheduler.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// 1-based container index within the trace.
    pub container: usize,
    /// Scheduler label.
    pub scheduler: &'static str,
    /// Image key (`name:tag`).
    pub image: String,
    /// Node the container landed on.
    pub node: String,
    /// WAN bytes pulled for this container.
    pub download: Bytes,
    /// Seconds from bind to ready.
    pub secs: f64,
    /// Cluster STD after this placement.
    pub std: f64,
}

/// The full table across all three schedulers.
#[derive(Debug, Clone)]
pub struct Table1 {
    /// All rows, scheduler-major.
    pub rows: Vec<Table1Row>,
    /// Containers per scheduler.
    pub n_pods: usize,
}

/// Regenerate the table for a seeded workload.
pub fn run(seed: u64, n_pods: usize, n_nodes: usize) -> Table1 {
    let trace = common::paper_trace(seed, n_pods);
    let mut rows = Vec::new();
    for rep in common::run_all(n_nodes, &trace, |_| {}) {
        for (i, r) in rep.records.iter().enumerate() {
            rows.push(Table1Row {
                container: i + 1,
                scheduler: rep.scheduler,
                image: r.image.clone(),
                node: r.node.clone(),
                download: r.download,
                secs: r.download_secs,
                std: r.std_after,
            });
        }
    }
    Table1 { rows, n_pods }
}

impl Table1 {
    /// Rows of one scheduler, in container order.
    pub fn rows_for(&self, scheduler: &str) -> Vec<&Table1Row> {
        self.rows.iter().filter(|r| r.scheduler == scheduler).collect()
    }

    /// Summed download size of one scheduler's rows.
    pub fn total_download(&self, scheduler: &str) -> Bytes {
        self.rows_for(scheduler).iter().map(|r| r.download).sum()
    }

    /// Summed download time of one scheduler's rows.
    pub fn total_secs(&self, scheduler: &str) -> f64 {
        self.rows_for(scheduler).iter().map(|r| r.secs).sum()
    }

    /// STD after the last placement of one scheduler.
    pub fn final_std(&self, scheduler: &str) -> f64 {
        self.rows_for(scheduler).last().map(|r| r.std).unwrap_or(0.0)
    }

    /// Render the table as aligned text.
    pub fn print(&self) -> String {
        let mut table_rows = Vec::new();
        for i in 1..=self.n_pods {
            for sched in ["Default", "Layer", "LRScheduler"] {
                if let Some(r) = self
                    .rows
                    .iter()
                    .find(|r| r.container == i && r.scheduler == sched)
                {
                    table_rows.push(vec![
                        if sched == "Default" { i.to_string() } else { String::new() },
                        sched.to_string(),
                        r.image.clone(),
                        r.node.clone(),
                        report::f1(r.download.as_mb()),
                        report::f1(r.secs),
                        report::f3(r.std),
                    ]);
                }
            }
        }
        let mut out = String::from("Table I — performance analysis per container\n");
        out.push_str(&report::table(
            &["#", "scheduler", "image", "node", "dl MB", "time s", "STD"],
            &table_rows,
        ));
        out.push('\n');
        for sched in ["Default", "Layer", "LRScheduler"] {
            out.push_str(&format!(
                "{sched:>12}: total {:.0} MB, {:.0} s, final STD {:.3}\n",
                self.total_download(sched).as_mb(),
                self.total_secs(sched),
                self.final_std(sched)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_holds() {
        let t = run(42, 20, 4);
        assert_eq!(t.rows.len(), 60);
        // Paired rows exist for every container and scheduler.
        for i in 1..=20 {
            for s in ["Default", "Layer", "LRScheduler"] {
                assert!(t.rows.iter().any(|r| r.container == i && r.scheduler == s));
            }
        }
        // Headline orderings: LR (and Layer) beat Default on totals; the
        // layer-aware schedulers carry equal-or-higher final imbalance
        // (they trade balance for locality — paper's STD column).
        assert!(t.total_download("LRScheduler") < t.total_download("Default"));
        assert!(t.total_download("Layer") < t.total_download("Default"));
        assert!(t.total_secs("LRScheduler") < t.total_secs("Default"));
        assert!(t.final_std("Default") <= t.final_std("Layer") + 0.05);
        // STD is in [0, 0.5] by construction (Eq. 11).
        for r in &t.rows {
            assert!((0.0..=0.5).contains(&r.std));
        }
    }

    #[test]
    fn per_step_values_nonnegative() {
        let t = run(7, 10, 4);
        for r in &t.rows {
            assert!(r.secs >= 0.0);
        }
    }
}
