//! Scheduler profiles — plugin-set configurations. The default profile
//! enables the plugins the paper lists in §IV-B with upstream default
//! weights; `FrameworkConfig` lets experiments toggle plugins individually
//! ("the plugins mentioned above can be enabled or disabled individually").

use super::framework::Framework;
use super::plugins::*;

/// Which score plugins to enable (filters are always on — they implement
/// hard constraints).
#[derive(Debug, Clone)]
pub struct FrameworkConfig {
    /// ImageLocality score plugin.
    pub image_locality: bool,
    /// TaintToleration score plugin.
    pub taint_toleration: bool,
    /// NodeAffinity score plugin.
    pub node_affinity: bool,
    /// PodTopologySpread score plugin.
    pub pod_topology_spread: bool,
    /// NodeResourcesFit/LeastAllocated score plugin.
    pub least_allocated: bool,
    /// VolumeBinding score plugin.
    pub volume_binding: bool,
    /// InterPodAffinity score plugin.
    pub inter_pod_affinity: bool,
    /// NodeResourcesBalancedAllocation score plugin.
    pub balanced_allocation: bool,
}

impl Default for FrameworkConfig {
    /// The §IV-B list with NodeResourcesBalancedAllocation (§I/[23]) on.
    fn default() -> FrameworkConfig {
        FrameworkConfig {
            image_locality: true,
            taint_toleration: true,
            node_affinity: true,
            pod_topology_spread: true,
            least_allocated: true,
            volume_binding: true,
            inter_pod_affinity: true,
            balanced_allocation: true,
        }
    }
}

impl FrameworkConfig {
    /// Only resource plugins — a minimal profile for ablations.
    pub fn resources_only() -> FrameworkConfig {
        FrameworkConfig {
            image_locality: false,
            taint_toleration: false,
            node_affinity: false,
            pod_topology_spread: false,
            least_allocated: true,
            volume_binding: false,
            inter_pod_affinity: false,
            balanced_allocation: true,
        }
    }

    /// Build the framework. Weights mirror upstream defaults (all 1 except
    /// TaintToleration=3 and NodeAffinity=2 in kube-scheduler v1.23).
    pub fn build(&self, profile_name: &str) -> Framework {
        let mut fw = Framework::new(profile_name)
            // Filters: hard constraints always enforced (paper §III-C).
            .add_filter(Box::new(NodeResourcesFit))
            .add_filter(Box::new(NodeCapacity))
            .add_filter(Box::new(TaintTolerationFilter))
            .add_filter(Box::new(NodeAffinityFilter))
            .add_filter(Box::new(VolumeBindingFilter));
        if self.image_locality {
            fw = fw.add_scorer(Box::new(ImageLocality), 1.0);
        }
        if self.taint_toleration {
            fw = fw.add_scorer(Box::new(TaintTolerationScore), 3.0);
        }
        if self.node_affinity {
            fw = fw.add_scorer(Box::new(NodeAffinityScore), 2.0);
        }
        if self.pod_topology_spread {
            fw = fw.add_scorer(Box::new(PodTopologySpread), 2.0);
        }
        if self.least_allocated {
            fw = fw.add_scorer(Box::new(LeastAllocated), 1.0);
        }
        if self.volume_binding {
            fw = fw.add_scorer(Box::new(VolumeBindingScore), 1.0);
        }
        if self.inter_pod_affinity {
            fw = fw.add_scorer(Box::new(InterPodAffinity), 1.0);
        }
        if self.balanced_allocation {
            fw = fw.add_scorer(Box::new(BalancedAllocation), 1.0);
        }
        fw
    }
}

/// The default-scheduler framework (baseline "Default" in the paper's
/// experiments).
pub fn default_framework() -> Framework {
    FrameworkConfig::default().build("default-scheduler")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_has_all_eight_scorers() {
        let fw = default_framework();
        let names = fw.scorer_names();
        assert_eq!(names.len(), 8);
        assert!(names.contains(&"ImageLocality"));
        assert!(names.contains(&"NodeResourcesBalancedAllocation"));
    }

    #[test]
    fn toggles_remove_scorers() {
        let mut cfg = FrameworkConfig::default();
        cfg.image_locality = false;
        cfg.inter_pod_affinity = false;
        let fw = cfg.build("test");
        let names = fw.scorer_names();
        assert_eq!(names.len(), 6);
        assert!(!names.contains(&"ImageLocality"));
    }

    #[test]
    fn resources_only_profile() {
        let fw = FrameworkConfig::resources_only().build("min");
        assert_eq!(fw.scorer_names().len(), 2);
    }
}
