//! Quickstart: schedule one pod with LRScheduler and inspect the decision.
//!
//! Run: `cargo run --release --example quickstart`

use lrsched::cluster::{Node, NodeId, PodBuilder, Resources};
use lrsched::registry::{MetadataCache, Registry, Watcher};
use lrsched::sched::{default_framework, CycleContext, LrScheduler};
use lrsched::util::units::{Bandwidth, Bytes};

fn main() {
    // 1. An edge cluster: three heterogeneous workers.
    let mut state = lrsched::cluster::ClusterState::new();
    for (i, (mem_gb, disk_gb)) in [(4.0, 30.0), (2.0, 30.0), (4.0, 20.0)].iter().enumerate() {
        state.add_node(Node::new(
            NodeId(i as u32),
            &format!("worker{}", i + 1),
            Resources::cores_gb(4.0, *mem_gb),
            Bytes::from_gb(*disk_gb),
            Bandwidth::from_mbps(10.0),
        ));
    }

    // 2. A private registry with the image corpus; the watcher fills the
    //    layer-metadata cache (the paper's cache.json).
    let registry = Registry::with_corpus();
    let mut cache = MetadataCache::new("/tmp/quickstart-cache.json");
    Watcher::with_default_interval().poll(0.0, &registry, &mut cache);

    // 3. Warm worker3 with php:8.2-apache — it shares the debian base,
    //    apache, and the php runtime with wordpress.
    let php = registry
        .manifest(&lrsched::registry::ImageRef::new("php", "8.2-apache"))
        .unwrap()
        .clone();
    let (_, php_layers) = state.intern_image(&php);
    state
        .install_image(NodeId(2), &php.image_ref(), &php_layers)
        .unwrap();

    // 4. A pod requesting wordpress:6.4 arrives.
    let pod = PodBuilder::new().build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
    let (meta, required, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, required, bytes);

    // 5. LRScheduler picks the node (Algorithm 1).
    let mut scheduler = LrScheduler::lr_scheduler(default_framework());
    let decision = scheduler.schedule(&ctx).unwrap();
    println!("pod image:        {}", pod.image);
    println!("scheduled to:     {}", state.node(decision.node).name);
    println!("layer score:      {:.1} / 100 (Eq. 3)", decision.layer_score);
    println!("dynamic weight:   {} (Eq. 13 gate)", decision.omega);
    println!("k8s score:        {:.1}", decision.k8s_score);
    println!("final score:      {:.1} (Eq. 4)", decision.final_score);
    println!("download cost:    {} (Eq. 1)", decision.download_cost);
    assert_eq!(decision.node, NodeId(2), "layer sharing should win");
}
