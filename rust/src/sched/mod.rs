//! The scheduler: Kubernetes-scheduling-framework analog (extension
//! points, default plugins, profiles) plus the paper's contribution —
//! the layer-sharing score (Eqs. 1–3), the resource-adaptive dynamic
//! weight (Eqs. 11–13), and the combined LRScheduler (Algorithm 1).

pub mod context;
pub mod dynamic_weight;
pub mod framework;
pub mod layer_score;
pub mod lrscheduler;
pub mod plugins;
pub mod profiles;
pub mod queue;
pub mod rl;
pub mod scoring;

pub use context::CycleContext;
pub use dynamic_weight::{WeightParams, WeightPolicy};
pub use framework::{Framework, NodeScore, Unschedulable};
pub use lrscheduler::{Decision, LrScheduler};
pub use profiles::{default_framework, FrameworkConfig};
pub use scoring::{NativeScorer, ScoreInputs, ScoreOutputs, ScoringBackend};
