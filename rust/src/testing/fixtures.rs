//! Canned clusters, caches, and pods shared by integration and property
//! tests.

use crate::cluster::{ClusterState, Node, NodeId, Resources};
use crate::registry::{MetadataCache, Registry, Watcher};
use crate::util::rng::Pcg;
use crate::util::units::{Bandwidth, Bytes};

/// A uniform n-node cluster (4 cores / 4 GB / 30 GB / 10 MB/s each).
pub fn uniform_cluster(n: u32) -> ClusterState {
    let mut s = ClusterState::new();
    for i in 0..n {
        s.add_node(Node::new(
            NodeId(i),
            &format!("node{i}"),
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(30.0),
            Bandwidth::from_mbps(10.0),
        ));
    }
    s
}

/// A heterogeneous cluster drawn from an RNG: capacities, disks, and
/// bandwidths vary (property tests).
pub fn random_cluster(rng: &mut Pcg, n: u32) -> ClusterState {
    let mut s = ClusterState::new();
    for i in 0..n {
        s.add_node(Node::new(
            NodeId(i),
            &format!("node{i}"),
            Resources::cores_gb(rng.range(2, 9) as f64, rng.range(2, 9) as f64),
            Bytes::from_gb(rng.range(10, 61) as f64),
            Bandwidth::from_mbps(rng.range(2, 51) as f64),
        ));
    }
    s
}

/// A metadata cache filled from the corpus registry.
pub fn corpus_cache() -> MetadataCache {
    let reg = Registry::with_corpus();
    let mut cache = MetadataCache::new("/tmp/lrsched-fixture-cache.json");
    Watcher::with_default_interval().poll(0.0, &reg, &mut cache);
    cache
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_build() {
        assert_eq!(uniform_cluster(4).node_count(), 4);
        let mut rng = Pcg::seeded(1);
        let c = random_cluster(&mut rng, 6);
        assert_eq!(c.node_count(), 6);
        assert_eq!(corpus_cache().len(), 30);
    }
}
