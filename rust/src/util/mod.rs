//! Foundation substrates built in-repo because the vendored dependency set
//! has no serde/rand/clap equivalents: JSON, RNG, statistics, logging, and
//! resource-unit newtypes.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod units;
