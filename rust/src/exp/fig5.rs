//! Figure 5 — "Accumulated download size for 20 pods": the running sum of
//! download cost as the trace deploys, per scheduler. The layer-aware
//! curves flatten as nodes warm up; the default curve keeps climbing.

use super::common;
use super::report;

/// The figure's data: one cumulative-download series per scheduler.
#[derive(Debug, Clone)]
pub struct Fig5 {
    /// Per scheduler: cumulative MB after each of the n pods.
    pub cumulative_mb: Vec<(&'static str, Vec<f64>)>,
}

/// Regenerate the figure's data for a seeded workload.
pub fn run(seed: u64, n_pods: usize, n_nodes: usize) -> Fig5 {
    let trace = common::paper_trace(seed, n_pods);
    let cumulative_mb = common::run_all(n_nodes, &trace, |_| {})
        .into_iter()
        .map(|rep| {
            let mut acc = 0.0;
            let series: Vec<f64> = rep
                .records
                .iter()
                .map(|r| {
                    acc += r.download.as_mb();
                    acc
                })
                .collect();
            (rep.scheduler, series)
        })
        .collect();
    Fig5 { cumulative_mb }
}

impl Fig5 {
    /// Cumulative series of one scheduler (panics when absent).
    pub fn series_for(&self, scheduler: &str) -> &[f64] {
        &self
            .cumulative_mb
            .iter()
            .find(|(s, _)| *s == scheduler)
            .expect("series")
            .1
    }

    /// Render the figure as aligned text series.
    pub fn print(&self) -> String {
        let mut out = String::from("Fig. 5 — accumulated download size (MB) per deployed pod\n");
        let lines: Vec<(String, Vec<f64>)> = self
            .cumulative_mb
            .iter()
            .map(|(s, v)| (s.to_string(), v.clone()))
            .collect();
        out.push_str(&report::series("", &lines, 0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_holds() {
        let fig = run(42, 20, 4);
        let def = fig.series_for("Default");
        let layer = fig.series_for("Layer");
        let lr = fig.series_for("LRScheduler");
        assert_eq!(def.len(), 20);
        // Cumulative series are non-decreasing.
        for s in [def, layer, lr] {
            assert!(s.windows(2).all(|w| w[1] >= w[0] - 1e-9));
        }
        // Layer-aware schedulers end significantly below Default.
        assert!(lr[19] < def[19] * 0.9, "lr {} vs def {}", lr[19], def[19]);
        assert!(layer[19] < def[19] * 0.9);
        // The gap grows with the number of deployed containers
        // ("significantly smaller … as the number increases").
        let gap_early = def[4] - lr[4];
        let gap_late = def[19] - lr[19];
        assert!(gap_late > gap_early);
    }
}
