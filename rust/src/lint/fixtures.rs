//! Embedded bad-snippet fixtures pinning each lint rule's behavior.
//!
//! Every rule must trip on exactly one embedded bad snippet and stay
//! silent on the clean/annotated variants — so a rules-engine regression
//! (a rule that stops firing, or one that starts over-firing) fails both
//! `cargo test` and the CI `lrsched lint --self-test` step without
//! needing a corpus of broken files on disk.

use super::lint_source;

/// One fixture: a pretend path (rule scoping is path-driven), a source
/// snippet, and the exact rule ids expected, in order.
struct Fixture {
    name: &'static str,
    path: &'static str,
    src: &'static str,
    expect: &'static [&'static str],
}

/// R1 trips: a hash map's key order escapes into a returned Vec.
const R1_BAD: &str = r#"
use std::collections::HashMap;
fn report(pending: HashMap<u64, f64>) -> Vec<u64> {
    let mut out = Vec::new();
    for pid in pending.keys() {
        out.push(*pid);
    }
    out
}
"#;

/// R1 silent: the same site, collect-then-sorted and annotated.
const R1_ANNOTATED: &str = r#"
use std::collections::HashMap;
fn report(pending: HashMap<u64, f64>) -> Vec<u64> {
    // det: sorted(pid)
    let mut out: Vec<u64> = pending.keys().copied().collect();
    out.sort_unstable();
    out
}
"#;

/// R2 trips: wall-clock in scheduler code.
const R2_BAD: &str = r#"
use std::time::Instant;
fn stamp() -> Instant {
    Instant::now()
}
"#;

/// R2 silent: a justified, reasoned allow.
const R2_ALLOWED: &str = r#"
fn level() -> Option<String> {
    // det: allow(R2): stderr verbosity only, simulation state never reads it
    std::env::var("LRSCHED_LOG").ok()
}
"#;

/// R3 trips once: a SAFETY comment is present but the file is not on the
/// unsafe allowlist.
const R3_BAD_FILE: &str = r#"
fn sneak(p: *const u8) -> u8 {
    // SAFETY: p is valid for reads (caller contract).
    unsafe { *p }
}
"#;

/// R3 trips once: allowlisted file, but the SAFETY comment is missing.
const R3_BAD_COMMENT: &str = r#"
fn sneak(p: *const u8) -> u8 {
    unsafe { *p }
}
"#;

/// R4 trips: a float accumulator captured by a `par_fill` closure.
const R4_BAD: &str = r#"
fn reduce(pool: &LanePool, xs: &mut [f64]) -> f64 {
    let mut total = 0.0;
    par_fill(pool, xs, &|_i, slot| {
        total += *slot;
    });
    total
}
"#;

/// R4 silent: accumulation into closure-local state, written back to a
/// fixed slot — the deterministic fan-out idiom.
const R4_CLEAN: &str = r#"
fn fill(pool: &LanePool, xs: &mut [f64]) {
    par_fill(pool, xs, &|_i, slot| {
        let mut acc = 0.0;
        for k in 0..4 {
            acc += k as f64;
        }
        *slot = acc;
    });
}
"#;

/// R0 trips: an annotation that suppresses nothing.
const R0_UNUSED: &str = r#"
fn tidy() -> u32 {
    // det: sorted(nothing)
    1 + 1
}
"#;

/// R0 trips: `det:` with an unparseable body.
const R0_MALFORMED: &str = r#"
fn tidy() -> u32 {
    // det: because reasons
    1 + 1
}
"#;

/// Silent: ordered-map iteration, hash lookups, and a local accumulator
/// outside any pool closure — the near-misses every rule must ignore.
const CLEAN: &str = r#"
use std::collections::{BTreeMap, HashMap};
fn steady(m: &BTreeMap<u64, f64>, h: &HashMap<u64, f64>) -> f64 {
    let mut total = 0.0;
    for (_k, v) in m.iter() {
        total += v;
    }
    total += h.get(&1).copied().unwrap_or(0.0);
    total
}
"#;

/// Silent: everything inside `#[cfg(test)]` is exempt from R1/R2/R4.
const TEST_REGION: &str = r#"
fn shipped() {}
#[cfg(test)]
mod tests {
    use std::time::Instant;
    #[test]
    fn timing() {
        let _ = Instant::now();
    }
}
"#;

const FIXTURES: &[Fixture] = &[
    Fixture { name: "r1_bad", path: "sim/fixture.rs", src: R1_BAD, expect: &["R1"] },
    Fixture { name: "r1_annotated", path: "sim/fixture.rs", src: R1_ANNOTATED, expect: &[] },
    Fixture { name: "r2_bad", path: "sched/fixture.rs", src: R2_BAD, expect: &["R2"] },
    Fixture { name: "r2_allowed", path: "util/fixture.rs", src: R2_ALLOWED, expect: &[] },
    Fixture { name: "r3_bad_file", path: "sched/fixture.rs", src: R3_BAD_FILE, expect: &["R3"] },
    Fixture { name: "r3_bad_comment", path: "sim/shard.rs", src: R3_BAD_COMMENT, expect: &["R3"] },
    Fixture { name: "r4_bad", path: "sim/fixture.rs", src: R4_BAD, expect: &["R4"] },
    Fixture { name: "r4_clean", path: "sim/fixture.rs", src: R4_CLEAN, expect: &[] },
    Fixture { name: "r0_unused", path: "sim/fixture.rs", src: R0_UNUSED, expect: &["R0"] },
    Fixture { name: "r0_malformed", path: "sim/fixture.rs", src: R0_MALFORMED, expect: &["R0"] },
    Fixture { name: "clean", path: "sim/fixture.rs", src: CLEAN, expect: &[] },
    Fixture { name: "test_region", path: "sim/fixture.rs", src: TEST_REGION, expect: &[] },
];

/// Run every embedded fixture through the rules engine and check that
/// each trips exactly the expected rule ids (bad snippets exactly once,
/// clean/annotated snippets not at all). Returns the first mismatch as
/// an error. Wired into CI as `lrsched lint --self-test`.
pub fn self_test() -> Result<(), String> {
    for f in FIXTURES {
        let got: Vec<&'static str> =
            lint_source(f.path, f.path, f.src).iter().map(|d| d.rule).collect();
        if got != f.expect {
            return Err(format!(
                "lint self-test {:?} ({}): expected rules {:?}, got {:?}",
                f.name, f.path, f.expect, got
            ));
        }
    }
    Ok(())
}
