//! XLA scoring backend — loads the AOT artifacts described by
//! `artifacts/manifest.json`, compiles each shape variant once, and serves
//! [`ScoringBackend::score`] on the scheduling hot path by padding inputs
//! to the smallest variant that fits.
//!
//! Falls back to the native scorer for cycles larger than every variant
//! (and records that in `stats`), so the scheduler never fails over shapes.

use super::ffi::anyhow::{bail, Context, Result};
use super::ffi::xla;
use super::pjrt::{Executable, PjRt};
use crate::sched::scoring::{NativeScorer, ScoreInputs, ScoreOutputs, ScoringBackend};
use crate::util::json;
use std::path::{Path, PathBuf};

/// One compiled shape variant with persistent, reusable input literals —
/// the hot path mutates them in place (`copy_raw_from`) instead of
/// allocating ten fresh literals per scheduling cycle (§Perf).
struct Variant {
    name: String,
    n_nodes: usize,
    n_layers: usize,
    exe: Executable,
    /// The 10 input literals, argument order of model.py::example_args.
    inputs: Vec<xla::Literal>,
}

fn f32_literal(len: usize, dims: &[i64]) -> xla::Literal {
    let lit = xla::Literal::vec1(&vec![0.0f32; len]);
    if dims.len() > 1 {
        lit.reshape(dims).expect("reshape fresh literal")
    } else {
        lit
    }
}

/// Execution statistics (observability + perf tests).
#[derive(Debug, Clone, Default)]
pub struct ScorerStats {
    /// Successful XLA executions.
    pub executions: u64,
    /// Cycles served by the native scorer (shape overflow or error).
    pub native_fallbacks: u64,
    /// Executions per variant, parallel to the variant list.
    pub per_variant: Vec<u64>,
}

/// The XLA-backed scorer.
pub struct XlaScorer {
    variants: Vec<Variant>,
    native: NativeScorer,
    /// Execution statistics (observability + perf tests).
    pub stats: ScorerStats,
    // Reused staging buffers (hot path: avoid per-cycle allocation).
    staging: Vec<f32>,
}

impl XlaScorer {
    /// Load every variant listed in `<artifacts_dir>/manifest.json`.
    pub fn load(artifacts_dir: &Path) -> Result<XlaScorer> {
        let manifest_path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = json::parse(&text).context("parsing manifest.json")?;
        if manifest.get("format").and_then(|f| f.as_str()) != Some("hlo-text") {
            bail!("unsupported artifact format");
        }
        let pjrt = PjRt::cpu()?;
        let mut variants = Vec::new();
        for v in manifest
            .get("variants")
            .and_then(|v| v.as_arr())
            .context("manifest missing variants")?
        {
            let name = v.get("name").and_then(|x| x.as_str()).context("variant name")?;
            let n_nodes = v.get("n_nodes").and_then(|x| x.as_i64()).context("n_nodes")? as usize;
            let n_layers =
                v.get("n_layers").and_then(|x| x.as_i64()).context("n_layers")? as usize;
            let file = v.get("file").and_then(|x| x.as_str()).context("file")?;
            let exe = pjrt.compile_hlo_file(&artifacts_dir.join(file))?;
            let (vn, vl) = (n_nodes, n_layers);
            let inputs = vec![
                f32_literal(vn * vl, &[vn as i64, vl as i64]), // present
                f32_literal(vl, &[vl as i64]),                 // req
                f32_literal(vl, &[vl as i64]),                 // sizes_mb
                f32_literal(vn, &[vn as i64]),                 // cpu_used
                f32_literal(vn, &[vn as i64]),                 // cpu_cap
                f32_literal(vn, &[vn as i64]),                 // mem_used
                f32_literal(vn, &[vn as i64]),                 // mem_cap
                f32_literal(vn, &[vn as i64]),                 // k8s_score
                f32_literal(vn, &[vn as i64]),                 // feasible
                f32_literal(5, &[5]),                          // params
            ];
            variants.push(Variant { name: name.to_string(), n_nodes, n_layers, exe, inputs });
        }
        if variants.is_empty() {
            bail!("manifest lists no variants");
        }
        // Smallest-first so variant selection picks the cheapest fit.
        variants.sort_by_key(|v| v.n_nodes * v.n_layers);
        let per_variant = vec![0; variants.len()];
        Ok(XlaScorer {
            variants,
            native: NativeScorer,
            stats: ScorerStats { per_variant, ..Default::default() },
            staging: Vec::new(),
        })
    }

    /// Default artifact location relative to the repo root / CWD.
    pub fn load_default() -> Result<XlaScorer> {
        let candidates = [PathBuf::from("artifacts"), PathBuf::from("../artifacts")];
        for c in &candidates {
            if c.join("manifest.json").exists() {
                return XlaScorer::load(c);
            }
        }
        bail!("artifacts/manifest.json not found — run `make artifacts` first")
    }

    /// Names of the compiled shape variants, smallest first.
    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.iter().map(|v| v.name.as_str()).collect()
    }

    fn pick_variant(&self, n_nodes: usize, n_layers: usize) -> Option<usize> {
        self.variants
            .iter()
            .position(|v| v.n_nodes >= n_nodes && v.n_layers >= n_layers)
    }

    /// Pad `x` into the variant's persistent literals in place
    /// (argument order matches `python/compile/model.py::example_args`).
    fn fill_literals(staging: &mut Vec<f32>, variant: &mut Variant, x: &ScoreInputs) -> Result<()> {
        let (n, l) = (x.n_nodes, x.n_layers);
        let (vn, vl) = (variant.n_nodes, variant.n_layers);
        // present: pad rows AND columns.
        staging.clear();
        staging.resize(vn * vl, 0.0);
        for i in 0..n {
            staging[i * vl..i * vl + l].copy_from_slice(&x.present[i * l..(i + 1) * l]);
        }
        variant.inputs[0].copy_raw_from(staging)?;

        fn pad_into(
            staging: &mut Vec<f32>,
            dst: &mut xla::Literal,
            src: &[f32],
            cap: usize,
            fill: f32,
        ) -> Result<()> {
            staging.clear();
            staging.resize(cap, fill);
            staging[..src.len()].copy_from_slice(src);
            Ok(dst.copy_raw_from(staging)?)
        }
        pad_into(staging, &mut variant.inputs[1], &x.req, vl, 0.0)?;
        pad_into(staging, &mut variant.inputs[2], &x.sizes_mb, vl, 0.0)?;
        pad_into(staging, &mut variant.inputs[3], &x.cpu_used, vn, 0.0)?;
        pad_into(staging, &mut variant.inputs[4], &x.cpu_cap, vn, 1.0)?; // avoid 0/0 on padding
        pad_into(staging, &mut variant.inputs[5], &x.mem_used, vn, 0.0)?;
        pad_into(staging, &mut variant.inputs[6], &x.mem_cap, vn, 1.0)?;
        pad_into(staging, &mut variant.inputs[7], &x.k8s_score, vn, 0.0)?;
        pad_into(staging, &mut variant.inputs[8], &x.feasible, vn, 0.0)?; // padding infeasible
        variant.inputs[9].copy_raw_from(&x.params_vec())?;
        Ok(())
    }

    fn score_xla(&mut self, x: &ScoreInputs) -> Result<ScoreOutputs> {
        let vi = match self.pick_variant(x.n_nodes, x.n_layers) {
            Some(vi) => vi,
            None => {
                self.stats.native_fallbacks += 1;
                return Ok(self.native.score(x));
            }
        };
        Self::fill_literals(&mut self.staging, &mut self.variants[vi], x)?;
        let out = self.variants[vi].exe.execute(&self.variants[vi].inputs)?;
        let (final_l, layer_l, omega_l, best_l) = out.to_tuple4()?;
        let mut final_score = final_l.to_vec::<f32>()?;
        let mut layer_score = layer_l.to_vec::<f32>()?;
        let mut omega = omega_l.to_vec::<f32>()?;
        let best = best_l.get_first_element::<i32>()? as usize;
        final_score.truncate(x.n_nodes);
        layer_score.truncate(x.n_nodes);
        omega.truncate(x.n_nodes);
        self.stats.executions += 1;
        self.stats.per_variant[vi] += 1;
        debug_assert!(best < x.n_nodes, "artifact picked a padding row");
        Ok(ScoreOutputs { final_score, layer_score, omega, best })
    }
}

impl ScoringBackend for XlaScorer {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn score(&mut self, inputs: &ScoreInputs) -> ScoreOutputs {
        match self.score_xla(inputs) {
            Ok(out) => out,
            Err(e) => {
                // An execute error is a bug (shapes are validated), but the
                // scheduler must not wedge: log and fall back.
                crate::log_error!("xla backend failed ({e:#}); falling back to native");
                self.stats.native_fallbacks += 1;
                self.native.score(inputs)
            }
        }
    }
}
