//! Metrics collection — the quantities the paper's evaluation reports:
//! per-step download size/time and cluster STD (Table I), per-node CPU /
//! memory / disk usage (Fig. 3a–c), download cost (Fig. 3e), and the ω
//! trace (Fig. 3f).

use crate::cluster::{ClusterState, PodId};
use crate::sched::dynamic_weight;
use crate::util::units::Bytes;

/// One per-pod deployment record — a row of Table I.
#[derive(Debug, Clone)]
pub struct PodRecord {
    /// The deployed pod.
    pub pod: PodId,
    /// Image key (`name:tag`).
    pub image: String,
    /// Name of the node it bound to.
    pub node: String,
    /// Bytes pulled from the registry over the WAN for this pod (Eq. 1;
    /// with P2P sharing enabled, peer-served layers are excluded).
    pub download: Bytes,
    /// Bytes fetched from peer edge nodes over the LAN (0 without P2P).
    pub p2p: Bytes,
    /// Seconds from bind to all-layers-ready.
    pub download_secs: f64,
    /// Cluster resource-balance STD after placement (mean of Eq. 11).
    pub std_after: f64,
    /// ω used for the winning node (0 for the Default baseline).
    pub omega: f64,
    /// S_layer of the winning node.
    pub layer_score: f64,
    /// Final S of the winning node.
    pub final_score: f64,
    /// Virtual time of the bind.
    pub at: f64,
}

/// Cluster-wide usage snapshot — a point of Fig. 3a–c.
#[derive(Debug, Clone)]
pub struct ClusterSnapshot {
    /// Virtual time of the snapshot.
    pub at: f64,
    /// Mean CPU utilisation across nodes (fraction).
    pub cpu_util: f64,
    /// Mean memory utilisation across nodes (fraction).
    pub mem_util: f64,
    /// Total disk used by image layers.
    pub disk_used: Bytes,
    /// Per-node (cpu%, mem%, disk bytes).
    pub per_node: Vec<(f64, f64, Bytes)>,
    /// Mean of Eq. 11 across nodes.
    pub std_score: f64,
}

/// Mean of Eq. 11 over the *live* nodes — the paper's cluster "STD"
/// column. Crashed (Down) nodes are excluded: they hold no load by
/// construction, and averaging their permanent zeros under churn would
/// deflate the balance metric (and the RL reward built on it).
pub fn cluster_std(state: &ClusterState) -> f64 {
    let live: Vec<f64> = state
        .nodes()
        .iter()
        .filter(|n| n.is_up())
        .map(dynamic_weight::std_score)
        .collect();
    if live.is_empty() {
        return 0.0;
    }
    live.iter().sum::<f64>() / live.len() as f64
}

/// Snapshot over the live (non-crashed) nodes; `per_node` keeps one row
/// per node id for stable Fig. 3a–c plotting, with Down rows zeroed.
pub fn snapshot(state: &ClusterState, at: f64) -> ClusterSnapshot {
    let mut cpu_sum = 0.0;
    let mut mem_sum = 0.0;
    let mut disk = Bytes::ZERO;
    let mut live = 0usize;
    let mut per_node = Vec::with_capacity(state.node_count());
    for n in state.nodes() {
        let (c, m) = n.utilisation();
        per_node.push((c, m, n.disk_used));
        if n.is_up() {
            live += 1;
            cpu_sum += c;
            mem_sum += m;
            disk += n.disk_used;
        }
    }
    let k = live.max(1) as f64;
    ClusterSnapshot {
        at,
        cpu_util: cpu_sum / k,
        mem_util: mem_sum / k,
        disk_used: disk,
        per_node,
        std_score: cluster_std(state),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, NodeId, PodBuilder, Resources};
    use crate::util::units::Bandwidth;

    #[test]
    fn snapshot_aggregates() {
        let mut state = ClusterState::new();
        for i in 0..2 {
            state.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(20.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        let mut b = PodBuilder::new();
        let pid = state.submit_pod(b.build("redis:7.2", Resources::cores_gb(2.0, 1.0)));
        state.bind(pid, NodeId(0)).unwrap();
        let s = snapshot(&state, 3.0);
        assert_eq!(s.at, 3.0);
        assert!((s.cpu_util - 0.25).abs() < 1e-9); // (0.5 + 0) / 2
        assert!((s.mem_util - 0.125).abs() < 1e-9);
        // Node 0: |0.5-0.25|/2 = 0.125; node 1: 0 → mean 0.0625.
        assert!((s.std_score - 0.0625).abs() < 1e-9);
        assert_eq!(s.per_node.len(), 2);
    }

    #[test]
    fn empty_cluster_std_is_zero() {
        let state = ClusterState::new();
        assert_eq!(cluster_std(&state), 0.0);
    }

    #[test]
    fn crashed_nodes_do_not_deflate_the_metrics() {
        let mut state = ClusterState::new();
        for i in 0..3 {
            state.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(20.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        let mut b = PodBuilder::new();
        let pid = state.submit_pod(b.build("redis:7.2", Resources::cores_gb(2.0, 1.0)));
        state.bind(pid, NodeId(0)).unwrap();
        let before = snapshot(&state, 1.0);
        state.crash_node(NodeId(2));
        let after = snapshot(&state, 2.0);
        // Averages now span the 2 live nodes, not 3: utilisation rises.
        assert!((after.cpu_util - 0.25).abs() < 1e-9); // (0.5 + 0) / 2
        assert!(after.cpu_util > before.cpu_util);
        assert!(after.std_score > before.std_score);
        assert_eq!(after.per_node.len(), 3, "rows stay per node id");
        assert_eq!(after.per_node[2], (0.0, 0.0, Bytes::ZERO));
    }
}
