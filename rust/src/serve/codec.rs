//! Line-level codec for the serve protocol: one [`InEvent`] per
//! non-blank line, with 1-based line numbers threaded into every error
//! (mirroring the trace importers' diagnostics). Blank lines and `#`
//! comments are ignored, so fixture streams can be annotated. Policy —
//! abort vs skip-and-count — is the session's job
//! ([`crate::serve::Session`]); the codec only classifies.

use super::protocol::{InEvent, ServeError};
use crate::util::json;

/// Decode one input line. Returns `Ok(None)` for blank lines and `#`
/// comments, `Ok(Some(event))` for a valid protocol object, and
/// [`ServeError::Malformed`] (carrying `lineno`) for anything else.
pub fn decode_line(line: &str, lineno: usize) -> Result<Option<InEvent>, ServeError> {
    let trimmed = line.trim();
    if trimmed.is_empty() || trimmed.starts_with('#') {
        return Ok(None);
    }
    let parsed = json::parse(trimmed)
        .map_err(|e| ServeError::Malformed { line: lineno, reason: e.to_string() })?;
    InEvent::from_json(&parsed)
        .map(Some)
        .map_err(|reason| ServeError::Malformed { line: lineno, reason })
}

/// Encode an [`InEvent`] as one protocol line (no trailing newline) —
/// `decode_line(&encode_line(ev), n)` returns the same event.
pub fn encode_line(ev: &InEvent) -> String {
    ev.to_json().to_string()
}
