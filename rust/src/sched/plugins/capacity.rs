//! Capacity constraints from the paper's problem formulation, enforced
//! "during the Prefilter and Filter plugins" (§III-C):
//!
//! - Eq. (6): storage — missing-layer bytes must fit the node's free disk.
//! - Eq. (7): the running-container limit `|C_n(t)| ≤ C_n`.

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{FilterPlugin, FilterResult};

/// The paper's §III-C capacity constraints: container slots and disk
/// headroom for the image's missing layers.
pub struct NodeCapacity;

impl FilterPlugin for NodeCapacity {
    fn name(&self) -> &'static str {
        "NodeCapacity"
    }

    fn filter(&self, ctx: &CycleContext, node: &Node) -> FilterResult {
        // Eq. (7): container count limit.
        if node.pods.len() >= node.max_containers {
            return FilterResult::Reject(format!(
                "container limit reached ({}/{})",
                node.pods.len(),
                node.max_containers
            ));
        }
        // Eq. (6): C_c^n(t) + Σ_{l∈L_n} d_l ≤ d_n.
        let need = ctx
            .required_layers
            .difference_bytes(&node.layers, &ctx.state.interner);
        if need > node.disk_free() {
            return FilterResult::Reject(format!(
                "insufficient disk: need {}, free {}",
                need,
                node.disk_free()
            ));
        }
        FilterResult::Pass
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, PodId, Resources};
    use crate::registry::hub;
    use crate::util::units::{Bandwidth, Bytes};

    #[test]
    fn container_limit_enforced() {
        let mut state = ClusterState::new();
        state.add_node(
            Node::new(
                NodeId(0),
                "n",
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(20.0),
                Bandwidth::from_mbps(10.0),
            )
            .with_max_containers(2),
        );
        let mut b = PodBuilder::new();
        for i in 0..2 {
            let pid = state.submit_pod(b.build("redis:7.2", Resources::ZERO));
            assert_eq!(pid, PodId(i));
            state.bind(pid, NodeId(0)).unwrap();
        }
        let pod = b.build("redis:7.2", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, None, Default::default(), Bytes::ZERO);
        assert!(matches!(
            NodeCapacity.filter(&ctx, state.node(NodeId(0))),
            FilterResult::Reject(r) if r.contains("container limit")
        ));
    }

    #[test]
    fn disk_constraint_counts_only_missing_layers() {
        let mut state = ClusterState::new();
        state.add_node(Node::new(
            NodeId(0),
            "n",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_mb(300.0), // wordpress (~243 MB) fits, gcc does not
            Bandwidth::from_mbps(10.0),
        ));
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let gcc = corpus.iter().find(|m| m.name == "gcc").unwrap();
        let (_, wp_layers) = state.intern_image(wp);
        let (_, gcc_layers) = state.intern_image(gcc);

        let pod = PodBuilder::new().build("gcc:13", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(gcc), gcc_layers.clone(), gcc.total_size);
        assert!(matches!(
            NodeCapacity.filter(&ctx, state.node(NodeId(0))),
            FilterResult::Reject(r) if r.contains("disk")
        ));

        // wordpress (243 MB) fits in the 300 MB disk and shares the debian
        // base with gcc — missing bytes shrink but gcc still doesn't fit.
        state.install_image(NodeId(0), &wp.image_ref(), &wp_layers).unwrap();
        let missing_after = gcc_layers.difference_bytes(
            &state.node(NodeId(0)).layers,
            &state.interner,
        );
        assert!(missing_after < gcc.total_size);
        let ctx2 = CycleContext::new(&state, &pod, Some(gcc), gcc_layers, gcc.total_size);
        assert!(matches!(
            NodeCapacity.filter(&ctx2, state.node(NodeId(0))),
            FilterResult::Reject(_)
        ));
    }

    #[test]
    fn pass_when_layers_cached() {
        let mut state = ClusterState::new();
        state.add_node(Node::new(
            NodeId(0),
            "n",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(2.0),
            Bandwidth::from_mbps(10.0),
        ));
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (_, layers) = state.intern_image(redis);
        state.install_image(NodeId(0), &redis.image_ref(), &layers).unwrap();
        // Fill the disk to the brim with the image already present.
        state.node_mut(NodeId(0)).disk_used = state.node(NodeId(0)).disk;
        let pod = PodBuilder::new().build("redis:7.2", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(redis), layers, redis.total_size);
        // All layers cached ⇒ zero missing bytes ⇒ passes despite full disk.
        assert_eq!(NodeCapacity.filter(&ctx, state.node(NodeId(0))), FilterResult::Pass);
    }
}
