//! Tiny leveled logger (the `log`/`env_logger` stack is not vendored with
//! an emitter). Level is process-global and settable from the CLI
//! (`--log-level`) or the `LRSCHED_LOG` environment variable.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// High-level progress (the default).
    Info = 2,
    /// Per-decision detail.
    Debug = 3,
    /// Everything, including hot-path chatter.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current process-global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialize from `LRSCHED_LOG` if set (error|warn|info|debug|trace).
pub fn init_from_env() {
    // det: allow(R2): stderr verbosity gate only — simulation state never
    // reads the level, so output bytes stay identical at any setting.
    if let Ok(v) = std::env::var("LRSCHED_LOG") {
        if let Some(l) = parse_level(&v) {
            set_level(l);
        }
    }
}

/// Parse a level name (case-insensitive); None for unknown names.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Would a message at `level` currently be emitted?
pub fn enabled(level: Level) -> bool {
    level <= self::level()
}

/// Emit one log line to stderr if `level` is enabled (use the
/// `log_error!`..`log_trace!` macros rather than calling this directly).
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

/// Log at [`util::logging::Level::Error`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`util::logging::Level::Warn`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`util::logging::Level::Info`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`util::logging::Level::Debug`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`util::logging::Level::Trace`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("DEBUG"), Some(Level::Debug));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
