//! LRScheduler — Algorithm 1. Combines the layer-sharing score (Eq. 3)
//! with the default-scheduler score S_K8s under the resource-adaptive
//! dynamic weight (Eqs. 11–13):
//!
//! ```text
//! for each node n:                            (lines 3–16)
//!   S_layer ← Eq. (3)
//!   S_weight ← Eq. (13);  ω ← ω₁ if S_weight = 1 else ω₂
//!   S_K8s ← default framework score
//!   S ← ω·S_layer + S_K8s                     (Eq. 4)
//! return argmax_n S                           (Eq. 5, line 17)
//! ```
//!
//! Three paper configurations are all instances of this type:
//! Default (no layer term), Layer (static ω = 4), LRScheduler (dynamic ω).

use super::context::CycleContext;
use super::dynamic_weight::{weight_for, WeightParams, WeightPolicy};
use super::framework::{select_best, Framework, NodeScore, Unschedulable};
use super::layer_score;
use super::scoring::{ScoreArena, ScoreInputs, ScoreOutputs, ScoringBackend, NEG_MASK};
use crate::cluster::NodeId;
use crate::util::units::Bytes;

/// The outcome of one scheduling cycle.
#[derive(Debug, Clone)]
pub struct Decision {
    /// The winning node.
    pub node: NodeId,
    /// Final S^{k,n}(t) of the winning node.
    pub final_score: f64,
    /// Its S_layer (Eq. 3).
    pub layer_score: f64,
    /// Its S_K8s.
    pub k8s_score: f64,
    /// The ω used for the winning node.
    pub omega: f64,
    /// Bytes the node must download (Eq. 1) — the paper's headline metric.
    pub download_cost: Bytes,
    /// The winning node's per-plugin `(plugin name, normalized score)`
    /// breakdown from the framework score pass, in plugin registration
    /// order — the observability surface `lrsched serve` emits per
    /// decision. Empty for schedulers that bypass the framework scorers
    /// (the RL pick) and for dense-backend wins whose node fell outside
    /// the recorded feasible set.
    pub breakdown: Vec<(&'static str, f64)>,
}

/// Running ω-usage statistics (regenerates Fig. 3f).
///
/// Decisions are bucketed by comparing the winning ω against the policy's
/// parameters: ω₁, ω₂, or — for the `ThreeLevel`/`Linear` policies whose
/// weights land strictly between them — a separate mid bucket. (The seed
/// counted *any* ω ≠ ω₁ as ω₂, so e.g. a ThreeLevel 1.25 decision
/// corrupted the Fig. 3f ω₂ column.)
#[derive(Debug, Clone, Default)]
pub struct WeightStats {
    /// Decisions taken at ω₁.
    pub omega1_used: u64,
    /// Decisions taken at ω₂.
    pub omega2_used: u64,
    /// Decisions whose ω matched neither ω₁ nor ω₂ (mid-range weights).
    pub omega_mid_used: u64,
    /// ω of the *winning* node per decision, in order.
    pub omega_trace: Vec<f64>,
}

/// The scheduler. `policy = None` reproduces the Default baseline
/// (S = S_K8s); `Some(Static(4.0))` is the Layer baseline; the paper's
/// LRScheduler is `Some(TwoLevel)`.
pub struct LrScheduler {
    /// Configuration name (`default` / `layer` / `lrscheduler`).
    pub name: String,
    framework: Framework,
    /// Dynamic-weight parameters (ω₁, ω₂, thresholds).
    pub params: WeightParams,
    /// Weight policy; None reproduces the Default baseline.
    pub policy: Option<WeightPolicy>,
    /// Dense scoring backend (XLA artifact). None ⇒ native per-node math.
    backend: Option<Box<dyn ScoringBackend>>,
    /// Persistent dense-input arena for the backend path — reused across
    /// cycles instead of rebuilding O(N·L) buffers from zeros each time.
    arena: ScoreArena,
    /// Running ω-usage statistics (Fig. 3f).
    pub stats: WeightStats,
}

impl LrScheduler {
    /// Assemble a scheduler from a framework profile and weight policy.
    pub fn new(name: &str, framework: Framework, policy: Option<WeightPolicy>) -> LrScheduler {
        LrScheduler {
            name: name.to_string(),
            framework,
            params: WeightParams::default(),
            policy,
            backend: None,
            arena: ScoreArena::new(),
            stats: WeightStats::default(),
        }
    }

    /// The paper's three experimental configurations (§VI-A).
    pub fn default_scheduler(framework: Framework) -> LrScheduler {
        LrScheduler::new("default", framework, None)
    }

    /// The Layer baseline: static ω = 4.
    pub fn layer_scheduler(framework: Framework) -> LrScheduler {
        LrScheduler::new("layer", framework, Some(WeightPolicy::Static(4.0)))
    }

    /// The paper's LRScheduler: two-level dynamic ω.
    pub fn lr_scheduler(framework: Framework) -> LrScheduler {
        LrScheduler::new("lrscheduler", framework, Some(WeightPolicy::TwoLevel))
    }

    /// Install a dense scoring backend (the XLA runtime).
    pub fn with_backend(mut self, backend: Box<dyn ScoringBackend>) -> LrScheduler {
        self.backend = Some(backend);
        self
    }

    /// Name of the installed scoring backend (`native` without one).
    pub fn backend_name(&self) -> &'static str {
        self.backend.as_ref().map(|b| b.name()).unwrap_or("native")
    }

    /// Run one scheduling cycle (Algorithm 1).
    pub fn schedule(&mut self, ctx: &CycleContext) -> Result<Decision, Unschedulable> {
        self.schedule_with_pool(ctx, None)
    }

    /// [`LrScheduler::schedule`], optionally fanning the per-node filter,
    /// score-plugin, and layer-sharing passes across a
    /// [`crate::sim::shard::LanePool`]. With `pool = None` this *is* the
    /// sequential cycle; with a pool, per-node outputs land at fixed
    /// indices and every reduction (normalize, weighted sum, argmax) runs
    /// on the calling thread in node order, so the decision is
    /// bit-identical either way. The dense backend path stays on the
    /// calling thread (the arena fill is already one fused pass).
    pub fn schedule_with_pool(
        &mut self,
        ctx: &CycleContext,
        pool: Option<&crate::sim::shard::LanePool>,
    ) -> Result<Decision, Unschedulable> {
        let feasible = match pool {
            Some(p) => self.framework.feasible_with_pool(ctx, p)?,
            None => self.framework.feasible(ctx)?,
        };
        let k8s_scores = match pool {
            Some(p) => self.framework.score_with_pool(ctx, &feasible, p),
            None => self.framework.score(ctx, &feasible),
        };
        let dense = self.backend.is_some();
        let decision = match self.policy {
            None => {
                // Default baseline: S = S_K8s.
                let best = select_best(&k8s_scores).expect("nonempty feasible set");
                let breakdown = best.breakdown.clone();
                self.decision_for(ctx, best.node, best.total, 0.0, best.total, 0.0, breakdown)
            }
            Some(policy) if dense => self.schedule_dense(ctx, policy, &k8s_scores),
            Some(policy) => match pool {
                Some(p) => self.schedule_native_pool(ctx, policy, &k8s_scores, p),
                None => self.schedule_native(ctx, policy, &k8s_scores),
            },
        };
        if let Some(policy) = self.policy {
            if !matches!(policy, WeightPolicy::Static(_)) {
                // Bucket by the actual parameter values: ThreeLevel/Linear
                // produce mid-range weights that are neither ω₁ nor ω₂.
                if (decision.omega - self.params.omega1).abs() < 1e-9 {
                    self.stats.omega1_used += 1;
                } else if (decision.omega - self.params.omega2).abs() < 1e-9 {
                    self.stats.omega2_used += 1;
                } else {
                    self.stats.omega_mid_used += 1;
                }
            }
            self.stats.omega_trace.push(decision.omega);
        }
        Ok(decision)
    }

    fn decision_for(
        &self,
        ctx: &CycleContext,
        node: NodeId,
        final_score: f64,
        layer: f64,
        k8s: f64,
        omega: f64,
        breakdown: Vec<(&'static str, f64)>,
    ) -> Decision {
        Decision {
            node,
            final_score,
            layer_score: layer,
            k8s_score: k8s,
            omega,
            download_cost: layer_score::download_cost(ctx, ctx.state.node(node)),
            breakdown,
        }
    }

    /// Native path: per-feasible-node math straight from the layer sets.
    fn schedule_native(
        &mut self,
        ctx: &CycleContext,
        policy: WeightPolicy,
        k8s_scores: &[NodeScore],
    ) -> Decision {
        // (index, S, S_layer, ω) of the running first-max winner; the
        // Decision (and its breakdown clone) is built once after the loop.
        let mut best: Option<(usize, f64, f64, f64)> = None;
        for (i, ns) in k8s_scores.iter().enumerate() {
            let node = ctx.state.node(ns.node);
            let local = layer_score::local_bytes(ctx, node);
            let s_layer = layer_score::layer_sharing_score(local, ctx.required_bytes);
            let omega = weight_for(policy, &self.params, node, local);
            let s = omega * s_layer + ns.total;
            let better = match &best {
                None => true,
                Some(b) => s > b.1,
            };
            if better {
                best = Some((i, s, s_layer, omega));
            }
        }
        let (i, s, s_layer, omega) = best.expect("nonempty feasible set");
        let ns = &k8s_scores[i];
        self.decision_for(ctx, ns.node, s, s_layer, ns.total, omega, ns.breakdown.clone())
    }

    /// [`LrScheduler::schedule_native`] with the per-node layer/weight math
    /// fanned across the pool; the first-max argmax reduction runs on the
    /// calling thread in `k8s_scores` order, exactly like the sequential
    /// loop, so the winner (and every recorded score) is bit-identical.
    fn schedule_native_pool(
        &self,
        ctx: &CycleContext,
        policy: WeightPolicy,
        k8s_scores: &[NodeScore],
        pool: &crate::sim::shard::LanePool,
    ) -> Decision {
        let mut lw: Vec<(f64, f64)> = vec![(0.0, 0.0); k8s_scores.len()];
        let params = &self.params;
        crate::sim::shard::par_fill(pool, &mut lw, &|i, out| {
            let node = ctx.state.node(k8s_scores[i].node);
            let local = layer_score::local_bytes(ctx, node);
            let s_layer = layer_score::layer_sharing_score(local, ctx.required_bytes);
            let omega = weight_for(policy, params, node, local);
            *out = (s_layer, omega);
        });
        let mut best: Option<(usize, f64, f64, f64)> = None;
        for (i, (ns, &(s_layer, omega))) in k8s_scores.iter().zip(&lw).enumerate() {
            let s = omega * s_layer + ns.total;
            let better = match &best {
                None => true,
                Some(b) => s > b.1,
            };
            if better {
                best = Some((i, s, s_layer, omega));
            }
        }
        let (i, s, s_layer, omega) = best.expect("nonempty feasible set");
        let ns = &k8s_scores[i];
        self.decision_for(ctx, ns.node, s, s_layer, ns.total, omega, ns.breakdown.clone())
    }

    /// Dense path: fill the persistent arena and run the installed backend.
    /// Only the TwoLevel policy is expressible in the AOT artifact (the
    /// paper's Algorithm 1); other policies fall back to native.
    fn schedule_dense(
        &mut self,
        ctx: &CycleContext,
        policy: WeightPolicy,
        k8s_scores: &[NodeScore],
    ) -> Decision {
        if !matches!(policy, WeightPolicy::TwoLevel) {
            return self.schedule_native(ctx, policy, k8s_scores);
        }
        let inputs = self.arena.fill(ctx, k8s_scores, &self.params);
        let out: ScoreOutputs = self.backend.as_mut().unwrap().score(inputs);
        // A masked/padding winner means the backend or its inputs are
        // corrupt — binding that node would corrupt cluster state, so this
        // must hold in release builds too, not just under debug_assert.
        assert!(
            out.final_score[out.best] > NEG_MASK / 2.0,
            "scoring backend chose a masked node (best={}, score={})",
            out.best,
            out.final_score[out.best]
        );
        let node = NodeId(out.best as u32);
        let (k8s, breakdown) = k8s_scores
            .iter()
            .find(|ns| ns.node == node)
            .map(|ns| (ns.total, ns.breakdown.clone()))
            .unwrap_or((0.0, Vec::new()));
        self.decision_for(
            ctx,
            node,
            out.final_score[out.best] as f64,
            out.layer_score[out.best] as f64,
            k8s,
            out.omega[out.best] as f64,
            breakdown,
        )
    }
}

/// Build dense inputs for the backend from a cycle. Public so the runtime
/// integration tests and benches can drive both backends identically.
pub fn build_inputs(
    ctx: &CycleContext,
    k8s_scores: &[NodeScore],
    params: &WeightParams,
) -> ScoreInputs {
    let n = ctx.state.node_count();
    let l = ctx.state.interner.len();
    let mut x = ScoreInputs::zeros(n, l, *params);
    x.sizes_mb = ctx.state.interner.sizes_mb_padded(l);
    ctx.required_layers.write_indicator(&mut x.req);
    for (i, node) in ctx.state.nodes().iter().enumerate() {
        node.layers.write_indicator(&mut x.present[i * l..(i + 1) * l]);
        x.cpu_used[i] = node.used.cpu.0 as f32;
        x.cpu_cap[i] = node.capacity.cpu.0.max(1) as f32;
        x.mem_used[i] = node.used.memory.0 as f32;
        x.mem_cap[i] = node.capacity.memory.0.max(1) as f32;
    }
    for ns in k8s_scores {
        x.k8s_score[ns.node.0 as usize] = ns.total as f32;
        x.feasible[ns.node.0 as usize] = 1.0;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, PodBuilder, Resources};
    use crate::registry::{hub, MetadataCache, Registry, Watcher};
    use crate::sched::profiles::default_framework;
    use crate::sched::scoring::NativeScorer;
    use crate::util::units::{Bandwidth, Bytes as B};

    fn cluster(n: u32) -> ClusterState {
        let mut s = ClusterState::new();
        for i in 0..n {
            s.add_node(Node::new(
                NodeId(i),
                &format!("worker{}", i + 1),
                Resources::cores_gb(4.0, 4.0),
                B::from_gb(30.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        s
    }

    fn cache() -> MetadataCache {
        let reg = Registry::with_corpus();
        let mut c = MetadataCache::new("/tmp/unused.json");
        Watcher::with_default_interval().poll(0.0, &reg, &mut c);
        c
    }

    #[test]
    fn lr_prefers_node_with_layers() {
        let mut state = cluster(3);
        let cache = cache();
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let (_, layers) = state.intern_image(wp);
        state.install_image(NodeId(2), &wp.image_ref(), &layers).unwrap();

        let mut b = PodBuilder::new();
        let pod = b.build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);

        let mut lr = LrScheduler::lr_scheduler(default_framework());
        let d = lr.schedule(&ctx).unwrap();
        assert_eq!(d.node, NodeId(2));
        assert!((d.layer_score - 100.0).abs() < 1e-9);
        assert_eq!(d.omega, 2.0, "idle node with layers gets ω₁");
        assert_eq!(d.download_cost, B::ZERO);
        assert_eq!(lr.stats.omega1_used, 1);
    }

    #[test]
    fn default_ignores_layers() {
        let mut state = cluster(3);
        let cache = cache();
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let (_, layers) = state.intern_image(wp);
        state.install_image(NodeId(2), &wp.image_ref(), &layers).unwrap();
        // Make node 2 busy so LeastAllocated prefers 0/1. Note ImageLocality
        // still gives node 2 some credit — use a huge request to dominate.
        let mut b = PodBuilder::new();
        let filler = b.build("busybox:1.36", Resources::cores_gb(3.0, 3.0));
        let fid = state.submit_pod(filler);
        state.bind(fid, NodeId(2)).unwrap();

        let pod = b.build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        let mut def = LrScheduler::default_scheduler(default_framework());
        let d = def.schedule(&ctx).unwrap();
        assert_ne!(d.node, NodeId(2), "default scheduler avoids the busy node");
        assert_eq!(d.omega, 0.0);
    }

    #[test]
    fn static_layer_weight_dominates() {
        let mut state = cluster(3);
        let cache = cache();
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let (_, layers) = state.intern_image(wp);
        state.install_image(NodeId(2), &wp.image_ref(), &layers).unwrap();
        // Busy node 2: static ω=4 should still pick it (4×100 = 400 ≫ ΔS_K8s)
        let mut b = PodBuilder::new();
        let filler = b.build("busybox:1.36", Resources::cores_gb(3.0, 3.0));
        let fid = state.submit_pod(filler);
        state.bind(fid, NodeId(2)).unwrap();

        let pod = b.build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        let mut layer = LrScheduler::layer_scheduler(default_framework());
        let d = layer.schedule(&ctx).unwrap();
        assert_eq!(d.node, NodeId(2));
        assert_eq!(d.omega, 4.0);
    }

    #[test]
    fn three_level_mid_weight_counts_in_its_own_bucket() {
        use crate::sched::dynamic_weight::WeightPolicy;
        let mut state = cluster(3);
        let cache = cache();
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let (_, layers) = state.intern_image(wp);
        state.install_image(NodeId(2), &wp.image_ref(), &layers).unwrap();

        let mut b = PodBuilder::new();
        // Nodes 0/1: nearly full → infeasible for a 0.5-core pod.
        for i in 0..2 {
            let filler = b.build("busybox:1.36", Resources::cores_gb(3.8, 3.8));
            let fid = state.submit_pod(filler);
            state.bind(fid, NodeId(i)).unwrap();
        }
        // Node 2: cpu 50%, mem 0% → S_CPU passes, S_STD (0.25) fails the
        // gate, layers local → ThreeLevel lands on the (ω₁+ω₂)/2 = 1.25
        // mid weight.
        let skew = b.build("busybox:1.36", Resources::cores_gb(2.0, 0.0));
        let sid = state.submit_pod(skew);
        state.bind(sid, NodeId(2)).unwrap();

        let pod = b.build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        let mut three =
            LrScheduler::new("three-level", default_framework(), Some(WeightPolicy::ThreeLevel));
        let d = three.schedule(&ctx).unwrap();
        assert_eq!(d.node, NodeId(2), "only feasible node");
        assert!((d.omega - 1.25).abs() < 1e-9, "mid weight expected, got {}", d.omega);
        // The seed miscounted any ω ≠ ω₁ as ω₂; mid decisions now have
        // their own bucket and leave the ω₂ column clean.
        assert_eq!(three.stats.omega1_used, 0);
        assert_eq!(three.stats.omega2_used, 0);
        assert_eq!(three.stats.omega_mid_used, 1);
        assert_eq!(three.stats.omega_trace, vec![1.25]);
    }

    #[test]
    fn dense_backend_agrees_with_native() {
        let mut state = cluster(4);
        let cache = cache();
        let corpus = hub::corpus();
        // Warm different nodes with different images.
        for (i, name) in [(0u32, "redis"), (1, "ghost"), (3, "nginx")] {
            let m = corpus.iter().find(|m| m.name == name).unwrap();
            let (_, layers) = state.intern_image(m);
            state.install_image(NodeId(i), &m.image_ref(), &layers).unwrap();
        }
        let mut b = PodBuilder::new();
        for image in ["ghost:5", "redis:7.2", "nginx:1.25", "wordpress:6.4"] {
            let pod = b.build(image, Resources::cores_gb(0.5, 0.5));
            let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
            let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
            let mut native = LrScheduler::lr_scheduler(default_framework());
            let mut dense = LrScheduler::lr_scheduler(default_framework())
                .with_backend(Box::new(NativeScorer));
            let dn = native.schedule(&ctx).unwrap();
            let dd = dense.schedule(&ctx).unwrap();
            assert_eq!(dn.node, dd.node, "backends disagree for {image}");
            assert!((dn.final_score - dd.final_score).abs() < 1e-3);
            assert_eq!(dn.omega, dd.omega);
        }
    }

    #[test]
    fn pooled_cycle_matches_sequential_bit_for_bit() {
        use crate::sim::shard::LanePool;
        let mut state = cluster(5);
        let cache = cache();
        let corpus = hub::corpus();
        for (i, name) in [(0u32, "redis"), (2, "wordpress"), (4, "nginx")] {
            let m = corpus.iter().find(|m| m.name == name).unwrap();
            let (_, layers) = state.intern_image(m);
            state.install_image(NodeId(i), &m.image_ref(), &layers).unwrap();
        }
        let pool = LanePool::new(3);
        let mut b = PodBuilder::new();
        for image in ["wordpress:6.4", "redis:7.2", "nginx:1.25"] {
            let pod = b.build(image, Resources::cores_gb(0.5, 0.5));
            let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
            let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
            let mut seq = LrScheduler::lr_scheduler(default_framework());
            let mut par = LrScheduler::lr_scheduler(default_framework());
            let ds = seq.schedule(&ctx).unwrap();
            let dp = par.schedule_with_pool(&ctx, Some(&pool)).unwrap();
            assert_eq!(ds.node, dp.node, "winner differs for {image}");
            assert_eq!(ds.final_score.to_bits(), dp.final_score.to_bits());
            assert_eq!(ds.layer_score.to_bits(), dp.layer_score.to_bits());
            assert_eq!(ds.k8s_score.to_bits(), dp.k8s_score.to_bits());
            assert_eq!(ds.omega.to_bits(), dp.omega.to_bits());
            assert_eq!(ds.download_cost, dp.download_cost);
        }
    }

    #[test]
    fn unschedulable_when_no_node_fits() {
        let mut state = cluster(2);
        let cache = cache();
        let mut b = PodBuilder::new();
        let pod = b.build("redis:7.2", Resources::cores_gb(8.0, 8.0)); // too big
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        let mut lr = LrScheduler::lr_scheduler(default_framework());
        let err = lr.schedule(&ctx).unwrap_err();
        assert_eq!(err.rejections.len(), 2);
    }
}
