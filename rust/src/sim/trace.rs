//! Real-trace replay: import public cluster traces and replay them through
//! the event engine instead of the synthetic Zipf workload.
//!
//! The paper validates LRScheduler on a real system; related work (e.g.
//! TD3-Sched, the joint task-scheduling/image-caching line) grounds its
//! evaluation on measured cluster traces. This module closes that gap for
//! the `scale` harness with a three-stage pipeline:
//!
//! 1. **Parse** — a streaming, line-by-line CSV importer (no full-file
//!    buffering, so multi-million-row traces replay in bounded memory)
//!    converts each row into the format-agnostic [`TraceEvent`]
//!    intermediate representation. Two concrete formats are supported:
//!    Alibaba cluster-trace `batch_task`-style CSV ([`TraceFormat::Alibaba`])
//!    and Azure packing-trace-style CSV ([`TraceFormat::Azure`]).
//! 2. **Synthesize** — public traces name tasks/VM types but carry no image
//!    manifests, so [`Trace::synthesize_registry`] deterministically hashes
//!    each app key into a layer stack (shared OS base + shared runtime
//!    layers + unique app layers). Equal app keys always map to the same
//!    image, so the trace's app-popularity skew becomes image-popularity
//!    skew — exactly the signal layer-aware scheduling exploits.
//! 3. **Replay** — [`Trace::arrivals`] builds `(arrival-offset, Pod)` pairs
//!    that [`crate::sim::Simulation::run_arrivals`] pushes into the event
//!    queue, preserving the trace's burstiness and heavy-tailed lifetimes.
//!    [`TraceOptions::speedup`] compresses virtual time and
//!    [`TraceOptions::limit`] truncates the trace so runs stay bounded.
//!
//! Malformed input is handled per [`ErrorMode`]: `Strict` rejects the first
//! bad row (with its line number), `Lenient` skips bad rows, drops
//! duplicate task ids, and re-sorts out-of-order timestamps — every repair
//! is counted in [`TraceStats`], never silent.
//!
//! See `docs/ARCHITECTURE.md` ("Trace replay") for the pipeline diagram and
//! `docs/SCALE.md` for copy-pasteable CLI runs against the bundled
//! fixtures under `rust/tests/fixtures/`.

use crate::cluster::{Pod, PodBuilder, Resources};
use crate::registry::hub::digest_for;
use crate::registry::{ImageMetadata, LayerMetadata, Registry};
use crate::util::rng::Pcg;
use crate::util::units::{Bytes, MilliCpu};
use std::collections::{BTreeSet, HashSet};
use std::fmt;
use std::io::BufRead;
use std::path::Path;

/// Reference edge-node shape used to de-normalize trace resource columns
/// (Alibaba `plan_cpu`/`plan_mem` are percentages of a machine; Azure
/// packing `core`/`memory` are fractions of a server). Matches the
/// `scale` fleet built by `exp::common::scale_nodes`: 4 cores / 8 GB.
pub const REF_NODE_CORES: f64 = 4.0;
/// Reference node memory in GB (see [`REF_NODE_CORES`]).
pub const REF_NODE_MEM_GB: f64 = 8.0;

/// Floor for de-normalized CPU requests: traces contain near-zero plans,
/// and a zero-request pod would trivially fit everywhere, hiding the
/// packing problem the replay is meant to exercise.
const MIN_CPU_MILLI: u64 = 10;
/// Floor for de-normalized memory requests (see [`MIN_CPU_MILLI`]).
const MIN_MEM_BYTES: u64 = 16_000_000;

const SECS_PER_DAY: f64 = 86_400.0;

/// Which on-disk trace dialect to parse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// Alibaba cluster-trace `batch_task.csv` dialect: headerless rows of
    /// `task_name,instance_num,job_name,task_type,status,start_time,`
    /// `end_time,plan_cpu,plan_mem` with times in seconds, `plan_cpu` in
    /// percent-of-core units (100 = 1 core) and `plan_mem` in percent of
    /// a machine's memory ([`REF_NODE_MEM_GB`]). Each row expands into
    /// `instance_num` pods. The app key is `task_name` (recurring DAG
    /// node names carry the popularity skew).
    Alibaba,
    /// Azure packing-trace dialect: a header line naming at least
    /// `vmid,starttime,endtime,core,memory` (an `appname`/`vmtypeid`/
    /// `tenantid` column, in that priority order, provides the app key),
    /// times in fractional days, and `core`/`memory` as fractions of a
    /// server ([`REF_NODE_CORES`]/[`REF_NODE_MEM_GB`]).
    Azure,
}

impl TraceFormat {
    /// Parse a CLI-style format name (`alibaba` | `azure`).
    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s {
            "alibaba" => Some(TraceFormat::Alibaba),
            "azure" => Some(TraceFormat::Azure),
            _ => None,
        }
    }

    /// CLI-facing name of the format.
    pub fn label(&self) -> &'static str {
        match self {
            TraceFormat::Alibaba => "alibaba",
            TraceFormat::Azure => "azure",
        }
    }
}

/// How parse problems are handled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorMode {
    /// Fail on the first malformed row, duplicate task id, or
    /// out-of-order timestamp — with the offending line number.
    Strict,
    /// Skip malformed rows and duplicate task ids, and re-sort
    /// out-of-order timestamps; every repair is counted in
    /// [`TraceStats`].
    Lenient,
}

/// Importer configuration.
#[derive(Debug, Clone)]
pub struct TraceOptions {
    /// Trace dialect to parse.
    pub format: TraceFormat,
    /// Strict vs lenient error handling.
    pub mode: ErrorMode,
    /// Virtual-time compression: arrival offsets *and* task durations are
    /// divided by this factor (> 1 makes week-long traces replayable in
    /// bounded virtual time while preserving the workload's shape).
    pub speedup: f64,
    /// Stop after this many parsed events (None = whole trace). The
    /// limit truncates in *file order* while streaming — before any
    /// lenient re-sort — so on an out-of-order trace the kept window is
    /// the first N events of the file, not the N earliest timestamps
    /// (the trade keeps multi-million-row imports one bounded pass).
    pub limit: Option<usize>,
    /// Seed for the deterministic layer-composition synthesis.
    pub seed: u64,
}

impl Default for TraceOptions {
    fn default() -> TraceOptions {
        TraceOptions {
            format: TraceFormat::Alibaba,
            mode: ErrorMode::Lenient,
            speedup: 1.0,
            limit: None,
            seed: 42,
        }
    }
}

/// Format-agnostic intermediate representation of one task/VM in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// 1-based source line this event was parsed from.
    pub line: usize,
    /// Arrival offset in seconds from trace start (normalized so the
    /// earliest event is at 0, then divided by [`TraceOptions::speedup`]).
    pub submit_at: f64,
    /// Unique task instance id (duplicate detection key before instance
    /// expansion; unique per emitted event afterwards).
    pub task_id: String,
    /// Image identity / layer-synthesis key. Equal keys replay as the
    /// same image, preserving the trace's app-popularity skew.
    pub app: String,
    /// De-normalized CPU request in millicores.
    pub cpu_milli: u64,
    /// De-normalized memory request in bytes.
    pub mem_bytes: u64,
    /// Task lifetime in (speedup-scaled) seconds; None = runs forever
    /// (the trace row had no end time — a service, or a task still
    /// running when the trace window closed).
    pub duration_secs: Option<f64>,
}

/// What went wrong while importing a trace.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceError {
    /// I/O failure reading the trace.
    Io(String),
    /// A row could not be parsed (strict mode only; lenient skips).
    Malformed {
        /// 1-based line number of the offending row.
        line: usize,
        /// Human-readable parse failure.
        reason: String,
    },
    /// Timestamps went backwards (strict mode only; lenient re-sorts).
    OutOfOrder {
        /// 1-based line number of the first row that went back in time.
        line: usize,
    },
    /// The same task id appeared twice (strict mode only; lenient drops
    /// the later occurrence).
    DuplicateTask {
        /// 1-based line number of the second occurrence.
        line: usize,
        /// The duplicated task id.
        task: String,
    },
    /// The trace contained no usable rows.
    Empty,
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace I/O error: {e}"),
            TraceError::Malformed { line, reason } => {
                write!(f, "trace line {line}: {reason}")
            }
            TraceError::OutOfOrder { line } => {
                write!(f, "trace line {line}: timestamp out of order (strict mode)")
            }
            TraceError::DuplicateTask { line, task } => {
                write!(f, "trace line {line}: duplicate task id {task:?} (strict mode)")
            }
            TraceError::Empty => write!(f, "trace contained no usable rows"),
        }
    }
}

impl std::error::Error for TraceError {}

/// Importer bookkeeping: what was parsed, what was repaired, what was
/// dropped. Lenient-mode repairs are visible here, never silent.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceStats {
    /// Data rows seen (excluding blank/comment/header lines).
    pub rows: usize,
    /// Events emitted (after instance expansion and `limit` truncation).
    pub events: usize,
    /// Malformed rows skipped (lenient mode).
    pub skipped: usize,
    /// Duplicate task ids dropped (lenient mode).
    pub duplicates: usize,
    /// Whether out-of-order timestamps were re-sorted (lenient mode).
    pub resorted: bool,
    /// Replayed span in (speedup-scaled) seconds: offset of the last
    /// arrival.
    pub span_secs: f64,
    /// Distinct app keys (= synthesized images).
    pub apps: usize,
}

/// A parsed trace, ready to synthesize a registry and build arrivals.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Normalized events, sorted by `submit_at`.
    pub events: Vec<TraceEvent>,
    /// Importer bookkeeping.
    pub stats: TraceStats,
    /// Layer-synthesis seed carried from [`TraceOptions::seed`].
    seed: u64,
}

/// One raw row before normalization (absolute trace timestamps).
struct RawRow {
    task_id: String,
    app: String,
    start: f64,
    /// Absolute end time; None = no end recorded.
    end: Option<f64>,
    cpu_milli: u64,
    mem_bytes: u64,
    /// Pods to expand this row into (Alibaba `instance_num`).
    instances: u64,
}

/// Parse a trace file from `path`. Files ending in `.gz` are gzip
/// members (real cluster traces ship compressed — e.g. Alibaba's
/// `batch_task.csv.gz`): they are decompressed in memory via the
/// dependency-free [`crate::util::gzip`] decoder and then streamed
/// line-by-line exactly like a plain file.
pub fn load(path: &Path, opts: &TraceOptions) -> Result<Trace, TraceError> {
    if path.extension().and_then(|e| e.to_str()) == Some("gz") {
        let raw = std::fs::read(path)
            .map_err(|e| TraceError::Io(format!("{}: {e}", path.display())))?;
        let plain = crate::util::gzip::decompress(&raw)
            .map_err(|e| TraceError::Io(format!("{}: {e}", path.display())))?;
        return parse_reader(std::io::Cursor::new(plain), opts);
    }
    let file = std::fs::File::open(path)
        .map_err(|e| TraceError::Io(format!("{}: {e}", path.display())))?;
    parse_reader(std::io::BufReader::new(file), opts)
}

/// Parse a trace from any buffered reader, line by line (no full-file
/// buffering). Blank lines and `#`-comments are skipped in both modes; a
/// literal `task_name…` header on an Alibaba trace is tolerated.
pub fn parse_reader<R: BufRead>(reader: R, opts: &TraceOptions) -> Result<Trace, TraceError> {
    assert!(opts.speedup > 0.0, "trace speedup must be positive");
    let mut stats = TraceStats::default();
    let mut events: Vec<TraceEvent> = Vec::new();
    let mut seen_tasks: HashSet<String> = HashSet::new();
    // Azure column map, built from the header line.
    let mut azure_cols: Option<AzureCols> = None;
    let limit = opts.limit.unwrap_or(usize::MAX);

    'lines: for (idx, line) in reader.lines().enumerate() {
        let lineno = idx + 1;
        let line = line.map_err(|e| TraceError::Io(e.to_string()))?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        match opts.format {
            TraceFormat::Alibaba => {
                // Tolerate a header on the first data line (the real
                // trace has none; comment/blank lines may precede it).
                // Matching the first two header column names keeps a
                // task literally named `task_name…` from false-matching.
                if stats.rows == 0 && trimmed.starts_with("task_name,instance_num") {
                    continue;
                }
            }
            TraceFormat::Azure => {
                if azure_cols.is_none() {
                    azure_cols = Some(AzureCols::from_header(trimmed, lineno)?);
                    continue;
                }
            }
        }
        stats.rows += 1;
        let parsed = match opts.format {
            TraceFormat::Alibaba => parse_alibaba_row(trimmed),
            TraceFormat::Azure => {
                parse_azure_row(trimmed, azure_cols.as_ref().expect("header parsed"))
            }
        };
        let row = match parsed {
            Ok(row) => row,
            Err(reason) => match opts.mode {
                ErrorMode::Strict => {
                    return Err(TraceError::Malformed { line: lineno, reason })
                }
                ErrorMode::Lenient => {
                    stats.skipped += 1;
                    continue;
                }
            },
        };
        if !seen_tasks.insert(row.task_id.clone()) {
            match opts.mode {
                ErrorMode::Strict => {
                    return Err(TraceError::DuplicateTask { line: lineno, task: row.task_id })
                }
                ErrorMode::Lenient => {
                    stats.duplicates += 1;
                    continue;
                }
            }
        }
        for k in 0..row.instances {
            if events.len() >= limit {
                break 'lines;
            }
            let task_id = if row.instances == 1 {
                row.task_id.clone()
            } else {
                format!("{}#{k}", row.task_id)
            };
            events.push(TraceEvent {
                line: lineno,
                submit_at: row.start, // absolute; normalized below
                task_id,
                app: row.app.clone(),
                cpu_milli: row.cpu_milli,
                mem_bytes: row.mem_bytes,
                duration_secs: row.end.map(|e| e - row.start),
            });
        }
    }

    if events.is_empty() {
        return Err(TraceError::Empty);
    }

    // Order check on the raw timestamps (the trace's own order).
    let ooo_line =
        events.windows(2).find(|w| w[1].submit_at < w[0].submit_at).map(|w| w[1].line);
    if let Some(line) = ooo_line {
        match opts.mode {
            ErrorMode::Strict => return Err(TraceError::OutOfOrder { line }),
            ErrorMode::Lenient => {
                stats.resorted = true;
                // Stable: equal timestamps keep the trace's row order.
                events.sort_by(|a, b| a.submit_at.partial_cmp(&b.submit_at).unwrap());
            }
        }
    }

    // Normalize: earliest arrival at t=0, then compress by `speedup`.
    let t0 = events[0].submit_at;
    for ev in &mut events {
        ev.submit_at = (ev.submit_at - t0) / opts.speedup;
        if let Some(d) = &mut ev.duration_secs {
            *d /= opts.speedup;
        }
    }

    stats.events = events.len();
    stats.span_secs = events.last().map(|e| e.submit_at).unwrap_or(0.0);
    stats.apps = events.iter().map(|e| e.app.as_str()).collect::<BTreeSet<_>>().len();
    Ok(Trace { events, stats, seed: opts.seed })
}

/// Split and validate one headerless Alibaba `batch_task` row.
fn parse_alibaba_row(line: &str) -> Result<RawRow, String> {
    let cols: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
    if cols.len() < 9 {
        return Err(format!("expected 9 columns, found {}", cols.len()));
    }
    let task_name = cols[0];
    let job_name = cols[2];
    if task_name.is_empty() {
        return Err("empty task_name".to_string());
    }
    let instances = match cols[1] {
        "" => 1,
        s => s.parse::<u64>().map_err(|_| format!("bad instance_num {s:?}"))?,
    };
    if instances == 0 {
        // A zero-instance row would vanish silently from the replay;
        // surface it as malformed (strict rejects, lenient counts it).
        return Err("instance_num is 0".to_string());
    }
    let start = parse_f64(cols[5], "start_time")?;
    let end = match cols[6] {
        "" => None,
        s => Some(parse_f64(s, "end_time")?),
    };
    if let Some(e) = end {
        if e < start {
            return Err(format!("end_time {e} before start_time {start}"));
        }
    }
    // plan_cpu: 100 = 1 core → ×10 millicores.
    let plan_cpu = parse_f64(cols[7], "plan_cpu")?;
    // plan_mem: percent of the reference machine's memory.
    let plan_mem = parse_f64(cols[8], "plan_mem")?;
    if plan_cpu < 0.0 || plan_mem < 0.0 {
        return Err("negative resource plan".to_string());
    }
    Ok(RawRow {
        task_id: format!("{task_name}@{job_name}"),
        app: task_name.to_string(),
        start,
        end,
        cpu_milli: ((plan_cpu * 10.0).round() as u64).max(MIN_CPU_MILLI),
        mem_bytes: ((plan_mem / 100.0 * REF_NODE_MEM_GB * 1e9).round() as u64)
            .max(MIN_MEM_BYTES),
        instances,
    })
}

/// Column indices resolved from an Azure-style header line.
struct AzureCols {
    /// Header width: data rows with fewer columns are malformed (a
    /// truncated row must not silently pass as "no end time").
    width: usize,
    id: usize,
    /// App-key column (`appname` > `vmtypeid` > `tenantid`); falls back
    /// to the id column when absent.
    app: usize,
    start: usize,
    end: Option<usize>,
    cpu: usize,
    mem: usize,
}

impl AzureCols {
    fn from_header(header: &str, lineno: usize) -> Result<AzureCols, TraceError> {
        let names: Vec<String> =
            header.split(',').map(|c| c.trim().to_ascii_lowercase()).collect();
        let find = |cands: &[&str]| cands.iter().find_map(|c| names.iter().position(|n| n == c));
        let missing = |what: &str| TraceError::Malformed {
            line: lineno,
            reason: format!("azure header missing a {what} column (got {header:?})"),
        };
        let id = find(&["vmid", "id"]).ok_or_else(|| missing("vmid"))?;
        let start = find(&["starttime", "start"]).ok_or_else(|| missing("starttime"))?;
        let cpu = find(&["core", "cores", "vcpus"]).ok_or_else(|| missing("core"))?;
        let mem = find(&["memory", "mem"]).ok_or_else(|| missing("memory"))?;
        let app = find(&["appname", "app", "vmtypeid", "tenantid"]).unwrap_or(id);
        let end = find(&["endtime", "end"]);
        Ok(AzureCols { width: names.len(), id, app, start, end, cpu, mem })
    }
}

/// Field accessor for a split Azure row (missing column ⇒ malformed).
fn azure_field<'a>(fields: &[&'a str], i: usize, what: &str) -> Result<&'a str, String> {
    fields.get(i).copied().ok_or_else(|| format!("row too short for {what} column"))
}

/// Split and validate one Azure-style data row against the header map.
fn parse_azure_row(line: &str, cols: &AzureCols) -> Result<RawRow, String> {
    let fields: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
    if fields.len() < cols.width {
        return Err(format!(
            "row has {} columns, header has {}",
            fields.len(),
            cols.width
        ));
    }
    let id = azure_field(&fields, cols.id, "vmid")?;
    if id.is_empty() {
        return Err("empty vmid".to_string());
    }
    let app = azure_field(&fields, cols.app, "app")?;
    // Times are fractional days. VMs alive before the trace window carry
    // negative start times in the public packing trace; clamp to the
    // window start (they are submitted at replay start).
    let start =
        parse_f64(azure_field(&fields, cols.start, "starttime")?, "starttime")?.max(0.0)
            * SECS_PER_DAY;
    let end = match cols.end {
        None => None,
        Some(i) => match fields.get(i).copied().unwrap_or("") {
            "" => None,
            s => Some(parse_f64(s, "endtime")?.max(0.0) * SECS_PER_DAY),
        },
    };
    if let Some(e) = end {
        if e < start {
            return Err(format!("endtime {e} before starttime {start}"));
        }
    }
    // core / memory: fractions of the reference server.
    let core = parse_f64(azure_field(&fields, cols.cpu, "core")?, "core")?;
    let mem = parse_f64(azure_field(&fields, cols.mem, "memory")?, "memory")?;
    if core < 0.0 || mem < 0.0 {
        return Err("negative resource fraction".to_string());
    }
    Ok(RawRow {
        task_id: id.to_string(),
        app: if app.is_empty() { id.to_string() } else { app.to_string() },
        start,
        end,
        cpu_milli: ((core * REF_NODE_CORES * 1000.0).round() as u64).max(MIN_CPU_MILLI),
        mem_bytes: ((mem * REF_NODE_MEM_GB * 1e9).round() as u64).max(MIN_MEM_BYTES),
        instances: 1,
    })
}

fn parse_f64(s: &str, what: &str) -> Result<f64, String> {
    let v: f64 = s.parse().map_err(|_| format!("bad {what} {s:?}"))?;
    if !v.is_finite() {
        return Err(format!("non-finite {what} {s:?}"));
    }
    Ok(v)
}

// --- layer-composition synthesis ------------------------------------------

/// Shared OS base layers the synthesizer draws from, with popularity
/// weights (debian-family bases dominate real registries). Names reuse
/// the `registry::hub` layer namespace so digests line up if a synthetic
/// corpus and a trace corpus ever share a registry.
const BASE_POOL: &[(&str, f64, f64)] = &[
    ("os.debian12", 49.0, 4.0),
    ("os.ubuntu2204", 29.0, 3.0),
    ("os.alpine319", 3.4, 2.0),
    ("os.debian11", 52.0, 1.0),
];

/// Shared runtime/dependency layers (language stacks, cert bundles).
const RUNTIME_POOL: &[(&str, f64)] = &[
    ("rt.jre17", 92.0),
    ("rt.python311", 19.0),
    ("rt.node18", 48.0),
    ("rt.go121", 68.0),
    ("rt.php82", 31.0),
    ("dep.ca-certs", 3.0),
    ("dep.curl", 48.0),
    ("rt.dotnet8", 110.0),
];

/// FNV-1a over the app key — the deterministic hash that anchors all
/// per-app synthesis decisions.
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The `(name, tag)` a given app key synthesizes to. A short hash suffix
/// keeps sanitized names collision-free.
pub fn image_name_for_app(app: &str) -> (String, String) {
    let mut s: String = app
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' {
                c.to_ascii_lowercase()
            } else {
                '-'
            }
        })
        .collect();
    s.truncate(40);
    (format!("trace/{s}-{:08x}", (fnv64(app) >> 32) as u32), "r1".to_string())
}

/// Deterministically synthesize the image for one app key: a weighted
/// shared base, 0–2 shared runtime layers, and 1–2 unique app layers with
/// heavy-tailed sizes. Same `(app, seed)` ⇒ byte-identical manifest.
pub fn synthesize_image(app: &str, seed: u64) -> ImageMetadata {
    let mut rng = Pcg::new(seed ^ fnv64(app), 29);
    let weights: Vec<f64> = BASE_POOL.iter().map(|(_, _, w)| *w).collect();
    let (base_name, base_mb, _) = BASE_POOL[rng.weighted(&weights)];
    let mut layers =
        vec![LayerMetadata { digest: digest_for(base_name), size: Bytes::from_mb(base_mb) }];
    let mut rt_idx: Vec<usize> = (0..RUNTIME_POOL.len()).collect();
    rng.shuffle(&mut rt_idx);
    for &i in rt_idx.iter().take(rng.range(0, 3)) {
        let (name, mb) = RUNTIME_POOL[i];
        layers.push(LayerMetadata { digest: digest_for(name), size: Bytes::from_mb(mb) });
    }
    for k in 0..1 + rng.range(0, 2) {
        let mb = (4.0 + rng.exponential(60.0)).min(400.0);
        layers.push(LayerMetadata {
            digest: digest_for(&format!("trace.app.{app}.{k}")),
            size: Bytes::from_mb(mb),
        });
    }
    let (name, tag) = image_name_for_app(app);
    ImageMetadata::new(&digest_for(&format!("manifest.{name}:{tag}")), &name, &tag, layers)
}

impl Trace {
    /// Build a registry holding one synthesized image per distinct app
    /// key (sorted, so registry construction is deterministic).
    pub fn synthesize_registry(&self) -> Registry {
        let apps: BTreeSet<&str> = self.events.iter().map(|e| e.app.as_str()).collect();
        let mut registry = Registry::new();
        for app in apps {
            registry.push(synthesize_image(app, self.seed));
        }
        registry
    }

    /// Build the `(arrival-offset, Pod)` pairs to feed
    /// [`crate::sim::Simulation::run_arrivals`]. Pod ids are assigned in
    /// trace order by a fresh [`PodBuilder`].
    pub fn arrivals(&self) -> Vec<(f64, Pod)> {
        let mut builder = PodBuilder::new();
        self.events
            .iter()
            .map(|ev| {
                let (name, tag) = image_name_for_app(&ev.app);
                let mut pod = builder.build(
                    &format!("{name}:{tag}"),
                    Resources::new(MilliCpu(ev.cpu_milli), Bytes(ev.mem_bytes)),
                );
                if let Some(d) = ev.duration_secs {
                    pod = pod.with_duration(d);
                }
                (ev.submit_at, pod)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const ALIBABA_OK: &str = "\
task_m1,2,j_1,A,Terminated,100,160,50,0.5
task_r2,1,j_1,A,Terminated,103,103,200,1.0
task_m1,1,j_2,A,Terminated,110,,100,0.2
";

    fn parse_str(s: &str, opts: &TraceOptions) -> Result<Trace, TraceError> {
        parse_reader(Cursor::new(s.as_bytes()), opts)
    }

    #[test]
    fn alibaba_happy_path() {
        let t = parse_str(ALIBABA_OK, &TraceOptions::default()).unwrap();
        // Row 1 expands into 2 instances.
        assert_eq!(t.events.len(), 4);
        assert_eq!(t.stats.rows, 3);
        assert_eq!(t.stats.events, 4);
        assert_eq!(t.stats.skipped, 0);
        assert_eq!(t.stats.apps, 2, "task_m1 recurs across jobs");
        // Normalized to t=0.
        assert_eq!(t.events[0].submit_at, 0.0);
        assert_eq!(t.events[2].submit_at, 3.0);
        assert_eq!(t.events[3].submit_at, 10.0);
        // Durations: 60s, 0s (zero-duration task), forever.
        assert_eq!(t.events[0].duration_secs, Some(60.0));
        assert_eq!(t.events[2].duration_secs, Some(0.0));
        assert_eq!(t.events[3].duration_secs, None);
        // plan_cpu 50 → 500m; plan_mem 0.5% of 8 GB = 40 MB.
        assert_eq!(t.events[0].cpu_milli, 500);
        assert_eq!(t.events[0].mem_bytes, 40_000_000);
        // Instance expansion keeps ids unique.
        assert_eq!(t.events[0].task_id, "task_m1@j_1#0");
        assert_eq!(t.events[1].task_id, "task_m1@j_1#1");
        assert_eq!(t.events[3].task_id, "task_m1@j_2");
    }

    #[test]
    fn speedup_scales_arrivals_and_durations() {
        let opts = TraceOptions { speedup: 10.0, ..Default::default() };
        let t = parse_str(ALIBABA_OK, &opts).unwrap();
        assert_eq!(t.events[0].duration_secs, Some(6.0));
        assert_eq!(t.events[3].submit_at, 1.0);
        assert_eq!(t.stats.span_secs, 1.0);
    }

    #[test]
    fn limit_truncates_mid_expansion() {
        let opts = TraceOptions { limit: Some(1), ..Default::default() };
        let t = parse_str(ALIBABA_OK, &opts).unwrap();
        assert_eq!(t.events.len(), 1);
    }

    #[test]
    fn malformed_rows_strict_vs_lenient() {
        let bad = "task_a,1,j_1,A,Terminated,100,160,50,0.5\nnot-a-row\n";
        let strict =
            TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        match parse_str(bad, &strict) {
            Err(TraceError::Malformed { line: 2, .. }) => {}
            other => panic!("expected Malformed at line 2, got {other:?}"),
        }
        let t = parse_str(bad, &TraceOptions::default()).unwrap();
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.stats.skipped, 1);
    }

    #[test]
    fn truncated_row_and_bad_numbers_are_malformed() {
        for bad in [
            "task_a,1,j_1,A,Terminated,100,160,50", // 8 columns
            "task_a,1,j_1,A,Terminated,abc,160,50,0.5", // bad start
            "task_a,1,j_1,A,Terminated,100,90,50,0.5", // end before start
            "task_a,1,j_1,A,Terminated,100,160,-5,0.5", // negative cpu
            ",1,j_1,A,Terminated,100,160,50,0.5",   // empty task name
            "task_a,x,j_1,A,Terminated,100,160,50,0.5", // bad instance_num
            "task_a,0,j_1,A,Terminated,100,160,50,0.5", // zero instances
        ] {
            let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
            assert!(
                matches!(parse_str(bad, &strict), Err(TraceError::Malformed { .. })),
                "{bad:?} should be malformed"
            );
        }
    }

    #[test]
    fn out_of_order_resorted_or_rejected() {
        let ooo = "\
task_a,1,j_1,A,Terminated,200,260,50,0.5
task_b,1,j_1,A,Terminated,100,160,50,0.5
";
        let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        assert!(matches!(
            parse_str(ooo, &strict),
            Err(TraceError::OutOfOrder { line: 2 })
        ));
        let t = parse_str(ooo, &TraceOptions::default()).unwrap();
        assert!(t.stats.resorted);
        assert_eq!(t.events[0].app, "task_b");
        assert_eq!(t.events[0].submit_at, 0.0);
        assert_eq!(t.events[1].submit_at, 100.0);
    }

    #[test]
    fn duplicate_task_ids_dropped_or_rejected() {
        let dup = "\
task_a,1,j_1,A,Terminated,100,160,50,0.5
task_a,1,j_1,A,Terminated,120,180,50,0.5
";
        let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        match parse_str(dup, &strict) {
            Err(TraceError::DuplicateTask { line: 2, task }) => {
                assert_eq!(task, "task_a@j_1");
            }
            other => panic!("expected DuplicateTask, got {other:?}"),
        }
        let t = parse_str(dup, &TraceOptions::default()).unwrap();
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.stats.duplicates, 1);
    }

    #[test]
    fn empty_trace_is_an_error() {
        assert!(matches!(parse_str("", &TraceOptions::default()), Err(TraceError::Empty)));
        assert!(matches!(
            parse_str("# only a comment\n", &TraceOptions::default()),
            Err(TraceError::Empty)
        ));
    }

    const AZURE_OK: &str = "\
vmId,tenantId,vmTypeId,priority,startTime,endTime,core,memory
vm1,t1,type_web,1,0.0,0.5,0.25,0.125
vm2,t1,type_web,1,-0.25,0.25,0.5,0.25
vm3,t2,type_db,0,0.125,,0.25,0.5
";

    #[test]
    fn azure_happy_path() {
        let t = parse_str(AZURE_OK, &TraceOptions { format: TraceFormat::Azure, ..Default::default() })
            .unwrap();
        assert_eq!(t.events.len(), 3);
        assert_eq!(t.stats.apps, 2);
        // vm2's negative start clamps to the window start.
        assert_eq!(t.events[0].submit_at, 0.0);
        assert_eq!(t.events[1].submit_at, 0.0);
        assert_eq!(t.events[2].submit_at, 0.125 * SECS_PER_DAY);
        // 0.25 of a 4-core server = 1000m; 0.125 of 8 GB = 1 GB.
        assert_eq!(t.events[0].cpu_milli, 1000);
        assert_eq!(t.events[0].mem_bytes, 1_000_000_000);
        // Durations: 0.5 days, 0.25 days (start clamped to 0), forever.
        assert_eq!(t.events[0].duration_secs, Some(0.5 * SECS_PER_DAY));
        assert_eq!(t.events[1].duration_secs, Some(0.25 * SECS_PER_DAY));
        assert_eq!(t.events[2].duration_secs, None);
    }

    #[test]
    fn azure_header_required_and_validated() {
        let missing = "vmId,tenantId\nvm1,t1\n";
        let opts = TraceOptions { format: TraceFormat::Azure, ..Default::default() };
        assert!(matches!(
            parse_str(missing, &opts),
            Err(TraceError::Malformed { line: 1, .. })
        ));
    }

    #[test]
    fn alibaba_header_tolerated_after_comments() {
        let with_header = "\
# comment block before the header
task_name,instance_num,job_name,task_type,status,start_time,end_time,plan_cpu,plan_mem
task_a,1,j_1,A,Terminated,100,160,50,0.5
";
        let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        let t = parse_str(with_header, &strict).unwrap();
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.stats.skipped, 0);
    }

    #[test]
    fn azure_truncated_row_is_malformed_even_past_required_cols() {
        // endtime is the LAST column: a row truncated before it must be
        // malformed, not silently parsed as a forever-running VM.
        let truncated = "\
vmId,startTime,core,memory,endTime
vm1,0.0,0.25,0.125
";
        let strict = TraceOptions {
            format: TraceFormat::Azure,
            mode: ErrorMode::Strict,
            ..Default::default()
        };
        assert!(matches!(
            parse_str(truncated, &strict),
            Err(TraceError::Malformed { line: 2, .. })
        ));
        // An explicitly empty endtime field is still a valid service row.
        let empty_end = "\
vmId,startTime,core,memory,endTime
vm1,0.0,0.25,0.125,
";
        let t = parse_str(
            empty_end,
            &TraceOptions { format: TraceFormat::Azure, ..Default::default() },
        )
        .unwrap();
        assert_eq!(t.events[0].duration_secs, None);
    }

    #[test]
    fn azure_duplicate_vmid_detected() {
        let dup = "\
vmId,startTime,endTime,core,memory
vm1,0.0,0.5,0.25,0.125
vm1,0.1,0.6,0.25,0.125
";
        let opts = TraceOptions {
            format: TraceFormat::Azure,
            mode: ErrorMode::Strict,
            ..Default::default()
        };
        assert!(matches!(parse_str(dup, &opts), Err(TraceError::DuplicateTask { .. })));
    }

    #[test]
    fn synthesis_is_deterministic_and_skew_preserving() {
        let a1 = synthesize_image("task_m1", 42);
        let a2 = synthesize_image("task_m1", 42);
        assert_eq!(a1, a2, "same (app, seed) ⇒ same manifest");
        let b = synthesize_image("task_r2", 42);
        assert_ne!(a1.image_ref(), b.image_ref());
        let other_seed = synthesize_image("task_m1", 7);
        assert_eq!(
            a1.image_ref(),
            other_seed.image_ref(),
            "image identity depends only on the app key"
        );
        // Layer stacks: at least a base + one app layer, nothing empty.
        for img in [&a1, &b] {
            assert!(img.layers.len() >= 2);
            assert!(img.total_size > Bytes::ZERO);
        }
    }

    #[test]
    fn synthesized_registry_shares_base_layers() {
        let t = parse_str(ALIBABA_OK, &TraceOptions::default()).unwrap();
        let reg = t.synthesize_registry();
        assert_eq!(reg.image_count(), 2);
        // Pods resolve against the synthesized registry.
        for (_, pod) in t.arrivals() {
            assert!(reg.manifest(&pod.image).is_ok(), "missing {}", pod.image);
        }
    }

    #[test]
    fn arrivals_preserve_trace_shape() {
        let t = parse_str(ALIBABA_OK, &TraceOptions::default()).unwrap();
        let arrivals = t.arrivals();
        assert_eq!(arrivals.len(), 4);
        assert_eq!(arrivals[0].0, 0.0);
        assert_eq!(arrivals[3].0, 10.0);
        // Same app ⇒ same image; instance expansion shares it too.
        assert_eq!(arrivals[0].1.image, arrivals[1].1.image);
        assert_eq!(arrivals[0].1.image, arrivals[3].1.image);
        assert_ne!(arrivals[0].1.image, arrivals[2].1.image);
        assert_eq!(arrivals[2].1.duration_secs, Some(0.0), "zero-duration task");
    }

    #[test]
    fn image_names_sanitize_without_collisions() {
        let (n1, _) = image_name_for_app("task/We ird:key");
        assert!(n1.starts_with("trace/task-we-ird-key-"));
        let (n2, _) = image_name_for_app("task/We ird!key");
        assert_ne!(n1, n2, "hash suffix disambiguates sanitized collisions");
    }
}
