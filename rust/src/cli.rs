//! Hand-rolled command-line parser (`clap` is not in the vendored
//! dependency set). Supports subcommands, `--flag`, `--key value`,
//! `--key=value`, and positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec for one subcommand.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name without the leading `--`.
    pub name: &'static str,
    /// One-line help text shown by `usage`.
    pub help: &'static str,
    /// None ⇒ boolean flag, Some(default) ⇒ takes a value.
    pub default: Option<&'static str>,
}

/// Parsed arguments for a subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Arguments that were not options (or followed `--`).
    pub positional: Vec<String>,
}

impl Args {
    /// Raw value of `--name` (None when the option was absent and had no
    /// non-empty default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Was the boolean flag `--name` passed?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parse `--name` as `T`, distinguishing absent (Ok(None)) from
    /// unparsable (Err).
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value for --{name}: {v:?}")),
        }
    }

    /// `--name` as usize, or `default` when absent.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        Ok(self.get_parsed::<usize>(name)?.unwrap_or(default))
    }

    /// `--name` as u64, or `default` when absent.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        Ok(self.get_parsed::<u64>(name)?.unwrap_or(default))
    }

    /// `--name` as f64, or `default` when absent.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        Ok(self.get_parsed::<f64>(name)?.unwrap_or(default))
    }

    /// `--name` as a string slice, or `default` when absent.
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

/// Parse `argv` (without the program name) against a spec. Unknown options
/// are an error; `--` ends option parsing.
pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args, String> {
    let mut args = Args::default();
    // Seed defaults.
    for opt in spec {
        if let Some(d) = opt.default {
            if !d.is_empty() {
                args.values.insert(opt.name.to_string(), d.to_string());
            }
        }
    }
    let mut i = 0;
    let mut opts_done = false;
    while i < argv.len() {
        let a = &argv[i];
        if opts_done || !a.starts_with("--") {
            args.positional.push(a.clone());
            i += 1;
            continue;
        }
        if a == "--" {
            opts_done = true;
            i += 1;
            continue;
        }
        let body = &a[2..];
        let (name, inline_val) = match body.split_once('=') {
            Some((n, v)) => (n, Some(v.to_string())),
            None => (body, None),
        };
        let opt = spec
            .iter()
            .find(|o| o.name == name)
            .ok_or_else(|| format!("unknown option --{name}"))?;
        match (opt.default, inline_val) {
            (None, None) => args.flags.push(name.to_string()),
            (None, Some(_)) => return Err(format!("--{name} is a flag and takes no value")),
            (Some(_), Some(v)) => {
                args.values.insert(name.to_string(), v);
            }
            (Some(_), None) => {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| format!("--{name} requires a value"))?;
                args.values.insert(name.to_string(), v.clone());
            }
        }
        i += 1;
    }
    Ok(args)
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: lrsched {cmd} [options]\n\nOptions:\n");
    for opt in spec {
        let head = match opt.default {
            None => format!("  --{}", opt.name),
            Some("") => format!("  --{} <value>", opt.name),
            Some(d) => format!("  --{} <value> (default: {d})", opt.name),
        };
        s.push_str(&format!("{head:<46} {}\n", opt.help));
    }
    s
}

/// The option specs for every `lrsched` subcommand, library-resident so
/// the docs-drift gate (`rust/tests/docs_complete.rs`) can enumerate the
/// real flag surface instead of a hand-maintained list. `main.rs` builds
/// its parsers and usage text from these; adding a flag here without
/// documenting it in `docs/SCALE.md` or `docs/SERVE.md` fails CI.
pub mod specs {
    use super::OptSpec;

    /// Options shared by the paper-experiment subcommands
    /// (`fig3`/`fig4`/`fig5`/`table1`, and the base of `simulate`).
    pub fn common() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "seed", help: "workload RNG seed", default: Some("42") },
            OptSpec { name: "pods", help: "number of pods in the trace", default: Some("20") },
            OptSpec { name: "nodes", help: "worker node count (1-5)", default: Some("4") },
            OptSpec { name: "log-level", help: "error|warn|info|debug|trace", default: Some("info") },
        ]
    }

    /// `lrsched simulate` options.
    pub fn simulate() -> Vec<OptSpec> {
        let mut s = common();
        s.push(OptSpec {
            name: "scheduler",
            help: "default|layer|lr|rl",
            default: Some("lr"),
        });
        s.push(OptSpec {
            name: "backend",
            help: "native|xla (xla loads artifacts/ via PJRT)",
            default: Some("native"),
        });
        s.push(OptSpec {
            name: "bandwidth",
            help: "per-node bandwidth MB/s",
            default: Some("10"),
        });
        s.push(OptSpec {
            name: "arrival",
            help: "seconds between arrivals (0 = sequential)",
            default: Some("0"),
        });
        s.push(OptSpec { name: "gc", help: "enable kubelet image GC", default: None });
        s.push(OptSpec {
            name: "p2p-lan",
            help: "peer layer-transfer LAN bandwidth MB/s (0 = off)",
            default: Some("0"),
        });
        s
    }

    /// `lrsched scale` options.
    pub fn scale() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "seed", help: "workload RNG seed", default: Some("42") },
            OptSpec { name: "pods", help: "number of pods in the trace", default: Some("100000") },
            OptSpec { name: "nodes", help: "edge node count", default: Some("64") },
            OptSpec {
                name: "disk-gb",
                help: "per-node disk capacity in GB (small disks put image GC \
                       and the cache policies on the hot path)",
                default: Some("64"),
            },
            OptSpec { name: "scheduler", help: "default|layer|lr|rl", default: Some("lr") },
            OptSpec {
                name: "backend",
                help: "native|dense (dense drives the reused-arena scoring path)",
                default: Some("native"),
            },
            OptSpec { name: "arrival", help: "seconds between arrivals", default: Some("0.3") },
            OptSpec { name: "duration-min", help: "min pod lifetime (s)", default: Some("30") },
            OptSpec { name: "duration-max", help: "max pod lifetime (s)", default: Some("300") },
            OptSpec {
                name: "zipf",
                help: "image-popularity Zipf exponent (0 = uniform)",
                default: Some("1.1"),
            },
            OptSpec {
                name: "trace",
                help: "replay a real cluster-trace CSV instead of the synthetic Zipf \
                       workload (disables --pods/--zipf/--duration-*/--arrival)",
                default: Some(""),
            },
            OptSpec {
                name: "trace-format",
                help: "alibaba|azure|borg (see docs/SCALE.md)",
                default: Some("alibaba"),
            },
            OptSpec {
                name: "trace-speedup",
                help: "divide trace arrival offsets and durations by this factor",
                default: Some("1"),
            },
            OptSpec {
                name: "trace-limit",
                help: "ingest at most N trace events, in file order (0 = all); the \
                       rest of the file is not read or inflated",
                default: Some("0"),
            },
            OptSpec {
                name: "trace-strict",
                help: "reject malformed/out-of-order/duplicate rows instead of repairing",
                default: None,
            },
            OptSpec {
                name: "trace-reorder",
                help: "lenient-mode reorder-buffer capacity in events (bounds \
                       streaming-replay memory; disorder beyond it falls back to a \
                       whole-trace sort)",
                default: Some("65536"),
            },
            OptSpec {
                name: "retry-limit",
                help: "retries before a pod is unschedulable",
                default: Some("10"),
            },
            OptSpec { name: "backoff", help: "scheduling-queue back-off (s)", default: Some("5") },
            OptSpec {
                name: "snapshot-every",
                help: "snapshot cadence (placements)",
                default: Some("1000"),
            },
            OptSpec {
                name: "shards",
                help: "per-node event lanes (N worker threads; report is \
                       byte-identical for every N)",
                default: Some("1"),
            },
            OptSpec {
                name: "report-out",
                help: "write the full report fingerprint to this file",
                default: Some(""),
            },
            OptSpec {
                name: "events-out",
                help: "write the event log (one line per record) to this file",
                default: Some(""),
            },
            OptSpec { name: "no-gc", help: "disable kubelet image GC", default: None },
            OptSpec {
                name: "p2p",
                help: "enable peer-swarm layer sharing: missing layers cached on \
                       Ready peers transfer over the LAN instead of the registry WAN",
                default: None,
            },
            OptSpec {
                name: "p2p-lan",
                help: "peer layer-transfer LAN bandwidth MB/s (with --p2p)",
                default: Some("125"),
            },
            OptSpec {
                name: "p2p-seeder-cap",
                help: "max concurrent uploads one seeder serves; saturated layers \
                       fall back to the registry (with --p2p)",
                default: Some("4"),
            },
            OptSpec {
                name: "churn",
                help: "enable cluster volatility: node joins/drains/crashes + a registry \
                       outage window (e.g. `lrsched scale --churn`)",
                default: None,
            },
            OptSpec {
                name: "churn-seed",
                help: "churn RNG seed (defaults to --seed)",
                default: Some(""),
            },
            OptSpec { name: "churn-joins", help: "nodes joining mid-trace", default: Some("3") },
            OptSpec { name: "churn-drains", help: "nodes drained mid-trace", default: Some("2") },
            OptSpec {
                name: "churn-crash-frac",
                help: "fraction of the initial fleet that crashes",
                default: Some("0.05"),
            },
            OptSpec { name: "churn-outages", help: "registry outage windows", default: Some("1") },
            OptSpec {
                name: "churn-outage-secs",
                help: "outage window length (s)",
                default: Some("60"),
            },
            OptSpec {
                name: "no-wake",
                help: "disable capacity-driven wake-ups (fixed back-off timers only)",
                default: None,
            },
            OptSpec {
                name: "cache-policy",
                help: "pressure|lru|popularity|scorer|prefetch (kubelet image-GC \
                       eviction/prefetch policy; see docs/SCALE.md)",
                default: Some("pressure"),
            },
            OptSpec {
                name: "cache-decay",
                help: "popularity half-life time constant in seconds (lru/popularity/\
                       prefetch recency decay)",
                default: Some("300"),
            },
            OptSpec {
                name: "cache-prefetch-mb",
                help: "per-intent prefetch budget in MB (with --cache-policy prefetch)",
                default: Some("256"),
            },
            OptSpec { name: "log-level", help: "error|warn|info|debug|trace", default: Some("info") },
        ]
    }

    /// `lrsched serve` options (`docs/SERVE.md` is the operator's guide).
    pub fn serve() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "nodes", help: "edge node count for the live fleet", default: Some("8") },
            OptSpec { name: "disk-gb", help: "per-node disk capacity in GB", default: Some("64") },
            OptSpec { name: "scheduler", help: "default|layer|lr|rl", default: Some("lr") },
            OptSpec {
                name: "seed",
                help: "registry-synthesis seed for --shadow replays",
                default: Some("42"),
            },
            OptSpec {
                name: "retry-limit",
                help: "retries before a pod is unschedulable",
                default: Some("10"),
            },
            OptSpec { name: "backoff", help: "scheduling-queue back-off (s)", default: Some("5") },
            OptSpec { name: "no-gc", help: "disable kubelet image GC", default: None },
            OptSpec {
                name: "strict",
                help: "abort on the first malformed or out-of-order line with its line \
                       number (default: skip it, count it, emit an error object)",
                default: None,
            },
            OptSpec {
                name: "listen",
                help: "serve the protocol over HTTP on this localhost address \
                       (e.g. 127.0.0.1:7473) instead of stdin",
                default: Some(""),
            },
            OptSpec {
                name: "shadow",
                help: "replay this trace CSV through the serve path and verify the \
                       decision stream is byte-identical to the batch `scale --trace` \
                       replay",
                default: Some(""),
            },
            OptSpec {
                name: "trace-format",
                help: "alibaba|azure|borg (with --shadow)",
                default: Some("alibaba"),
            },
            OptSpec {
                name: "trace-speedup",
                help: "divide trace arrival offsets and durations by this factor \
                       (with --shadow)",
                default: Some("1"),
            },
            OptSpec {
                name: "trace-limit",
                help: "ingest at most N trace events (0 = all; with --shadow)",
                default: Some("0"),
            },
            OptSpec { name: "log-level", help: "error|warn|info|debug|trace", default: Some("info") },
        ]
    }

    /// `lrsched gen-trace` options.
    pub fn gen_trace() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "rows", help: "data rows to generate", default: Some("1000000") },
            OptSpec { name: "seed", help: "generator RNG seed", default: Some("42") },
            OptSpec {
                name: "out",
                help: "output path; a .gz suffix writes a stored-block gzip member \
                       (no external gzip needed)",
                default: Some(""),
            },
            OptSpec { name: "log-level", help: "error|warn|info|debug|trace", default: Some("info") },
        ]
    }

    /// `lrsched lint` options.
    pub fn lint() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "root",
                help: "source tree to walk (defaults to rust/src, or src/ when \
                       invoked from inside rust/)",
                default: Some(""),
            },
            OptSpec { name: "json", help: "print diagnostics as a JSON array", default: None },
            OptSpec {
                name: "self-test",
                help: "run the embedded rule fixtures instead of walking a tree",
                default: None,
            },
            OptSpec { name: "log-level", help: "error|warn|info|debug|trace", default: Some("info") },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "nodes", help: "node count", default: Some("4") },
            OptSpec { name: "seed", help: "rng seed", default: Some("42") },
            OptSpec { name: "verbose", help: "chatty", default: None },
            OptSpec { name: "out", help: "output path", default: Some("") },
        ]
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &spec()).unwrap();
        assert_eq!(a.get("nodes"), Some("4"));
        assert_eq!(a.get("out"), None); // empty default means optional
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&sv(&["--nodes", "5", "--seed=7"]), &spec()).unwrap();
        assert_eq!(a.usize_or("nodes", 0).unwrap(), 5);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&sv(&["--verbose", "pos1", "--", "--not-an-opt"]), &spec()).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "--not-an-opt"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--bogus"]), &spec()).is_err());
        assert!(parse(&sv(&["--nodes"]), &spec()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &spec()).is_err());
        let a = parse(&sv(&["--nodes", "abc"]), &spec()).unwrap();
        assert!(a.usize_or("nodes", 0).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("simulate", "Run the simulator", &spec());
        assert!(u.contains("--nodes"));
        assert!(u.contains("default: 4"));
    }
}
