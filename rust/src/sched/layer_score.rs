//! The layer-sharing score — paper Eqs. (1)–(3) and contribution 1.
//!
//! For task k requesting container c on node n at time t:
//!   C_c^n(t)  = Σ_{l ∈ L_c \ L_n(t)} d_l          (download cost, Eq. 1)
//!   D_c^n(t)  = Σ_{l ∈ L_c ∩ L_n(t)} d_l          (local bytes,   Eq. 2)
//!   S_layer   = D_c^n(t) / Σ_{l ∈ L_c} d_l × 100  (score,         Eq. 3)

use crate::cluster::Node;
use crate::registry::LayerInterner;
use crate::sched::context::CycleContext;
use crate::sched::framework::{ScorePlugin, MAX_NODE_SCORE};
use crate::util::units::Bytes;

/// Eq. (1): bytes node `n` must download for the required layer set.
pub fn download_cost(ctx: &CycleContext, node: &Node) -> Bytes {
    ctx.required_layers
        .difference_bytes(&node.layers, &ctx.state.interner)
}

/// Eq. (2): bytes of the required layer set already local on `n`.
pub fn local_bytes(ctx: &CycleContext, node: &Node) -> Bytes {
    ctx.required_layers
        .intersection_bytes(&node.layers, &ctx.state.interner)
}

/// Eq. (3) as a pure function of the byte quantities.
pub fn layer_sharing_score(local: Bytes, total: Bytes) -> f64 {
    if total == Bytes::ZERO {
        // Unknown image (not yet in cache.json) or empty layer set: no
        // sharing signal. 0 matches the paper's behaviour on first sight.
        return 0.0;
    }
    local.0 as f64 / total.0 as f64 * MAX_NODE_SCORE
}

/// The layer-sharing score plugin (the paper's score extension point).
pub struct LayerScore;

impl ScorePlugin for LayerScore {
    fn name(&self) -> &'static str {
        "LayerScore"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        layer_sharing_score(local_bytes(ctx, node), ctx.required_bytes)
    }
}

/// Download time T^{k,n} = C_c^n(t) / b_n (§III-B).
pub fn download_time_secs(ctx: &CycleContext, node: &Node) -> f64 {
    node.bandwidth.transfer_secs(download_cost(ctx, node))
}

/// Standalone form used by the simulator (no cycle context).
pub fn score_for_sets(
    required: &crate::registry::LayerSet,
    node_layers: &crate::registry::LayerSet,
    interner: &LayerInterner,
) -> f64 {
    let local = required.intersection_bytes(node_layers, interner);
    let total = required.total_bytes(interner);
    layer_sharing_score(local, total)
}

/// Keep-set hook for the scorer-informed cache policy: how much of an
/// image's layer set is shared with the layers the node would retain if
/// this image were evicted. Low score = shares little with the keep set =
/// cheap to evict (re-uses Eq. 3's byte-overlap ratio, so the eviction
/// order agrees with the scheduler's own notion of layer value).
pub fn keep_set_score(
    layers: &crate::registry::LayerSet,
    kept: &crate::registry::LayerSet,
    interner: &LayerInterner,
) -> f64 {
    score_for_sets(layers, kept, interner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::hub;
    use crate::util::units::Bandwidth;

    fn setup() -> (ClusterState, crate::registry::ImageMetadata, crate::registry::LayerSet) {
        let mut state = ClusterState::new();
        for i in 0..2 {
            state.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(30.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        let corpus = hub::corpus();
        let wp = corpus
            .iter()
            .find(|m| m.name == "wordpress" && m.tag == "6.4")
            .unwrap()
            .clone();
        let (_, layers) = state.intern_image(&wp);
        (state, wp, layers)
    }

    #[test]
    fn cold_node_scores_zero() {
        let (state, wp, layers) = setup();
        let pod = PodBuilder::new().build("wordpress:6.4", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(&wp), layers, wp.total_size);
        assert_eq!(LayerScore.score(&ctx, state.node(NodeId(0))), 0.0);
        assert_eq!(download_cost(&ctx, state.node(NodeId(0))), wp.total_size);
    }

    #[test]
    fn warm_node_scores_100() {
        let (mut state, wp, layers) = setup();
        state.install_image(NodeId(0), &wp.image_ref(), &layers).unwrap();
        let pod = PodBuilder::new().build("wordpress:6.4", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(&wp), layers, wp.total_size);
        assert!((LayerScore.score(&ctx, state.node(NodeId(0))) - 100.0).abs() < 1e-9);
        assert_eq!(download_cost(&ctx, state.node(NodeId(0))), Bytes::ZERO);
    }

    #[test]
    fn partial_sharing_is_proportional() {
        let (mut state, wp, wp_layers) = setup();
        // Install php:8.2-apache — shares debian + ca-certs + apache + php
        // runtime with wordpress (104 MB of wordpress's 243 MB).
        let corpus = hub::corpus();
        let php = corpus.iter().find(|m| m.name == "php" && m.tag == "8.2-apache").unwrap();
        let (_, php_layers) = state.intern_image(php);
        state.install_image(NodeId(0), &php.image_ref(), &php_layers).unwrap();

        let pod = PodBuilder::new().build("wordpress:6.4", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(&wp), wp_layers, wp.total_size);
        let s = LayerScore.score(&ctx, state.node(NodeId(0)));
        let local = local_bytes(&ctx, state.node(NodeId(0)));
        assert!(local > Bytes::ZERO);
        let expected = local.0 as f64 / wp.total_size.0 as f64 * 100.0;
        assert!((s - expected).abs() < 1e-9);
        assert!(s > 30.0 && s < 70.0, "php stack ≈ 43% of wordpress, got {s}");
        // Eq. 1 + Eq. 2 partition the total.
        assert_eq!(
            local + download_cost(&ctx, state.node(NodeId(0))),
            wp.total_size
        );
    }

    #[test]
    fn unknown_image_scores_zero() {
        let (state, _, _) = setup();
        let pod = PodBuilder::new().build("mystery:1", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, None, Default::default(), Bytes::ZERO);
        assert_eq!(LayerScore.score(&ctx, state.node(NodeId(0))), 0.0);
    }

    #[test]
    fn download_time_uses_bandwidth() {
        let (state, wp, layers) = setup();
        let pod = PodBuilder::new().build("wordpress:6.4", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(&wp), layers, wp.total_size);
        let t = download_time_secs(&ctx, state.node(NodeId(0)));
        let expected = wp.total_size.as_mb() / 10.0;
        assert!((t - expected).abs() < 1e-6);
    }
}
