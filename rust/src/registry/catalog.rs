//! In-process Docker registry — the substrate behind the paper's private
//! registry (§V-1). Exposes the same logical endpoints the Go scheduler
//! polls (`/v2/_catalog`, `/v2/<name>/tags/list`, manifests) as methods.

use super::image::{ImageMetadata, ImageRef};
use std::collections::BTreeMap;

/// Registry error type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// No repository with this name.
    UnknownImage(String),
    /// Repository exists but the tag does not.
    UnknownTag(String),
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::UnknownImage(n) => write!(f, "unknown image {n}"),
            RegistryError::UnknownTag(t) => write!(f, "unknown tag {t}"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// The registry: image metadata keyed `name` → `tag` → manifest.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    images: BTreeMap<String, BTreeMap<String, ImageMetadata>>,
    /// Simulated per-request latency in milliseconds (edge registries are
    /// not colocated with the scheduler; used by the watcher timing model).
    pub request_latency_ms: f64,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A registry pre-populated with the synthetic Docker Hub corpus.
    pub fn with_corpus() -> Registry {
        let mut r = Registry::new();
        for m in super::hub::corpus() {
            r.push(m);
        }
        r
    }

    /// Upload (push) an image manifest.
    pub fn push(&mut self, meta: ImageMetadata) {
        self.images
            .entry(meta.name.clone())
            .or_default()
            .insert(meta.tag.clone(), meta);
    }

    /// `/v2/_catalog` — repository names, sorted.
    pub fn catalog(&self) -> Vec<String> {
        self.images.keys().cloned().collect()
    }

    /// `/v2/<name>/tags/list`.
    pub fn tags(&self, name: &str) -> Result<Vec<String>, RegistryError> {
        self.images
            .get(name)
            .map(|tags| tags.keys().cloned().collect())
            .ok_or_else(|| RegistryError::UnknownImage(name.to_string()))
    }

    /// `/v2/<name>/manifests/<tag>`.
    pub fn manifest(&self, image: &ImageRef) -> Result<&ImageMetadata, RegistryError> {
        let tags = self
            .images
            .get(&image.name)
            .ok_or_else(|| RegistryError::UnknownImage(image.name.clone()))?;
        tags.get(&image.tag)
            .ok_or_else(|| RegistryError::UnknownTag(image.key()))
    }

    /// Walk every (name, tag) manifest — what the watcher does per poll.
    pub fn all_manifests(&self) -> impl Iterator<Item = &ImageMetadata> {
        self.images.values().flat_map(|tags| tags.values())
    }

    /// Total (name, tag) manifests.
    pub fn image_count(&self) -> usize {
        self.images.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::layer::LayerMetadata;
    use crate::util::units::Bytes;

    fn tiny() -> ImageMetadata {
        ImageMetadata::new(
            "sha256:m",
            "app",
            "v1",
            vec![LayerMetadata { digest: "sha256:l1".into(), size: Bytes::from_mb(1.0) }],
        )
    }

    #[test]
    fn push_and_lookup() {
        let mut r = Registry::new();
        r.push(tiny());
        assert_eq!(r.catalog(), vec!["app"]);
        assert_eq!(r.tags("app").unwrap(), vec!["v1"]);
        assert_eq!(r.manifest(&ImageRef::new("app", "v1")).unwrap().id, "sha256:m");
    }

    #[test]
    fn errors() {
        let r = Registry::with_corpus();
        assert!(matches!(r.tags("nope"), Err(RegistryError::UnknownImage(_))));
        assert!(matches!(
            r.manifest(&ImageRef::new("redis", "nope")),
            Err(RegistryError::UnknownTag(_))
        ));
        assert!(matches!(
            r.manifest(&ImageRef::new("nope", "1")),
            Err(RegistryError::UnknownImage(_))
        ));
    }

    #[test]
    fn corpus_registry() {
        let r = Registry::with_corpus();
        assert_eq!(r.image_count(), 30);
        assert!(r.catalog().contains(&"wordpress".to_string()));
        assert_eq!(r.tags("redis").unwrap().len(), 2);
        assert_eq!(r.all_manifests().count(), 30);
    }

    #[test]
    fn push_overwrites_same_tag() {
        let mut r = Registry::new();
        r.push(tiny());
        let mut v2 = tiny();
        v2.id = "sha256:m2".into();
        r.push(v2);
        assert_eq!(r.image_count(), 1);
        assert_eq!(r.manifest(&ImageRef::new("app", "v1")).unwrap().id, "sha256:m2");
    }
}
