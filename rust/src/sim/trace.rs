//! Real-trace replay: import public cluster traces and replay them through
//! the event engine instead of the synthetic Zipf workload.
//!
//! The paper validates LRScheduler on a real system; related work (e.g.
//! TD3-Sched, the joint task-scheduling/image-caching line) grounds its
//! evaluation on measured cluster traces. This module closes that gap for
//! the `scale` harness with a **two-pass streaming pipeline** whose
//! memory footprint is O(distinct apps + reorder buffer + one 64-bit
//! duplicate-detection fingerprint per task id) — never the materialized
//! event or pod list:
//!
//! 1. **Scan** — a first streaming pass over the file (through the
//!    streaming gzip decoder for `.csv.gz`) parses every row, validates
//!    it (strict mode fails here, with line numbers), and keeps only
//!    O(distinct-apps + distinct-tasks) state: the set of app keys for
//!    registry synthesis, 64-bit task-id fingerprints for duplicate
//!    detection, the earliest/latest timestamps (for `t=0` normalization
//!    and the replay span), and a simulation of the bounded reorder
//!    buffer that measures the trace's actual disorder
//!    ([`TraceStats::reorder_depth`]).
//! 2. **Replay** — a second streaming pass re-parses the file as a
//!    pull-based [`TraceSource`] (an
//!    [`crate::sim::arrivals::ArrivalSource`]): each accepted row becomes
//!    a normalized [`TraceEvent`] and then a [`Pod`], emitted one at a
//!    time as the engine's clock reaches it. The scan pass picks the
//!    replay strategy ([`TraceStats::ingest_path`]): a **single-pass
//!    direct stream** when no repair is needed (strict mode, or a
//!    measured [`TraceStats::reorder_depth`] of 0 — time-sorted traces
//!    skip the heap entirely), otherwise a **bounded reorder buffer**
//!    (a min-heap of at most [`TraceOptions::reorder_cap`] + 1 events,
//!    keyed by `(time, row order)`) that repairs out-of-order timestamps
//!    exactly like the old whole-trace stable re-sort did —
//!    byte-identically, because the scan pass proves the trace's
//!    disorder fits the buffer — falling back to a buffered full sort
//!    ([`TraceStats::full_resort`]) when it does not.
//!
//! **When can the scan pass itself be cut short?** The replay pass
//! always needs the scan's `t=0` normalization anchor and app set, so a
//! pass over the file cannot be skipped outright — but its costly part,
//! the keys-only reorder-buffer simulation, only runs in lenient mode.
//! Files produced by `lrsched gen-trace` are emitted with strictly
//! increasing timestamps and unique task ids, so they can (and should)
//! be ingested in [`ErrorMode::Strict`]: the scan degenerates to pure
//! parse + min/max bookkeeping, and the replay pass takes
//! [`IngestPath::Direct`] — the same single-pass route a lenient scan
//! would select after measuring `reorder_depth == 0`.
//!
//! Three concrete dialects are supported: Alibaba cluster-trace
//! `batch_task` CSV ([`TraceFormat::Alibaba`]), Azure packing-trace CSV
//! ([`TraceFormat::Azure`]), and Google cluster-data (Borg) task-events
//! CSV ([`TraceFormat::Borg`]).
//!
//! Public traces name tasks/VM types but carry no image manifests, so
//! [`synthesize_image`] deterministically hashes each app key into a
//! layer stack (shared OS base + shared runtime layers + unique app
//! layers). Equal app keys always map to the same image, so the trace's
//! app-popularity skew becomes image-popularity skew — exactly the
//! signal layer-aware scheduling exploits.
//!
//! Malformed input is handled per [`ErrorMode`]: `Strict` rejects the
//! first bad row (with its line number), `Lenient` skips bad rows, drops
//! duplicate task ids, and repairs out-of-order timestamps — every
//! repair is counted in [`TraceStats`], never silent.
//! [`TraceOptions::limit`] **short-circuits ingestion**: once the limit
//! is reached the file is not read (or inflated) any further.
//!
//! See `docs/ARCHITECTURE.md` ("Arrival pipeline") for the pipeline
//! diagram and `docs/SCALE.md` for copy-pasteable CLI runs against the
//! bundled fixtures under `rust/tests/fixtures/`.

use super::arrivals::ArrivalSource;
use crate::cluster::{Pod, PodBuilder, Resources};
use crate::registry::hub::digest_for;
use crate::registry::{ImageMetadata, LayerMetadata, Registry};
use crate::util::rng::Pcg;
use crate::util::units::{Bytes, MilliCpu};
use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeSet, BinaryHeap, HashSet};
use std::fmt;
use std::io::{BufRead, Seek, SeekFrom};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Reference edge-node shape used to de-normalize trace resource columns
/// (Alibaba `plan_cpu`/`plan_mem` are percentages of a machine; Azure
/// packing `core`/`memory` and Borg `cpu_request`/`mem_request` are
/// fractions of a server). Matches the `scale` fleet built by
/// `exp::common::scale_nodes`: 4 cores / 8 GB.
pub const REF_NODE_CORES: f64 = 4.0;
/// Reference node memory in GB (see [`REF_NODE_CORES`]).
pub const REF_NODE_MEM_GB: f64 = 8.0;

/// Floor for de-normalized CPU requests: traces contain near-zero plans,
/// and a zero-request pod would trivially fit everywhere, hiding the
/// packing problem the replay is meant to exercise.
const MIN_CPU_MILLI: u64 = 10;
/// Floor for de-normalized memory requests (see [`MIN_CPU_MILLI`]).
const MIN_MEM_BYTES: u64 = 16_000_000;

const SECS_PER_DAY: f64 = 86_400.0;

/// Which on-disk trace dialect to parse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// Alibaba cluster-trace `batch_task.csv` dialect: headerless rows of
    /// `task_name,instance_num,job_name,task_type,status,start_time,`
    /// `end_time,plan_cpu,plan_mem` with times in seconds, `plan_cpu` in
    /// percent-of-core units (100 = 1 core) and `plan_mem` in percent of
    /// a machine's memory ([`REF_NODE_MEM_GB`]). Each row expands into
    /// `instance_num` pods. The app key is `task_name` (recurring DAG
    /// node names carry the popularity skew).
    Alibaba,
    /// Azure packing-trace dialect: a header line naming at least
    /// `vmid,starttime,endtime,core,memory` (an `appname`/`vmtypeid`/
    /// `tenantid` column, in that priority order, provides the app key),
    /// times in fractional days, and `core`/`memory` as fractions of a
    /// server ([`REF_NODE_CORES`]/[`REF_NODE_MEM_GB`]).
    Azure,
    /// Google cluster-data (Borg) `task_events` dialect: headerless rows
    /// of `time,missing,job_id,task_index,machine_id,event_type,user,`
    /// `sched_class,priority,cpu_request,mem_request[,disk,constraint]`
    /// with times in **microseconds** and requests as fractions of a
    /// machine. Only SUBMIT rows (`event_type` 0) become arrivals; the
    /// other lifecycle rows (SCHEDULE/EVICT/FINISH/…) are valid input
    /// but produce no pod and are counted in [`TraceStats::filtered`].
    /// Durations are not reconstructed (they would require pairing
    /// SUBMIT with later FINISH rows across the whole stream), so Borg
    /// tasks replay as services; bound runs with `--trace-limit` or a
    /// pre-cut window. The app key is `job_id` (tasks of a job share an
    /// image, so job popularity carries the layer-sharing skew).
    Borg,
}

impl TraceFormat {
    /// Parse a CLI-style format name (`alibaba` | `azure` | `borg`).
    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s {
            "alibaba" => Some(TraceFormat::Alibaba),
            "azure" => Some(TraceFormat::Azure),
            "borg" => Some(TraceFormat::Borg),
            _ => None,
        }
    }

    /// CLI-facing name of the format.
    pub fn label(&self) -> &'static str {
        match self {
            TraceFormat::Alibaba => "alibaba",
            TraceFormat::Azure => "azure",
            TraceFormat::Borg => "borg",
        }
    }
}

/// How parse problems are handled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorMode {
    /// Fail on the first malformed row, duplicate task id, or
    /// out-of-order timestamp — with the offending line number.
    Strict,
    /// Skip malformed rows and duplicate task ids, and repair
    /// out-of-order timestamps through the bounded reorder buffer;
    /// every repair is counted in [`TraceStats`].
    Lenient,
}

/// Importer configuration.
#[derive(Debug, Clone)]
pub struct TraceOptions {
    /// Trace dialect to parse.
    pub format: TraceFormat,
    /// Strict vs lenient error handling.
    pub mode: ErrorMode,
    /// Virtual-time compression: arrival offsets *and* task durations are
    /// divided by this factor (> 1 makes week-long traces replayable in
    /// bounded virtual time while preserving the workload's shape).
    pub speedup: f64,
    /// Stop after this many parsed events (None = whole trace). The limit
    /// **short-circuits ingestion**: once `n` events have been accepted
    /// (in *file order*, before any lenient reorder) the underlying file
    /// is not read — or gzip-inflated — any further.
    /// [`TraceStats::limit_hit`] records the cut, and
    /// [`TraceStats::truncated_events`] counts the instances dropped from
    /// the row being expanded when it hit.
    pub limit: Option<usize>,
    /// Seed for the deterministic layer-composition synthesis.
    pub seed: u64,
    /// Lenient-mode reorder-buffer capacity in events: out-of-order
    /// timestamps are repaired by holding at most this many events in a
    /// look-ahead min-heap. Traces whose disorder fits the buffer (the
    /// scan pass checks, see [`TraceStats::reorder_depth`]) replay
    /// byte-identically to a whole-trace stable sort; traces that
    /// exceed it fall back to the buffered sort
    /// ([`TraceStats::full_resort`]).
    pub reorder_cap: usize,
}

impl Default for TraceOptions {
    fn default() -> TraceOptions {
        TraceOptions {
            format: TraceFormat::Alibaba,
            mode: ErrorMode::Lenient,
            speedup: 1.0,
            limit: None,
            seed: 42,
            reorder_cap: 65_536,
        }
    }
}

/// Format-agnostic intermediate representation of one task/VM in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// 1-based source line this event was parsed from.
    pub line: usize,
    /// Arrival offset in seconds from trace start (normalized so the
    /// earliest event is at 0, then divided by [`TraceOptions::speedup`]).
    pub submit_at: f64,
    /// Unique task instance id (duplicate detection key before instance
    /// expansion; unique per emitted event afterwards).
    pub task_id: String,
    /// Image identity / layer-synthesis key. Equal keys replay as the
    /// same image, preserving the trace's app-popularity skew.
    pub app: String,
    /// De-normalized CPU request in millicores.
    pub cpu_milli: u64,
    /// De-normalized memory request in bytes.
    pub mem_bytes: u64,
    /// Task lifetime in (speedup-scaled) seconds; None = runs forever
    /// (the trace row had no end time — a service, or a task still
    /// running when the trace window closed).
    pub duration_secs: Option<f64>,
}

/// What went wrong while importing a trace.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceError {
    /// I/O failure reading the trace (including gzip decode errors).
    Io(String),
    /// A row could not be parsed (strict mode only; lenient skips).
    Malformed {
        /// 1-based line number of the offending row.
        line: usize,
        /// Human-readable parse failure.
        reason: String,
    },
    /// Timestamps went backwards (strict mode only; lenient repairs
    /// through the reorder buffer).
    OutOfOrder {
        /// 1-based line number of the first row that went back in time.
        line: usize,
    },
    /// The same task id appeared twice (strict mode only; lenient drops
    /// the later occurrence).
    DuplicateTask {
        /// 1-based line number of the second occurrence.
        line: usize,
        /// The duplicated task id.
        task: String,
    },
    /// The trace contained no usable rows.
    Empty,
    /// The file extension names a compression format the importer cannot
    /// inflate. Supported inputs are plain `.csv` and gzip `.csv.gz`.
    UnsupportedCompression {
        /// The rejected extension (lowercased, without the dot).
        ext: String,
    },
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace I/O error: {e}"),
            TraceError::Malformed { line, reason } => {
                write!(f, "trace line {line}: {reason}")
            }
            TraceError::OutOfOrder { line } => {
                write!(f, "trace line {line}: timestamp out of order (strict mode)")
            }
            TraceError::DuplicateTask { line, task } => {
                write!(f, "trace line {line}: duplicate task id {task:?} (strict mode)")
            }
            TraceError::Empty => write!(f, "trace contained no usable rows"),
            TraceError::UnsupportedCompression { ext } => write!(
                f,
                "unsupported compressed trace format .{ext}: supported inputs are plain \
                 .csv or gzip-compressed .csv.gz — decompress the archive (or re-compress \
                 it with gzip) before replaying"
            ),
        }
    }
}

impl std::error::Error for TraceError {}

/// Importer bookkeeping: what was parsed, what was repaired, what was
/// dropped. Lenient-mode repairs are visible here, never silent.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceStats {
    /// Data rows seen (excluding blank/comment/header lines). With
    /// [`TraceOptions::limit`] this counts only the rows actually read
    /// before ingestion short-circuited.
    pub rows: usize,
    /// Events emitted (after instance expansion and `limit` truncation).
    pub events: usize,
    /// Malformed rows skipped (lenient mode).
    pub skipped: usize,
    /// Duplicate task ids dropped (lenient mode). Duplicate detection
    /// uses 64-bit FNV-1a fingerprints of the task id (8 bytes per task
    /// instead of the id string), so a false positive needs a 64-bit
    /// hash collision (odds ≈ n²/2⁶⁵).
    pub duplicates: usize,
    /// Valid rows that produce no arrival (Borg non-SUBMIT lifecycle
    /// rows).
    pub filtered: usize,
    /// Whether out-of-order timestamps were repaired (lenient mode) —
    /// through the bounded reorder buffer, or the full-sort fallback
    /// when [`TraceStats::full_resort`] is set.
    pub resorted: bool,
    /// Peak reorder displacement measured by the scan pass: the largest
    /// number of events the reorder buffer had to hold past their turn
    /// (0 for a time-sorted trace). The replay pass is byte-identical to
    /// a whole-trace stable sort whenever this fits
    /// [`TraceOptions::reorder_cap`].
    pub reorder_depth: usize,
    /// The trace's disorder exceeded [`TraceOptions::reorder_cap`]: the
    /// replay pass fell back to buffering and stable-sorting the whole
    /// event stream (correct, but no longer constant-memory).
    pub full_resort: bool,
    /// Ingestion stopped at [`TraceOptions::limit`] without reading the
    /// rest of the file.
    pub limit_hit: bool,
    /// Instances dropped from the row being expanded when the limit hit
    /// (rows beyond the cut are never read, so they are not counted
    /// anywhere).
    pub truncated_events: usize,
    /// Replayed span in (speedup-scaled) seconds: offset of the last
    /// arrival.
    pub span_secs: f64,
    /// Distinct app keys (= synthesized images).
    pub apps: usize,
    /// Which replay-pass strategy the scan pass selected — see
    /// [`IngestPath`]. Time-sorted traces (everything `gen-trace`
    /// produces) take [`IngestPath::Direct`] and never touch the reorder
    /// heap.
    pub ingest_path: IngestPath,
}

/// The replay-pass strategy the scan pass selects, recorded in
/// [`TraceStats::ingest_path`] so callers can see which pipeline their
/// trace actually exercised.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IngestPath {
    /// Single-pass direct streaming: no reorder buffer at all. Chosen in
    /// strict mode (the scan pass rejected any disorder) and in lenient
    /// mode when the scan measured [`TraceStats::reorder_depth`] == 0.
    /// Byte-identical to the buffered paths on such input: the reorder
    /// heap is keyed `(time, row order)`, so on a time-sorted stream
    /// every push is immediately the heap minimum and pops in input
    /// order — the heap is a per-event `O(log cap)` no-op the direct
    /// path simply skips.
    Direct,
    /// Lenient-mode bounded reorder buffer: disorder exists but fits
    /// [`TraceOptions::reorder_cap`].
    #[default]
    BoundedReorder,
    /// Whole-stream buffered stable sort — the disorder exceeded the
    /// buffer ([`TraceStats::full_resort`]).
    FullResort,
}

impl IngestPath {
    /// CLI/report-facing name of the path.
    pub fn label(&self) -> &'static str {
        match self {
            IngestPath::Direct => "direct",
            IngestPath::BoundedReorder => "bounded-reorder",
            IngestPath::FullResort => "full-resort",
        }
    }
}

/// A parsed trace, fully materialized: the buffered compatibility layer
/// over the streaming pipeline (`events` holds the whole normalized
/// stream). The paper-scale fixtures and tests use it; multi-million-row
/// replays should stream through [`TraceReplay`] instead.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Normalized events, sorted by `submit_at`.
    pub events: Vec<TraceEvent>,
    /// Importer bookkeeping.
    pub stats: TraceStats,
    /// Layer-synthesis seed carried from [`TraceOptions::seed`].
    seed: u64,
}

/// One raw row before normalization (absolute trace timestamps).
struct RawRow {
    task_id: String,
    app: String,
    start: f64,
    /// Absolute end time; None = no end recorded.
    end: Option<f64>,
    cpu_milli: u64,
    mem_bytes: u64,
    /// Pods to expand this row into (Alibaba `instance_num`).
    instances: u64,
}

// --- opening traces -------------------------------------------------------

/// Reject compressed formats the importer cannot inflate, *before*
/// feeding compressed bytes to the CSV parser.
fn check_extension(path: &Path) -> Result<(), TraceError> {
    if let Some(ext) = path.extension().and_then(|e| e.to_str()) {
        let ext = ext.to_ascii_lowercase();
        if matches!(ext.as_str(), "zst" | "zstd" | "xz" | "bz2" | "lz4" | "zip" | "7z") {
            return Err(TraceError::UnsupportedCompression { ext });
        }
    }
    Ok(())
}

/// Open `path` for one streaming pass. Files ending in `.gz` stream
/// through the bounded-memory [`crate::util::gzip::GzDecoder`] (real
/// cluster traces ship compressed — e.g. Alibaba's `batch_task.csv.gz`);
/// everything else reads as plain text.
fn open_reader(path: &Path) -> Result<Box<dyn BufRead>, TraceError> {
    let file = std::fs::File::open(path)
        .map_err(|e| TraceError::Io(format!("{}: {e}", path.display())))?;
    // Case-insensitive, matching `check_extension`: a `.GZ` trace must
    // decompress, not feed compressed bytes to the CSV parser.
    let is_gz = path
        .extension()
        .and_then(|e| e.to_str())
        .is_some_and(|e| e.eq_ignore_ascii_case("gz"));
    if is_gz {
        Ok(Box::new(std::io::BufReader::new(crate::util::gzip::GzDecoder::new(file))))
    } else {
        Ok(Box::new(std::io::BufReader::new(file)))
    }
}

/// Parse a whole trace file into a buffered [`Trace`] (both passes of the
/// streaming pipeline, collected). For replays that should stay
/// constant-memory, use [`TraceReplay::open`] instead.
pub fn load(path: &Path, opts: &TraceOptions) -> Result<Trace, TraceError> {
    TraceReplay::open(path, opts)?.into_trace()
}

/// Parse a trace from any seekable buffered reader (the in-memory /
/// test-harness entry point): the scan pass runs over the reader, the
/// reader rewinds, and the replay pass collects into a buffered
/// [`Trace`]. Blank lines and `#`-comments are skipped in both modes; a
/// literal `task_name…` header on an Alibaba trace is tolerated.
pub fn parse_reader<R: BufRead + Seek>(
    mut reader: R,
    opts: &TraceOptions,
) -> Result<Trace, TraceError> {
    let start = reader
        .stream_position()
        .map_err(|e| TraceError::Io(e.to_string()))?;
    let summary = scan(&mut reader, opts)?;
    reader
        .seek(SeekFrom::Start(start))
        .map_err(|e| TraceError::Io(e.to_string()))?;
    let mut source = TraceSource::new(&mut reader, opts, &summary);
    let mut events = Vec::with_capacity(summary.stats.events);
    while let Some(ev) = source.next_event()? {
        events.push(ev);
    }
    Ok(Trace { events, stats: summary.stats, seed: opts.seed })
}

/// A trace opened for constant-memory streaming replay: the scan pass has
/// run (stats, app set, and normalization anchor are known), and the
/// replay pass is ready to pull as an
/// [`crate::sim::arrivals::ArrivalSource`].
pub struct TraceReplay {
    /// Importer bookkeeping from the scan pass (the replay pass makes
    /// byte-identical decisions).
    pub stats: TraceStats,
    /// Distinct app keys, for registry synthesis.
    apps: BTreeSet<String>,
    /// Layer-synthesis seed carried from [`TraceOptions::seed`].
    seed: u64,
    source: TraceSource<Box<dyn BufRead>>,
}

impl TraceReplay {
    /// Open `path` for streaming replay: validate the extension, run the
    /// scan pass, and arm the replay pass (the file is opened twice; each
    /// pass streams it once).
    pub fn open(path: &Path, opts: &TraceOptions) -> Result<TraceReplay, TraceError> {
        check_extension(path)?;
        let summary = scan(open_reader(path)?, opts)?;
        let source = TraceSource::new(open_reader(path)?, opts, &summary);
        Ok(TraceReplay { stats: summary.stats, apps: summary.apps, seed: opts.seed, source })
    }

    /// Build a registry holding one synthesized image per distinct app
    /// key (sorted, so registry construction is deterministic) — same
    /// output as [`Trace::synthesize_registry`] on the buffered path.
    pub fn synthesize_registry(&self) -> Registry {
        let mut registry = Registry::new();
        for app in &self.apps {
            registry.push(synthesize_image(app, self.seed));
        }
        registry
    }

    /// Hand over the pull-based arrival source (consumes the replay).
    pub fn into_source(self) -> TraceSource<Box<dyn BufRead>> {
        self.source
    }

    /// Drain the replay pass into a buffered [`Trace`].
    fn into_trace(mut self) -> Result<Trace, TraceError> {
        let mut events = Vec::with_capacity(self.stats.events);
        while let Some(ev) = self.source.next_event()? {
            events.push(ev);
        }
        Ok(Trace { events, stats: self.stats, seed: self.seed })
    }
}

// --- the shared row parser ------------------------------------------------

/// Per-line parse/validate/dedup machinery shared verbatim by the scan
/// and replay passes, so both make byte-identical decisions about every
/// row.
struct RowParser {
    format: TraceFormat,
    mode: ErrorMode,
    stats: TraceStats,
    /// Azure column map, built from the header line.
    azure_cols: Option<AzureCols>,
    /// 64-bit FNV-1a fingerprints of task ids seen (see
    /// [`TraceStats::duplicates`] for the collision trade).
    seen_tasks: HashSet<u64>,
}

impl RowParser {
    fn new(opts: &TraceOptions) -> RowParser {
        RowParser {
            format: opts.format,
            mode: opts.mode,
            stats: TraceStats::default(),
            azure_cols: None,
            seen_tasks: HashSet::new(),
        }
    }

    /// Process one source line. `Ok(None)` = no row from this line
    /// (blank/comment/header, lenient skip, or a filtered Borg
    /// lifecycle row).
    fn push_line(&mut self, lineno: usize, raw: &str) -> Result<Option<RawRow>, TraceError> {
        let trimmed = raw.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            return Ok(None);
        }
        match self.format {
            TraceFormat::Alibaba => {
                // Tolerate a header on the first data line (the real
                // trace has none; comment/blank lines may precede it).
                // Matching the first two header column names keeps a
                // task literally named `task_name…` from false-matching.
                if self.stats.rows == 0 && trimmed.starts_with("task_name,instance_num") {
                    return Ok(None);
                }
            }
            TraceFormat::Azure => {
                if self.azure_cols.is_none() {
                    self.azure_cols = Some(AzureCols::from_header(trimmed, lineno)?);
                    return Ok(None);
                }
            }
            TraceFormat::Borg => {}
        }
        self.stats.rows += 1;
        let parsed = match self.format {
            TraceFormat::Alibaba => parse_alibaba_row(trimmed),
            TraceFormat::Azure => {
                parse_azure_row(trimmed, self.azure_cols.as_ref().expect("header parsed"))
            }
            TraceFormat::Borg => parse_borg_row(trimmed),
        };
        let row = match parsed {
            Ok(Some(row)) => row,
            Ok(None) => {
                // Valid lifecycle row that produces no arrival.
                self.stats.filtered += 1;
                return Ok(None);
            }
            Err(reason) => match self.mode {
                ErrorMode::Strict => {
                    return Err(TraceError::Malformed { line: lineno, reason })
                }
                ErrorMode::Lenient => {
                    self.stats.skipped += 1;
                    return Ok(None);
                }
            },
        };
        if !self.seen_tasks.insert(fnv64(&row.task_id)) {
            match self.mode {
                ErrorMode::Strict => {
                    return Err(TraceError::DuplicateTask { line: lineno, task: row.task_id })
                }
                ErrorMode::Lenient => {
                    self.stats.duplicates += 1;
                    return Ok(None);
                }
            }
        }
        Ok(Some(row))
    }
}

/// Streams raw (absolute-time) [`TraceEvent`]s off a reader: pulls lines
/// through the [`RowParser`], expands Alibaba `instance_num` rows, and
/// enforces the event limit by **short-circuiting** — once the limit is
/// reached no further line is read (or gzip-inflated).
struct EventReader<B> {
    lines: std::io::Lines<B>,
    parser: RowParser,
    lineno: usize,
    /// Row mid-expansion: (row, next instance index, source line).
    pending: Option<(RawRow, u64, usize)>,
    emitted: usize,
    limit: usize,
    finished: bool,
}

impl<B: BufRead> EventReader<B> {
    fn new(reader: B, opts: &TraceOptions) -> EventReader<B> {
        EventReader {
            lines: reader.lines(),
            parser: RowParser::new(opts),
            lineno: 0,
            pending: None,
            emitted: 0,
            limit: opts.limit.unwrap_or(usize::MAX),
            finished: false,
        }
    }

    /// Next raw event (absolute trace timestamps; normalization happens
    /// at the consumer edge so order checks see the trace's own times).
    fn next_event(&mut self) -> Result<Option<TraceEvent>, TraceError> {
        if self.finished {
            return Ok(None);
        }
        loop {
            if self.emitted >= self.limit {
                // Limit short-circuit: stop reading. Count what the cut
                // dropped from the current row; when the cut fell on a row
                // boundary, probe ahead so `limit_hit` means "a data row
                // (or unreadable input) was cut", not "the file also ended
                // here". The probe skips trailing blank/comment lines and
                // stops at the first data candidate (or read error), so a
                // real cut stops it after one line; probed lines are never
                // parsed, and both passes probe identically.
                self.finished = true;
                if let Some((row, k, _)) = self.pending.take() {
                    self.parser.stats.truncated_events += (row.instances - k) as usize;
                    self.parser.stats.limit_hit = true;
                } else {
                    while let Some(line) = self.lines.next() {
                        match line {
                            Err(_) => {
                                // Unreadable tail: input existed past the
                                // cut even if it cannot be decoded.
                                self.parser.stats.limit_hit = true;
                                break;
                            }
                            Ok(l) => {
                                let t = l.trim();
                                if !t.is_empty() && !t.starts_with('#') {
                                    self.parser.stats.limit_hit = true;
                                    break;
                                }
                            }
                        }
                    }
                }
                return Ok(None);
            }
            if let Some((row, k, line)) = self.pending.as_mut() {
                let task_id = if row.instances == 1 {
                    row.task_id.clone()
                } else {
                    format!("{}#{k}", row.task_id)
                };
                let ev = TraceEvent {
                    line: *line,
                    submit_at: row.start, // absolute; normalized downstream
                    task_id,
                    app: row.app.clone(),
                    cpu_milli: row.cpu_milli,
                    mem_bytes: row.mem_bytes,
                    duration_secs: row.end.map(|e| e - row.start),
                };
                *k += 1;
                if *k >= row.instances {
                    self.pending = None;
                }
                self.emitted += 1;
                self.parser.stats.events += 1;
                return Ok(Some(ev));
            }
            match self.lines.next() {
                None => {
                    self.finished = true;
                    return Ok(None);
                }
                Some(line) => {
                    let line = line.map_err(|e| TraceError::Io(e.to_string()))?;
                    self.lineno += 1;
                    if let Some(row) = self.parser.push_line(self.lineno, &line)? {
                        self.pending = Some((row, 0, self.lineno));
                    }
                }
            }
        }
    }
}

// --- ordering keys --------------------------------------------------------

/// Total-order key for the reorder buffer: `(raw time, parse order)`.
/// Times are finite by construction (`parse_f64` rejects non-finite), so
/// the order is total; the sequence tie-break makes heap emission exactly
/// a *stable* sort by time.
#[derive(Debug, Clone, Copy)]
struct TimeKey {
    t: f64,
    seq: u64,
}

impl PartialEq for TimeKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for TimeKey {}

impl PartialOrd for TimeKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TimeKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t
            .partial_cmp(&other.t)
            .expect("trace timestamps are finite")
            .then(self.seq.cmp(&other.seq))
    }
}

/// A buffered event in the replay pass's reorder heap.
struct HeapEvent {
    key: TimeKey,
    ev: TraceEvent,
}

impl PartialEq for HeapEvent {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl Eq for HeapEvent {}

impl PartialOrd for HeapEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key.cmp(&other.key)
    }
}

// --- pass 1: scan ---------------------------------------------------------

/// What the scan pass learned about the trace.
struct ScanSummary {
    stats: TraceStats,
    /// Earliest kept timestamp (the `t=0` normalization anchor).
    t0: f64,
    /// Distinct app keys, for registry synthesis.
    apps: BTreeSet<String>,
}

/// Pass 1: stream the whole (limit-truncated) trace once, keeping only
/// bounded state — strict-mode validation with line numbers, min/max
/// timestamps, the app set, and a keys-only simulation of the bounded
/// reorder buffer that measures the trace's disorder and decides whether
/// the replay pass needs the full-sort fallback.
fn scan<B: BufRead>(reader: B, opts: &TraceOptions) -> Result<ScanSummary, TraceError> {
    assert!(opts.speedup > 0.0, "trace speedup must be positive");
    let mut er = EventReader::new(reader, opts);
    let mut apps: BTreeSet<String> = BTreeSet::new();
    let mut min_t = f64::INFINITY;
    let mut max_t = f64::NEG_INFINITY;
    let mut prev_t = f64::NEG_INFINITY;
    let mut inversion = false;
    let mut full_resort = false;
    // Keys-only reorder-buffer simulation (lenient mode): identical pop
    // discipline to the replay pass, 16 bytes per buffered event.
    let cap = opts.reorder_cap.max(1);
    let mut heap: BinaryHeap<Reverse<TimeKey>> = BinaryHeap::new();
    let mut seq: u64 = 0;
    let mut emit_idx: u64 = 0;
    let mut depth: u64 = 0;
    let mut max_emitted: Option<TimeKey> = None;

    while let Some(ev) = er.next_event()? {
        let t = ev.submit_at; // raw absolute time
        if t < prev_t {
            match opts.mode {
                ErrorMode::Strict => return Err(TraceError::OutOfOrder { line: ev.line }),
                ErrorMode::Lenient => inversion = true,
            }
        }
        prev_t = t;
        min_t = min_t.min(t);
        max_t = max_t.max(t);
        if !apps.contains(ev.app.as_str()) {
            apps.insert(ev.app.clone());
        }
        if opts.mode == ErrorMode::Lenient {
            let key = TimeKey { t, seq };
            if let Some(m) = &max_emitted {
                if key < *m {
                    // The bounded buffer already emitted something that
                    // sorts after this event: bounded replay would not
                    // match the stable full sort. Fall back.
                    full_resort = true;
                }
            }
            heap.push(Reverse(key));
            if heap.len() > cap {
                let popped = heap.pop().expect("heap non-empty").0;
                depth = depth.max(popped.seq.saturating_sub(emit_idx));
                emit_idx += 1;
                let is_new_max = match &max_emitted {
                    None => true,
                    Some(m) => popped > *m,
                };
                if is_new_max {
                    max_emitted = Some(popped);
                }
            }
        }
        seq += 1;
    }
    while let Some(Reverse(popped)) = heap.pop() {
        depth = depth.max(popped.seq.saturating_sub(emit_idx));
        emit_idx += 1;
    }

    let mut stats = std::mem::take(&mut er.parser.stats);
    if stats.events == 0 {
        return Err(TraceError::Empty);
    }
    stats.resorted = inversion;
    stats.reorder_depth = depth as usize;
    stats.full_resort = full_resort;
    stats.ingest_path = if full_resort {
        IngestPath::FullResort
    } else if opts.mode == ErrorMode::Strict || depth == 0 {
        // Strict already proved the stream ordered; a measured depth of 0
        // proves the heap would emit input order anyway. Either way the
        // replay pass can stream single-pass, heap-free.
        IngestPath::Direct
    } else {
        IngestPath::BoundedReorder
    };
    stats.apps = apps.len();
    stats.span_secs = (max_t - min_t) / opts.speedup;
    Ok(ScanSummary { stats, t0: min_t, apps })
}

// --- pass 2: the streaming arrival source ---------------------------------

/// Normalize a raw event against the scan pass's anchor: earliest arrival
/// at t = 0, then compress by `speedup` (same float operations as the
/// historical buffered path, so offsets are bit-identical).
fn normalize_event(mut ev: TraceEvent, t0: f64, speedup: f64) -> TraceEvent {
    ev.submit_at = (ev.submit_at - t0) / speedup;
    if let Some(d) = &mut ev.duration_secs {
        *d /= speedup;
    }
    ev
}

/// Build the pod one normalized trace event replays as (shared by the
/// streaming source and the buffered [`Trace::arrivals`], so both paths
/// produce identical pods).
fn pod_for_event(builder: &mut PodBuilder, ev: &TraceEvent) -> Pod {
    let (name, tag) = image_name_for_app(&ev.app);
    let mut pod = builder.build(
        &format!("{name}:{tag}"),
        Resources::new(MilliCpu(ev.cpu_milli), Bytes(ev.mem_bytes)),
    );
    if let Some(d) = ev.duration_secs {
        pod = pod.with_duration(d);
    }
    pod
}

/// Pass 2: the pull-based streaming replay —
/// [`crate::sim::arrivals::ArrivalSource`] over a trace reader, running
/// whichever strategy the scan pass selected ([`IngestPath`]): direct
/// single-pass streaming when the input needs no repair (strict mode, or
/// a measured [`TraceStats::reorder_depth`] of 0 — pre-sorted traces
/// never pay for the heap), the bounded reorder min-heap
/// ([`TraceOptions::reorder_cap`]) when disorder fits it, and the
/// buffered whole-stream stable sort when it does not
/// ([`TraceStats::full_resort`]) — identical output on all three,
/// documented memory cost on the last.
///
/// I/O or parse errors encountered mid-replay (e.g. the file changed
/// between the passes, or late gzip corruption) end the stream; check
/// [`TraceSource::take_error`] after draining, or hold on to
/// [`TraceSource::error_slot`] when the source is handed to the engine
/// by value.
pub struct TraceSource<B: BufRead> {
    reader: EventReader<B>,
    /// Replay strategy the scan pass selected (see [`IngestPath`]).
    path: IngestPath,
    t0: f64,
    speedup: f64,
    cap: usize,
    heap: BinaryHeap<Reverse<HeapEvent>>,
    seq: u64,
    input_done: bool,
    /// Whole-trace fallback: sorted events not yet emitted.
    sorted: Option<std::vec::IntoIter<TraceEvent>>,
    builder: PodBuilder,
    /// Shared slot for a mid-replay error (see [`TraceErrorSlot`]).
    failed: TraceErrorSlot,
}

/// Shared handle to a [`TraceSource`]'s mid-replay error: the
/// [`crate::sim::arrivals::ArrivalSource`] pull interface has no error
/// channel, so a source that fails mid-stream records the
/// [`TraceError`] here and ends the stream. Callers that move the
/// source into the engine keep a clone of the slot
/// ([`TraceSource::error_slot`]) and inspect it after the run.
pub type TraceErrorSlot = Arc<Mutex<Option<TraceError>>>;

impl<B: BufRead> TraceSource<B> {
    /// Arm the replay pass over `reader`, using the scan pass's summary
    /// for the normalization anchor and the fallback decision.
    fn new(reader: B, opts: &TraceOptions, summary: &ScanSummary) -> TraceSource<B> {
        TraceSource {
            reader: EventReader::new(reader, opts),
            path: summary.stats.ingest_path,
            t0: summary.t0,
            speedup: opts.speedup,
            cap: opts.reorder_cap.max(1),
            heap: BinaryHeap::new(),
            seq: 0,
            input_done: false,
            sorted: None,
            builder: PodBuilder::new(),
            failed: Arc::new(Mutex::new(None)),
        }
    }

    /// Next normalized event in replay order, or `None` at end of trace.
    pub fn next_event(&mut self) -> Result<Option<TraceEvent>, TraceError> {
        let (t0, speedup) = (self.t0, self.speedup);
        match self.path {
            IngestPath::FullResort => {
                if self.sorted.is_none() {
                    let mut all = Vec::new();
                    while let Some(ev) = self.reader.next_event()? {
                        all.push(ev);
                    }
                    // Stable: equal timestamps keep the trace's row order.
                    all.sort_by(|a, b| {
                        a.submit_at.partial_cmp(&b.submit_at).expect("finite timestamps")
                    });
                    self.sorted = Some(all.into_iter());
                }
                let next = self.sorted.as_mut().expect("fallback built").next();
                Ok(next.map(|ev| normalize_event(ev, t0, speedup)))
            }
            IngestPath::Direct => {
                // Single-pass: strict proved the stream ordered, or the
                // scan measured zero disorder — the heap would pop every
                // event straight back out in input order, so skip it.
                let next = self.reader.next_event()?;
                Ok(next.map(|ev| normalize_event(ev, t0, speedup)))
            }
            IngestPath::BoundedReorder => loop {
                if !self.input_done && self.heap.len() <= self.cap {
                    match self.reader.next_event()? {
                        None => self.input_done = true,
                        Some(ev) => {
                            let key = TimeKey { t: ev.submit_at, seq: self.seq };
                            self.seq += 1;
                            self.heap.push(Reverse(HeapEvent { key, ev }));
                        }
                    }
                    continue;
                }
                let next = self.heap.pop();
                return Ok(next.map(|Reverse(h)| normalize_event(h.ev, t0, speedup)));
            },
        }
    }

    /// The error that ended the stream early (if any) — set when a pull
    /// through [`ArrivalSource::next_arrival`] hit an I/O or parse
    /// failure it had no channel to report.
    pub fn take_error(&mut self) -> Option<TraceError> {
        self.failed.lock().expect("trace error slot poisoned").take()
    }

    /// A shared handle to the mid-replay error slot, for callers that
    /// move the source into the engine (see [`TraceErrorSlot`]).
    pub fn error_slot(&self) -> TraceErrorSlot {
        Arc::clone(&self.failed)
    }
}

impl<B: BufRead> ArrivalSource for TraceSource<B> {
    fn next_arrival(&mut self) -> Option<(f64, Pod)> {
        if self.failed.lock().expect("trace error slot poisoned").is_some() {
            return None;
        }
        match self.next_event() {
            Ok(Some(ev)) => {
                let pod = pod_for_event(&mut self.builder, &ev);
                Some((ev.submit_at, pod))
            }
            Ok(None) => None,
            Err(e) => {
                *self.failed.lock().expect("trace error slot poisoned") = Some(e);
                None
            }
        }
    }
}

// --- dialect row parsers --------------------------------------------------

/// Split and validate one headerless Alibaba `batch_task` row.
fn parse_alibaba_row(line: &str) -> Result<Option<RawRow>, String> {
    let cols: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
    if cols.len() < 9 {
        return Err(format!("expected 9 columns, found {}", cols.len()));
    }
    let task_name = cols[0];
    let job_name = cols[2];
    if task_name.is_empty() {
        return Err("empty task_name".to_string());
    }
    let instances = match cols[1] {
        "" => 1,
        s => s.parse::<u64>().map_err(|_| format!("bad instance_num {s:?}"))?,
    };
    if instances == 0 {
        // A zero-instance row would vanish silently from the replay;
        // surface it as malformed (strict rejects, lenient counts it).
        return Err("instance_num is 0".to_string());
    }
    let start = parse_f64(cols[5], "start_time")?;
    let end = match cols[6] {
        "" => None,
        s => Some(parse_f64(s, "end_time")?),
    };
    if let Some(e) = end {
        if e < start {
            return Err(format!("end_time {e} before start_time {start}"));
        }
    }
    // plan_cpu: 100 = 1 core → ×10 millicores.
    let plan_cpu = parse_f64(cols[7], "plan_cpu")?;
    // plan_mem: percent of the reference machine's memory.
    let plan_mem = parse_f64(cols[8], "plan_mem")?;
    if plan_cpu < 0.0 || plan_mem < 0.0 {
        return Err("negative resource plan".to_string());
    }
    Ok(Some(RawRow {
        task_id: format!("{task_name}@{job_name}"),
        app: task_name.to_string(),
        start,
        end,
        cpu_milli: ((plan_cpu * 10.0).round() as u64).max(MIN_CPU_MILLI),
        mem_bytes: ((plan_mem / 100.0 * REF_NODE_MEM_GB * 1e9).round() as u64)
            .max(MIN_MEM_BYTES),
        instances,
    }))
}

/// Column indices resolved from an Azure-style header line.
struct AzureCols {
    /// Header width: data rows with fewer columns are malformed (a
    /// truncated row must not silently pass as "no end time").
    width: usize,
    id: usize,
    /// App-key column (`appname` > `vmtypeid` > `tenantid`); falls back
    /// to the id column when absent.
    app: usize,
    start: usize,
    end: Option<usize>,
    cpu: usize,
    mem: usize,
}

impl AzureCols {
    fn from_header(header: &str, lineno: usize) -> Result<AzureCols, TraceError> {
        let names: Vec<String> =
            header.split(',').map(|c| c.trim().to_ascii_lowercase()).collect();
        let find = |cands: &[&str]| cands.iter().find_map(|c| names.iter().position(|n| n == c));
        let missing = |what: &str| TraceError::Malformed {
            line: lineno,
            reason: format!("azure header missing a {what} column (got {header:?})"),
        };
        let id = find(&["vmid", "id"]).ok_or_else(|| missing("vmid"))?;
        let start = find(&["starttime", "start"]).ok_or_else(|| missing("starttime"))?;
        let cpu = find(&["core", "cores", "vcpus"]).ok_or_else(|| missing("core"))?;
        let mem = find(&["memory", "mem"]).ok_or_else(|| missing("memory"))?;
        let app = find(&["appname", "app", "vmtypeid", "tenantid"]).unwrap_or(id);
        let end = find(&["endtime", "end"]);
        Ok(AzureCols { width: names.len(), id, app, start, end, cpu, mem })
    }
}

/// Field accessor for a split Azure row (missing column ⇒ malformed).
fn azure_field<'a>(fields: &[&'a str], i: usize, what: &str) -> Result<&'a str, String> {
    fields.get(i).copied().ok_or_else(|| format!("row too short for {what} column"))
}

/// Split and validate one Azure-style data row against the header map.
fn parse_azure_row(line: &str, cols: &AzureCols) -> Result<Option<RawRow>, String> {
    let fields: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
    if fields.len() < cols.width {
        return Err(format!(
            "row has {} columns, header has {}",
            fields.len(),
            cols.width
        ));
    }
    let id = azure_field(&fields, cols.id, "vmid")?;
    if id.is_empty() {
        return Err("empty vmid".to_string());
    }
    let app = azure_field(&fields, cols.app, "app")?;
    // Times are fractional days. VMs alive before the trace window carry
    // negative start times in the public packing trace; clamp to the
    // window start (they are submitted at replay start).
    let start =
        parse_f64(azure_field(&fields, cols.start, "starttime")?, "starttime")?.max(0.0)
            * SECS_PER_DAY;
    let end = match cols.end {
        None => None,
        Some(i) => match fields.get(i).copied().unwrap_or("") {
            "" => None,
            s => Some(parse_f64(s, "endtime")?.max(0.0) * SECS_PER_DAY),
        },
    };
    if let Some(e) = end {
        if e < start {
            return Err(format!("endtime {e} before starttime {start}"));
        }
    }
    // core / memory: fractions of the reference server.
    let core = parse_f64(azure_field(&fields, cols.cpu, "core")?, "core")?;
    let mem = parse_f64(azure_field(&fields, cols.mem, "memory")?, "memory")?;
    if core < 0.0 || mem < 0.0 {
        return Err("negative resource fraction".to_string());
    }
    Ok(Some(RawRow {
        task_id: id.to_string(),
        app: if app.is_empty() { id.to_string() } else { app.to_string() },
        start,
        end,
        cpu_milli: ((core * REF_NODE_CORES * 1000.0).round() as u64).max(MIN_CPU_MILLI),
        mem_bytes: ((mem * REF_NODE_MEM_GB * 1e9).round() as u64).max(MIN_MEM_BYTES),
        instances: 1,
    }))
}

/// Split and validate one headerless Google cluster-data (Borg)
/// `task_events` row — see [`TraceFormat::Borg`] for the column map.
/// Non-SUBMIT lifecycle rows are valid input but produce no arrival
/// (`Ok(None)`, counted in [`TraceStats::filtered`]).
fn parse_borg_row(line: &str) -> Result<Option<RawRow>, String> {
    let cols: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
    if cols.len() < 11 {
        return Err(format!("expected at least 11 columns, found {}", cols.len()));
    }
    let time_us = parse_f64(cols[0], "time")?;
    if time_us < 0.0 {
        return Err("negative timestamp".to_string());
    }
    let job = cols[2];
    let task_index = cols[3];
    if job.is_empty() || task_index.is_empty() {
        return Err("empty job_id or task_index".to_string());
    }
    let event_type = cols[5]
        .parse::<u32>()
        .map_err(|_| format!("bad event_type {:?}", cols[5]))?;
    if event_type != 0 {
        // SCHEDULE/EVICT/FAIL/FINISH/KILL/…: lifecycle rows, not arrivals.
        return Ok(None);
    }
    // Requests are fractions of the largest machine; empty cells happen
    // in the public trace and floor to the minimum request.
    let cpu = match cols[9] {
        "" => 0.0,
        s => parse_f64(s, "cpu_request")?,
    };
    let mem = match cols[10] {
        "" => 0.0,
        s => parse_f64(s, "mem_request")?,
    };
    if cpu < 0.0 || mem < 0.0 {
        return Err("negative resource request".to_string());
    }
    Ok(Some(RawRow {
        task_id: format!("{job}#{task_index}"),
        app: job.to_string(),
        start: time_us / 1e6,
        // Lifetimes live in later FINISH rows; pairing them would need
        // unbounded cross-stream state, so Borg tasks replay as services.
        end: None,
        cpu_milli: ((cpu * REF_NODE_CORES * 1000.0).round() as u64).max(MIN_CPU_MILLI),
        mem_bytes: ((mem * REF_NODE_MEM_GB * 1e9).round() as u64).max(MIN_MEM_BYTES),
        instances: 1,
    }))
}

fn parse_f64(s: &str, what: &str) -> Result<f64, String> {
    let v: f64 = s.parse().map_err(|_| format!("bad {what} {s:?}"))?;
    if !v.is_finite() {
        return Err(format!("non-finite {what} {s:?}"));
    }
    Ok(v)
}

// --- layer-composition synthesis ------------------------------------------

/// Shared OS base layers the synthesizer draws from, with popularity
/// weights (debian-family bases dominate real registries). Names reuse
/// the `registry::hub` layer namespace so digests line up if a synthetic
/// corpus and a trace corpus ever share a registry.
const BASE_POOL: &[(&str, f64, f64)] = &[
    ("os.debian12", 49.0, 4.0),
    ("os.ubuntu2204", 29.0, 3.0),
    ("os.alpine319", 3.4, 2.0),
    ("os.debian11", 52.0, 1.0),
];

/// Shared runtime/dependency layers (language stacks, cert bundles).
const RUNTIME_POOL: &[(&str, f64)] = &[
    ("rt.jre17", 92.0),
    ("rt.python311", 19.0),
    ("rt.node18", 48.0),
    ("rt.go121", 68.0),
    ("rt.php82", 31.0),
    ("dep.ca-certs", 3.0),
    ("dep.curl", 48.0),
    ("rt.dotnet8", 110.0),
];

/// FNV-1a over the app key — the deterministic hash that anchors all
/// per-app synthesis decisions (and the task-id dedup fingerprints).
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The `(name, tag)` a given app key synthesizes to. A short hash suffix
/// keeps sanitized names collision-free.
pub fn image_name_for_app(app: &str) -> (String, String) {
    let mut s: String = app
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' {
                c.to_ascii_lowercase()
            } else {
                '-'
            }
        })
        .collect();
    s.truncate(40);
    (format!("trace/{s}-{:08x}", (fnv64(app) >> 32) as u32), "r1".to_string())
}

/// Deterministically synthesize the image for one app key: a weighted
/// shared base, 0–2 shared runtime layers, and 1–2 unique app layers with
/// heavy-tailed sizes. Same `(app, seed)` ⇒ byte-identical manifest.
pub fn synthesize_image(app: &str, seed: u64) -> ImageMetadata {
    let mut rng = Pcg::new(seed ^ fnv64(app), 29);
    let weights: Vec<f64> = BASE_POOL.iter().map(|(_, _, w)| *w).collect();
    let (base_name, base_mb, _) = BASE_POOL[rng.weighted(&weights)];
    let mut layers =
        vec![LayerMetadata { digest: digest_for(base_name), size: Bytes::from_mb(base_mb) }];
    let mut rt_idx: Vec<usize> = (0..RUNTIME_POOL.len()).collect();
    rng.shuffle(&mut rt_idx);
    for &i in rt_idx.iter().take(rng.range(0, 3)) {
        let (name, mb) = RUNTIME_POOL[i];
        layers.push(LayerMetadata { digest: digest_for(name), size: Bytes::from_mb(mb) });
    }
    for k in 0..1 + rng.range(0, 2) {
        let mb = (4.0 + rng.exponential(60.0)).min(400.0);
        layers.push(LayerMetadata {
            digest: digest_for(&format!("trace.app.{app}.{k}")),
            size: Bytes::from_mb(mb),
        });
    }
    let (name, tag) = image_name_for_app(app);
    ImageMetadata::new(&digest_for(&format!("manifest.{name}:{tag}")), &name, &tag, layers)
}

impl Trace {
    /// Build a registry holding one synthesized image per distinct app
    /// key (sorted, so registry construction is deterministic).
    pub fn synthesize_registry(&self) -> Registry {
        let apps: BTreeSet<&str> = self.events.iter().map(|e| e.app.as_str()).collect();
        let mut registry = Registry::new();
        for app in apps {
            registry.push(synthesize_image(app, self.seed));
        }
        registry
    }

    /// Build the `(arrival-offset, Pod)` pairs to feed
    /// [`crate::sim::Simulation::run_arrivals`]. Pod ids are assigned in
    /// trace order by a fresh [`PodBuilder`] — the same ids the
    /// streaming [`TraceSource`] assigns when pulled in order.
    pub fn arrivals(&self) -> Vec<(f64, Pod)> {
        let mut builder = PodBuilder::new();
        self.events
            .iter()
            .map(|ev| (ev.submit_at, pod_for_event(&mut builder, ev)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const ALIBABA_OK: &str = "\
task_m1,2,j_1,A,Terminated,100,160,50,0.5
task_r2,1,j_1,A,Terminated,103,103,200,1.0
task_m1,1,j_2,A,Terminated,110,,100,0.2
";

    fn parse_str(s: &str, opts: &TraceOptions) -> Result<Trace, TraceError> {
        parse_reader(Cursor::new(s.as_bytes().to_vec()), opts)
    }

    #[test]
    fn direct_path_matches_the_reorder_heap_on_sorted_input() {
        // The single-pass fast path's correctness argument, executed: on
        // input the scan measured as sorted, streaming past the heap must
        // emit exactly what the heap would have (it pops every push
        // immediately, in input order). Force the heap on a second source
        // over the same bytes and compare event-for-event.
        let opts = TraceOptions::default();
        let summary = scan(Cursor::new(ALIBABA_OK.as_bytes()), &opts).unwrap();
        assert_eq!(summary.stats.ingest_path, IngestPath::Direct);
        let mut forced = scan(Cursor::new(ALIBABA_OK.as_bytes()), &opts).unwrap();
        forced.stats.ingest_path = IngestPath::BoundedReorder;

        let mut direct = TraceSource::new(Cursor::new(ALIBABA_OK.as_bytes()), &opts, &summary);
        let mut heaped = TraceSource::new(Cursor::new(ALIBABA_OK.as_bytes()), &opts, &forced);
        loop {
            let a = direct.next_event().unwrap();
            let b = heaped.next_event().unwrap();
            assert_eq!(a, b, "heap-free fast path diverged from the reorder heap");
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn strict_mode_on_sorted_input_selects_the_direct_path() {
        let opts = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        let t = parse_str(ALIBABA_OK, &opts).unwrap();
        assert_eq!(t.stats.ingest_path, IngestPath::Direct);
        assert_eq!(t.events.len(), 4);
    }

    #[test]
    fn alibaba_happy_path() {
        let t = parse_str(ALIBABA_OK, &TraceOptions::default()).unwrap();
        // Row 1 expands into 2 instances.
        assert_eq!(t.events.len(), 4);
        assert_eq!(t.stats.rows, 3);
        assert_eq!(t.stats.events, 4);
        assert_eq!(t.stats.skipped, 0);
        assert_eq!(t.stats.apps, 2, "task_m1 recurs across jobs");
        assert_eq!(t.stats.reorder_depth, 0, "fixture is time-sorted");
        assert!(!t.stats.full_resort);
        assert_eq!(
            t.stats.ingest_path,
            IngestPath::Direct,
            "zero measured disorder must select the heap-free single pass"
        );
        assert!(!t.stats.limit_hit);
        // Normalized to t=0.
        assert_eq!(t.events[0].submit_at, 0.0);
        assert_eq!(t.events[2].submit_at, 3.0);
        assert_eq!(t.events[3].submit_at, 10.0);
        // Durations: 60s, 0s (zero-duration task), forever.
        assert_eq!(t.events[0].duration_secs, Some(60.0));
        assert_eq!(t.events[2].duration_secs, Some(0.0));
        assert_eq!(t.events[3].duration_secs, None);
        // plan_cpu 50 → 500m; plan_mem 0.5% of 8 GB = 40 MB.
        assert_eq!(t.events[0].cpu_milli, 500);
        assert_eq!(t.events[0].mem_bytes, 40_000_000);
        // Instance expansion keeps ids unique.
        assert_eq!(t.events[0].task_id, "task_m1@j_1#0");
        assert_eq!(t.events[1].task_id, "task_m1@j_1#1");
        assert_eq!(t.events[3].task_id, "task_m1@j_2");
    }

    #[test]
    fn speedup_scales_arrivals_and_durations() {
        let opts = TraceOptions { speedup: 10.0, ..Default::default() };
        let t = parse_str(ALIBABA_OK, &opts).unwrap();
        assert_eq!(t.events[0].duration_secs, Some(6.0));
        assert_eq!(t.events[3].submit_at, 1.0);
        assert_eq!(t.stats.span_secs, 1.0);
    }

    #[test]
    fn limit_truncates_mid_expansion_and_short_circuits() {
        let opts = TraceOptions { limit: Some(1), ..Default::default() };
        let t = parse_str(ALIBABA_OK, &opts).unwrap();
        assert_eq!(t.events.len(), 1);
        assert!(t.stats.limit_hit, "the cut must be visible in stats");
        assert_eq!(t.stats.truncated_events, 1, "row 1's second instance was dropped");
        // Short-circuit: rows 2 and 3 were never read.
        assert_eq!(t.stats.rows, 1);
    }

    #[test]
    fn exact_limit_is_not_reported_as_a_cut() {
        // ALIBABA_OK holds exactly 4 events: a limit of 4 truncates
        // nothing, and the stats must say so (the EOF probe).
        let opts = TraceOptions { limit: Some(4), ..Default::default() };
        let t = parse_str(ALIBABA_OK, &opts).unwrap();
        assert_eq!(t.events.len(), 4);
        assert!(!t.stats.limit_hit, "limit == trace length: nothing was cut");
        assert_eq!(t.stats.truncated_events, 0);
        // Trailing blank/comment lines are not data: still not a cut.
        let trailing = format!("{ALIBABA_OK}\n# trailing comment\n\n");
        let t = parse_str(&trailing, &opts).unwrap();
        assert!(!t.stats.limit_hit, "trailing comments are not truncated data");
        // But a data row past the cut is: limit 3 stops before row 3.
        let opts = TraceOptions { limit: Some(3), ..Default::default() };
        let t = parse_str(ALIBABA_OK, &opts).unwrap();
        assert_eq!(t.events.len(), 3);
        assert!(t.stats.limit_hit, "row 3 was cut");
        assert_eq!(t.stats.truncated_events, 0, "the cut fell on a row boundary");
    }

    #[test]
    fn malformed_rows_strict_vs_lenient() {
        let bad = "task_a,1,j_1,A,Terminated,100,160,50,0.5\nnot-a-row\n";
        let strict =
            TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        match parse_str(bad, &strict) {
            Err(TraceError::Malformed { line: 2, .. }) => {}
            other => panic!("expected Malformed at line 2, got {other:?}"),
        }
        let t = parse_str(bad, &TraceOptions::default()).unwrap();
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.stats.skipped, 1);
    }

    #[test]
    fn truncated_row_and_bad_numbers_are_malformed() {
        for bad in [
            "task_a,1,j_1,A,Terminated,100,160,50", // 8 columns
            "task_a,1,j_1,A,Terminated,abc,160,50,0.5", // bad start
            "task_a,1,j_1,A,Terminated,100,90,50,0.5", // end before start
            "task_a,1,j_1,A,Terminated,100,160,-5,0.5", // negative cpu
            ",1,j_1,A,Terminated,100,160,50,0.5",   // empty task name
            "task_a,x,j_1,A,Terminated,100,160,50,0.5", // bad instance_num
            "task_a,0,j_1,A,Terminated,100,160,50,0.5", // zero instances
        ] {
            let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
            assert!(
                matches!(parse_str(bad, &strict), Err(TraceError::Malformed { .. })),
                "{bad:?} should be malformed"
            );
        }
    }

    #[test]
    fn out_of_order_resorted_or_rejected() {
        let ooo = "\
task_a,1,j_1,A,Terminated,200,260,50,0.5
task_b,1,j_1,A,Terminated,100,160,50,0.5
";
        let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        assert!(matches!(
            parse_str(ooo, &strict),
            Err(TraceError::OutOfOrder { line: 2 })
        ));
        let t = parse_str(ooo, &TraceOptions::default()).unwrap();
        assert!(t.stats.resorted);
        assert_eq!(t.stats.reorder_depth, 1, "task_b was held one slot past its turn");
        assert!(!t.stats.full_resort, "tiny disorder fits the default buffer");
        assert_eq!(t.events[0].app, "task_b");
        assert_eq!(t.events[0].submit_at, 0.0);
        assert_eq!(t.events[1].submit_at, 100.0);
    }

    #[test]
    fn bounded_reorder_matches_full_sort() {
        // A deterministically shuffled trace: bounded-buffer replay must
        // equal the whole-trace stable sort for any sufficient cap, and
        // the full-sort fallback must equal it for an insufficient cap.
        let mut rng = Pcg::new(7, 3);
        let mut rows: Vec<String> = (0..200)
            .map(|i| format!("task_{i},1,j_{i},A,Terminated,{},{},50,0.5", 1000 + i, 2000 + i))
            .collect();
        // Local shuffles with displacement < 16.
        for w in 0..(rows.len() / 8) {
            let base = w * 8;
            let a = base + rng.range(0, 8);
            let b = base + rng.range(0, 8);
            rows.swap(a, b);
        }
        let text = rows.join("\n");
        let big = TraceOptions { reorder_cap: 100_000, ..Default::default() };
        let reference = parse_str(&text, &big).unwrap();
        assert!(!reference.stats.full_resort);

        let bounded = TraceOptions { reorder_cap: 16, ..Default::default() };
        let t = parse_str(&text, &bounded).unwrap();
        assert!(!t.stats.full_resort, "depth {} must fit 16", t.stats.reorder_depth);
        assert!(t.stats.reorder_depth <= 16);
        assert_eq!(t.events, reference.events, "bounded buffer must equal the full sort");

        let tiny = TraceOptions { reorder_cap: 1, ..Default::default() };
        let t = parse_str(&text, &tiny).unwrap();
        if t.stats.reorder_depth > 1 {
            assert!(t.stats.full_resort, "overflowing the buffer must trigger the fallback");
        }
        assert_eq!(t.events, reference.events, "fallback must equal the full sort");
    }

    #[test]
    fn duplicate_task_ids_dropped_or_rejected() {
        let dup = "\
task_a,1,j_1,A,Terminated,100,160,50,0.5
task_a,1,j_1,A,Terminated,120,180,50,0.5
";
        let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        match parse_str(dup, &strict) {
            Err(TraceError::DuplicateTask { line: 2, task }) => {
                assert_eq!(task, "task_a@j_1");
            }
            other => panic!("expected DuplicateTask, got {other:?}"),
        }
        let t = parse_str(dup, &TraceOptions::default()).unwrap();
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.stats.duplicates, 1);
    }

    #[test]
    fn empty_trace_is_an_error() {
        assert!(matches!(parse_str("", &TraceOptions::default()), Err(TraceError::Empty)));
        assert!(matches!(
            parse_str("# only a comment\n", &TraceOptions::default()),
            Err(TraceError::Empty)
        ));
    }

    #[test]
    fn unsupported_compressed_extensions_are_rejected() {
        // The check runs before any I/O, so no file needs to exist, and
        // the message must point at the supported paths.
        for name in ["trace.csv.zst", "trace.csv.xz", "trace.csv.bz2", "trace.ZST"] {
            let err = load(Path::new(name), &TraceOptions::default()).unwrap_err();
            match &err {
                TraceError::UnsupportedCompression { .. } => {}
                other => panic!("{name}: expected UnsupportedCompression, got {other:?}"),
            }
            let msg = err.to_string();
            assert!(msg.contains(".csv.gz"), "{name}: message must name the gz path: {msg}");
            assert!(msg.contains(".csv"), "{name}: message must name the plain path: {msg}");
        }
        // Plain .csv and .gz still route to real I/O (missing file).
        for name in ["missing.csv", "missing.csv.gz"] {
            assert!(matches!(
                load(Path::new(name), &TraceOptions::default()),
                Err(TraceError::Io(_))
            ));
        }
    }

    const AZURE_OK: &str = "\
vmId,tenantId,vmTypeId,priority,startTime,endTime,core,memory
vm1,t1,type_web,1,0.0,0.5,0.25,0.125
vm2,t1,type_web,1,-0.25,0.25,0.5,0.25
vm3,t2,type_db,0,0.125,,0.25,0.5
";

    #[test]
    fn azure_happy_path() {
        let t = parse_str(AZURE_OK, &TraceOptions { format: TraceFormat::Azure, ..Default::default() })
            .unwrap();
        assert_eq!(t.events.len(), 3);
        assert_eq!(t.stats.apps, 2);
        // vm2's negative start clamps to the window start.
        assert_eq!(t.events[0].submit_at, 0.0);
        assert_eq!(t.events[1].submit_at, 0.0);
        assert_eq!(t.events[2].submit_at, 0.125 * SECS_PER_DAY);
        // 0.25 of a 4-core server = 1000m; 0.125 of 8 GB = 1 GB.
        assert_eq!(t.events[0].cpu_milli, 1000);
        assert_eq!(t.events[0].mem_bytes, 1_000_000_000);
        // Durations: 0.5 days, 0.25 days (start clamped to 0), forever.
        assert_eq!(t.events[0].duration_secs, Some(0.5 * SECS_PER_DAY));
        assert_eq!(t.events[1].duration_secs, Some(0.25 * SECS_PER_DAY));
        assert_eq!(t.events[2].duration_secs, None);
    }

    #[test]
    fn azure_header_required_and_validated() {
        let missing = "vmId,tenantId\nvm1,t1\n";
        let opts = TraceOptions { format: TraceFormat::Azure, ..Default::default() };
        assert!(matches!(
            parse_str(missing, &opts),
            Err(TraceError::Malformed { line: 1, .. })
        ));
    }

    #[test]
    fn alibaba_header_tolerated_after_comments() {
        let with_header = "\
# comment block before the header
task_name,instance_num,job_name,task_type,status,start_time,end_time,plan_cpu,plan_mem
task_a,1,j_1,A,Terminated,100,160,50,0.5
";
        let strict = TraceOptions { mode: ErrorMode::Strict, ..Default::default() };
        let t = parse_str(with_header, &strict).unwrap();
        assert_eq!(t.events.len(), 1);
        assert_eq!(t.stats.skipped, 0);
    }

    #[test]
    fn azure_truncated_row_is_malformed_even_past_required_cols() {
        // endtime is the LAST column: a row truncated before it must be
        // malformed, not silently parsed as a forever-running VM.
        let truncated = "\
vmId,startTime,core,memory,endTime
vm1,0.0,0.25,0.125
";
        let strict = TraceOptions {
            format: TraceFormat::Azure,
            mode: ErrorMode::Strict,
            ..Default::default()
        };
        assert!(matches!(
            parse_str(truncated, &strict),
            Err(TraceError::Malformed { line: 2, .. })
        ));
        // An explicitly empty endtime field is still a valid service row.
        let empty_end = "\
vmId,startTime,core,memory,endTime
vm1,0.0,0.25,0.125,
";
        let t = parse_str(
            empty_end,
            &TraceOptions { format: TraceFormat::Azure, ..Default::default() },
        )
        .unwrap();
        assert_eq!(t.events[0].duration_secs, None);
    }

    #[test]
    fn azure_duplicate_vmid_detected() {
        let dup = "\
vmId,startTime,endTime,core,memory
vm1,0.0,0.5,0.25,0.125
vm1,0.1,0.6,0.25,0.125
";
        let opts = TraceOptions {
            format: TraceFormat::Azure,
            mode: ErrorMode::Strict,
            ..Default::default()
        };
        assert!(matches!(parse_str(dup, &opts), Err(TraceError::DuplicateTask { .. })));
    }

    const BORG_OK: &str = "\
0,,6251,0,,0,u1,2,9,0.025,0.05,0.001,0
1000000,,6251,1,,0,u1,2,9,0.025,0.05,0.001,0
2000000,,6251,0,m1,1,u1,2,9,0.025,0.05,0.001,0
3500000,,7000,0,,0,u2,0,1,0.5,0.25,0.002,0
9000000,,6251,0,m1,4,u1,2,9,,,,
";

    #[test]
    fn borg_happy_path() {
        let opts = TraceOptions { format: TraceFormat::Borg, ..Default::default() };
        let t = parse_str(BORG_OK, &opts).unwrap();
        // 3 SUBMIT rows become arrivals; SCHEDULE + FINISH are filtered.
        assert_eq!(t.stats.rows, 5);
        assert_eq!(t.stats.events, 3);
        assert_eq!(t.stats.filtered, 2);
        assert_eq!(t.stats.apps, 2, "jobs 6251 and 7000");
        // Microsecond times normalize to seconds from trace start.
        assert_eq!(t.events[0].submit_at, 0.0);
        assert_eq!(t.events[1].submit_at, 1.0);
        assert_eq!(t.events[2].submit_at, 3.5);
        // Fractions of the 4-core / 8 GB reference machine.
        assert_eq!(t.events[0].cpu_milli, 100);
        assert_eq!(t.events[0].mem_bytes, 400_000_000);
        assert_eq!(t.events[2].cpu_milli, 2000);
        // Borg rows carry no end time: tasks replay as services.
        assert!(t.events.iter().all(|e| e.duration_secs.is_none()));
        // Task ids pair job and index.
        assert_eq!(t.events[0].task_id, "6251#0");
        assert_eq!(t.events[1].task_id, "6251#1");
    }

    #[test]
    fn borg_malformed_rows() {
        for bad in [
            "0,,6251,0,,0,u1,2,9,0.025",        // too few columns
            "-5,,6251,0,,0,u1,2,9,0.025,0.05",  // negative time
            "0,,,0,,0,u1,2,9,0.025,0.05",       // empty job id
            "0,,6251,0,,x,u1,2,9,0.025,0.05",   // bad event_type
            "0,,6251,0,,0,u1,2,9,-0.1,0.05",    // negative cpu
        ] {
            let strict = TraceOptions {
                format: TraceFormat::Borg,
                mode: ErrorMode::Strict,
                ..Default::default()
            };
            assert!(
                matches!(parse_str(bad, &strict), Err(TraceError::Malformed { .. })),
                "{bad:?} should be malformed"
            );
        }
        // Duplicate SUBMIT for the same (job, task) is a duplicate task.
        let dup = "\
0,,6251,0,,0,u1,2,9,0.025,0.05
1000000,,6251,0,,0,u1,2,9,0.025,0.05
";
        let strict = TraceOptions {
            format: TraceFormat::Borg,
            mode: ErrorMode::Strict,
            ..Default::default()
        };
        assert!(matches!(parse_str(dup, &strict), Err(TraceError::DuplicateTask { .. })));
        let t = parse_str(
            dup,
            &TraceOptions { format: TraceFormat::Borg, ..Default::default() },
        )
        .unwrap();
        assert_eq!(t.stats.duplicates, 1);
        assert_eq!(t.events.len(), 1);
    }

    #[test]
    fn synthesis_is_deterministic_and_skew_preserving() {
        let a1 = synthesize_image("task_m1", 42);
        let a2 = synthesize_image("task_m1", 42);
        assert_eq!(a1, a2, "same (app, seed) ⇒ same manifest");
        let b = synthesize_image("task_r2", 42);
        assert_ne!(a1.image_ref(), b.image_ref());
        let other_seed = synthesize_image("task_m1", 7);
        assert_eq!(
            a1.image_ref(),
            other_seed.image_ref(),
            "image identity depends only on the app key"
        );
        // Layer stacks: at least a base + one app layer, nothing empty.
        for img in [&a1, &b] {
            assert!(img.layers.len() >= 2);
            assert!(img.total_size > Bytes::ZERO);
        }
    }

    #[test]
    fn synthesized_registry_shares_base_layers() {
        let t = parse_str(ALIBABA_OK, &TraceOptions::default()).unwrap();
        let reg = t.synthesize_registry();
        assert_eq!(reg.image_count(), 2);
        // Pods resolve against the synthesized registry.
        for (_, pod) in t.arrivals() {
            assert!(reg.manifest(&pod.image).is_ok(), "missing {}", pod.image);
        }
    }

    #[test]
    fn arrivals_preserve_trace_shape() {
        let t = parse_str(ALIBABA_OK, &TraceOptions::default()).unwrap();
        let arrivals = t.arrivals();
        assert_eq!(arrivals.len(), 4);
        assert_eq!(arrivals[0].0, 0.0);
        assert_eq!(arrivals[3].0, 10.0);
        // Same app ⇒ same image; instance expansion shares it too.
        assert_eq!(arrivals[0].1.image, arrivals[1].1.image);
        assert_eq!(arrivals[0].1.image, arrivals[3].1.image);
        assert_ne!(arrivals[0].1.image, arrivals[2].1.image);
        assert_eq!(arrivals[2].1.duration_secs, Some(0.0), "zero-duration task");
    }

    #[test]
    fn streaming_source_matches_buffered_arrivals() {
        // The buffered Trace::arrivals and a pulled TraceSource must
        // produce identical (offset, pod) streams — the unit-level core
        // of the differential suite in tests/streaming_pipeline.rs.
        let opts = TraceOptions::default();
        let buffered = parse_str(ALIBABA_OK, &opts).unwrap().arrivals();
        let mut reader = Cursor::new(ALIBABA_OK.as_bytes().to_vec());
        let summary = scan(&mut reader, &opts).unwrap();
        reader.set_position(0);
        let mut source = TraceSource::new(&mut reader, &opts, &summary);
        let mut streamed = Vec::new();
        while let Some(pair) = source.next_arrival() {
            streamed.push(pair);
        }
        assert!(source.take_error().is_none());
        assert_eq!(streamed.len(), buffered.len());
        for ((o1, p1), (o2, p2)) in buffered.iter().zip(&streamed) {
            assert_eq!(o1, o2);
            assert_eq!(p1.id, p2.id);
            assert_eq!(p1.image, p2.image);
            assert_eq!(p1.requests, p2.requests);
            assert_eq!(p1.duration_secs, p2.duration_secs);
        }
    }

    #[test]
    fn image_names_sanitize_without_collisions() {
        let (n1, _) = image_name_for_app("task/We ird:key");
        assert!(n1.starts_with("trace/task-we-ird-key-"));
        let (n2, _) = image_name_for_app("task/We ird!key");
        assert_ne!(n1, n2, "hash suffix disambiguates sanitized collisions");
    }
}
