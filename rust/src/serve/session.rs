//! The serve session: one live scheduling conversation between a
//! protocol stream and the engine.
//!
//! A [`Session`] wires a [`StreamSource`] into an open
//! [`Simulation`] stream (see [`Simulation::open_stream`]) and then, per
//! input line: validates it, advances the engine to the event's
//! timestamp with [`Simulation::step_until`], injects the event (pods
//! through the arrival pipeline, node/registry lifecycle through
//! [`Simulation::inject_event`]), steps again to the same frontier, and
//! drains any [`crate::sim::DecisionDetail`]s the scheduling cycle
//! produced into NDJSON decision lines. Because arrivals are the last
//! event class at any timestamp and the protocol enforces non-decreasing
//! `t`, the popped event sequence — and therefore every decision and the
//! final report — is byte-identical to a batch replay of the same
//! arrivals (`docs/ARCHITECTURE.md`, "Serve mode"; enforced end-to-end
//! by [`crate::serve::run_shadow`]).
//!
//! Wall-clock time is injected: the session never reads a clock itself
//! (the determinism lint's R2 bans ambient time outside `main.rs`), it
//! calls the `FnMut() -> u64` microsecond counter its caller supplies.
//! The CLI passes an `Instant`-based counter; shadow runs and tests pass
//! `|| 0`, pinning `latency_us` to 0 so streams stay byte-comparable.

use super::codec;
use super::protocol::{error_to_json, InEvent, ServeError};
use crate::cluster::{NodeId, Pod, PodBuilder, Resources};
use crate::exp::export;
use crate::registry::ImageRef;
use crate::sim::{ErrorMode, EventPayload, SimReport, Simulation, StreamHandle, StreamSource};
use crate::util::units::{Bytes, MilliCpu};

/// Counters a [`Session`] accumulates over its lifetime (reported in the
/// summary line and by the shadow differential).
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionStats {
    /// Protocol events accepted (pods + lifecycle + shutdown).
    pub events: usize,
    /// Pods submitted through the arrival pipeline.
    pub pods: usize,
    /// Lines skipped in lenient mode (malformed or out-of-order).
    pub skipped: usize,
    /// Decision lines emitted.
    pub decisions: usize,
}

/// A live serve session over a mutably borrowed [`Simulation`] (see the
/// module docs). Construct with [`Session::new`] (which opens the
/// engine stream), feed it lines with [`Session::handle_line`] or pods
/// directly with [`Session::submit_pod`], and end it exactly once with
/// [`Session::finish`]. The simulation should be freshly built with a
/// `shards = 1` config — incremental stepping is the sequential event
/// loop cut at the arrival boundary.
pub struct Session<'a> {
    sim: &'a mut Simulation,
    handle: StreamHandle,
    builder: PodBuilder,
    t0: f64,
    last_t: f64,
    mode: ErrorMode,
    clock_us: Box<dyn FnMut() -> u64 + 'a>,
    /// Running session counters.
    pub stats: SessionStats,
}

impl<'a> Session<'a> {
    /// Open a session: switch on per-bind decision capture, create the
    /// stream channel, and open the engine stream. `mode` governs bad
    /// input lines (strict abort vs lenient skip-and-count, mirroring
    /// the trace importers); `clock_us` is the wall-clock microsecond
    /// counter used only for the emitted `latency_us` field.
    pub fn new(
        sim: &'a mut Simulation,
        mode: ErrorMode,
        clock_us: Box<dyn FnMut() -> u64 + 'a>,
    ) -> Session<'a> {
        sim.collect_decisions(true);
        let (source, handle) = StreamSource::channel();
        let t0 = sim.clock.now();
        sim.open_stream(Box::new(source));
        Session {
            sim,
            handle,
            builder: PodBuilder::new(),
            t0,
            last_t: t0,
            mode,
            clock_us,
            stats: SessionStats::default(),
        }
    }

    /// Process one input line: decode, validate (monotone `t`, known
    /// node ids, image present in the registry catalog), apply, and
    /// append any resulting decision lines to `out`. Lenient-mode
    /// rejections append a `{"type":"error",...}` object to `diag` (kept
    /// separate so stdout can stay a pure decision stream) and return
    /// `Ok(false)`; strict mode returns the error. `Ok(true)` means a
    /// `shutdown` event was accepted — call [`Session::finish`].
    pub fn handle_line(
        &mut self,
        line: &str,
        lineno: usize,
        out: &mut Vec<String>,
        diag: &mut Vec<String>,
    ) -> Result<bool, ServeError> {
        let ev = match codec::decode_line(line, lineno) {
            Ok(None) => return Ok(false),
            Ok(Some(ev)) => ev,
            Err(e) => return self.reject(e, diag),
        };
        // Semantic checks the stateless codec cannot make.
        if let Some(t) = ev.t() {
            if t < self.last_t {
                let e = ServeError::OutOfOrder { line: lineno, t, last: self.last_t };
                return self.reject(e, diag);
            }
        }
        match &ev {
            InEvent::NodeDrain { node, .. } | InEvent::NodeCrash { node, .. } => {
                let fleet = self.sim.state.node_count();
                if (*node as usize) >= fleet {
                    let reason = format!("unknown node id {node} (fleet has {fleet} nodes)");
                    return self.reject(ServeError::Malformed { line: lineno, reason }, diag);
                }
            }
            InEvent::Pod { image, .. } => {
                if self.sim.registry.manifest(&ImageRef::parse(image)).is_err() {
                    let reason = format!("image {image:?} not in the registry catalog");
                    return self.reject(ServeError::Malformed { line: lineno, reason }, diag);
                }
            }
            _ => {}
        }
        self.stats.events += 1;
        Ok(self.apply(ev, out))
    }

    /// Apply one already-validated event (the shared tail of
    /// [`Session::handle_line`]; callers that construct [`InEvent`]s
    /// programmatically can use it directly). Returns true for
    /// `shutdown`.
    pub fn apply(&mut self, ev: InEvent, out: &mut Vec<String>) -> bool {
        match ev {
            InEvent::Pod { t, name, image, cpu_milli, mem_mb, duration_secs } => {
                let requests = Resources::new(MilliCpu(cpu_milli), Bytes::from_mb(mem_mb));
                let mut pod = self.builder.build(&image, requests);
                if let Some(d) = duration_secs {
                    pod = pod.with_duration(d);
                }
                if let Some(n) = name {
                    pod.name = n;
                }
                self.submit_pod(t, pod, out);
                false
            }
            InEvent::NodeJoin { t } => {
                self.lifecycle(t, EventPayload::NodeJoin, out);
                false
            }
            InEvent::NodeDrain { t, node } => {
                self.lifecycle(t, EventPayload::NodeDrain { node: NodeId(node) }, out);
                false
            }
            InEvent::NodeCrash { t, node } => {
                self.lifecycle(t, EventPayload::NodeCrash { node: NodeId(node) }, out);
                false
            }
            InEvent::Outage { t, secs } => {
                self.lifecycle(t, EventPayload::RegistryOutageStart { until: t + secs }, out);
                false
            }
            InEvent::Shutdown { t } => {
                if let Some(t) = t {
                    let start = (self.clock_us)();
                    self.sim.step_until(t);
                    let us = (self.clock_us)().saturating_sub(start);
                    self.drain_decisions(us, out);
                    self.last_t = t;
                }
                true
            }
        }
    }

    /// Submit one pod at absolute virtual time `t` — the serve half of
    /// the arrival pipeline, also driven directly by the shadow replay.
    /// Steps the engine to `t`, pushes the arrival (offset `t - t0`
    /// under the [`crate::sim::ArrivalSource`] contract), pumps the
    /// stream, and steps again so the arrival — the last event class at
    /// `t` — pops exactly where a batch replay would pop it. Decision
    /// lines for every bind the steps produced (this pod's, and any
    /// parked pod released by the same events) are appended to `out`
    /// with the measured step latency.
    pub fn submit_pod(&mut self, t: f64, pod: Pod, out: &mut Vec<String>) {
        let t = if t.is_finite() { t.max(self.last_t) } else { self.last_t };
        let start = (self.clock_us)();
        self.sim.step_until(t);
        self.handle.push(t - self.t0, pod);
        self.sim.pump_stream();
        self.sim.step_until(t);
        let us = (self.clock_us)().saturating_sub(start);
        self.drain_decisions(us, out);
        self.last_t = t;
        self.stats.pods += 1;
    }

    /// End the session: close the engine stream (draining every queued
    /// event to quiescence — the same tail a batch run executes), append
    /// the remaining decision lines and the summary line to `out`, and
    /// return the full [`SimReport`]. Call exactly once, after EOF or an
    /// accepted `shutdown` event.
    pub fn finish(&mut self, out: &mut Vec<String>) -> SimReport {
        let start = (self.clock_us)();
        let report = self.sim.close_stream();
        let us = (self.clock_us)().saturating_sub(start);
        self.drain_decisions(us, out);
        let summary = export::serve_summary_to_json(
            &report,
            self.stats.decisions,
            self.stats.skipped,
            self.sim.clock.now(),
        );
        out.push(summary.to_string());
        report
    }

    /// Advance to `t`, inject a lifecycle event, and advance again —
    /// node churn and outages share the arrival path's step discipline.
    /// Crashes resubmit lost pods, so these steps can bind pods and
    /// emit decisions too.
    fn lifecycle(&mut self, t: f64, payload: EventPayload, out: &mut Vec<String>) {
        let start = (self.clock_us)();
        self.sim.step_until(t);
        self.sim.inject_event(t, payload);
        self.sim.step_until(t);
        let us = (self.clock_us)().saturating_sub(start);
        self.drain_decisions(us, out);
        self.last_t = t;
    }

    /// Route a bad line by mode: strict aborts with the error, lenient
    /// counts it and renders a diagnostic object.
    fn reject(&mut self, e: ServeError, diag: &mut Vec<String>) -> Result<bool, ServeError> {
        match self.mode {
            ErrorMode::Strict => Err(e),
            ErrorMode::Lenient => {
                self.stats.skipped += 1;
                diag.push(error_to_json(&e).to_string());
                Ok(false)
            }
        }
    }

    /// Render and append every decision captured since the last drain.
    fn drain_decisions(&mut self, latency_us: u64, out: &mut Vec<String>) {
        for d in self.sim.take_decisions() {
            out.push(export::decision_to_json(&d, latency_us).to_string());
            self.stats.decisions += 1;
        }
    }
}
