"""L2 correctness: the full scoring pipeline (model.score_pipeline, which
routes Eq. 2 through the Pallas kernel) vs. the pure-jnp oracle, plus
golden tests of the paper's formulas mirroring the rust unit tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import NEG_MASK, score_pipeline_ref
from compile.model import VARIANTS, example_args, score_pipeline

PAPER_PARAMS = np.array([2.0, 0.5, 10.0, 0.6, 0.16], dtype=np.float32)


def random_inputs(seed, n, l, feasible_density=1.0):
    r = np.random.default_rng(seed)
    return dict(
        present=(r.random((n, l)) < 0.3).astype(np.float32),
        req=(r.random(l) < 0.2).astype(np.float32),
        sizes_mb=(r.random(l) * 300).astype(np.float32),
        cpu_used=(r.random(n) * 4000).astype(np.float32),
        cpu_cap=np.full(n, 4000.0, dtype=np.float32),
        mem_used=(r.random(n) * 4e9).astype(np.float32),
        mem_cap=np.full(n, 4e9, dtype=np.float32),
        k8s_score=(r.random(n) * 800).astype(np.float32),
        feasible=(r.random(n) < feasible_density).astype(np.float32),
        params=PAPER_PARAMS,
    )


def as_jnp(d):
    return {k: jnp.asarray(v) for k, v in d.items()}


def run_both(d):
    args = [
        d["present"], d["req"], d["sizes_mb"], d["cpu_used"], d["cpu_cap"],
        d["mem_used"], d["mem_cap"], d["k8s_score"], d["feasible"], d["params"],
    ]
    return score_pipeline(*args), score_pipeline_ref(*args)


@pytest.mark.parametrize("name,n,l", list(VARIANTS))
def test_model_matches_ref_at_variant_shapes(name, n, l):
    d = as_jnp(random_inputs(7, n, l))
    (f1, l1, o1, b1), (f2, l2, o2, b2) = run_both(d)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert int(b1) == int(b2)


def test_golden_two_nodes():
    """Mirror of rust sched::scoring::tests::native_scorer_matches_hand_math."""
    present = np.zeros((2, 4), dtype=np.float32)
    present[0, 1] = 1.0
    present[0, 2] = 1.0
    d = dict(
        present=present,
        req=np.array([1, 1, 0, 1], dtype=np.float32),
        sizes_mb=np.array([10, 20, 30, 40], dtype=np.float32),
        cpu_used=np.array([1000, 1000], dtype=np.float32),
        cpu_cap=np.array([4000, 4000], dtype=np.float32),
        mem_used=np.array([1e9, 1e9], dtype=np.float32),
        mem_cap=np.array([4e9, 4e9], dtype=np.float32),
        k8s_score=np.array([50.0, 60.0], dtype=np.float32),
        feasible=np.ones(2, dtype=np.float32),
        params=PAPER_PARAMS,
    )
    final, layer, omega, best = score_pipeline(*[jnp.asarray(v) for v in (
        d["present"], d["req"], d["sizes_mb"], d["cpu_used"], d["cpu_cap"],
        d["mem_used"], d["mem_cap"], d["k8s_score"], d["feasible"], d["params"],
    )])
    expected_layer0 = 20.0 / 70.0 * 100.0
    np.testing.assert_allclose(float(layer[0]), expected_layer0, rtol=1e-5)
    assert float(omega[0]) == 2.0  # gate passes: 20MB > 10, cpu 25% < 60%, std 0
    assert float(omega[1]) == 0.5  # no shared bytes
    np.testing.assert_allclose(float(final[0]), 2.0 * expected_layer0 + 50.0, rtol=1e-5)
    np.testing.assert_allclose(float(final[1]), 60.0, rtol=1e-5)
    assert int(best) == 0


def test_infeasible_nodes_masked():
    d = as_jnp(random_inputs(3, 16, 256))
    feasible = np.zeros(16, dtype=np.float32)
    feasible[5] = 1.0
    d["feasible"] = jnp.asarray(feasible)
    (final, _, _, best), _ = run_both(d)
    assert int(best) == 5
    final = np.asarray(final)
    assert np.all(final[np.arange(16) != 5] == NEG_MASK)


def test_gate_thresholds_exact():
    """Iverson bracket boundaries: strict inequalities per Eq. 13."""
    n, l = 16, 256
    d = random_inputs(0, n, l)
    # Node 0: exactly at h_cpu (0.6*4000=2400) -> gate must FAIL (strict <).
    d["present"][:] = 0.0
    d["present"][0, :8] = 1.0
    d["present"][1, :8] = 1.0
    d["req"][:] = 0.0
    d["req"][:8] = 1.0
    d["sizes_mb"][:8] = 10.0  # shared = 80 MB > h_size
    d["cpu_used"][:] = 0.0
    d["mem_used"][:] = 0.0
    d["cpu_used"][0] = 2400.0
    d["mem_used"][0] = 2.4e9
    d["cpu_used"][1] = 2399.0  # just under
    d["mem_used"][1] = 2.399e9
    d["feasible"][:] = 1.0
    (_, _, omega, _), (_, _, omega_ref, _) = run_both(as_jnp(d))
    omega = np.asarray(omega)
    assert omega[0] == 0.5, "cpu_frac == h_cpu must fail the strict inequality"
    assert omega[1] == 2.0
    np.testing.assert_array_equal(omega, np.asarray(omega_ref))


def test_zero_total_size_no_nan():
    d = as_jnp(random_inputs(11, 16, 256))
    d["req"] = jnp.zeros(256, dtype=jnp.float32)
    (final, layer, _, _), _ = run_both(d)
    assert not np.any(np.isnan(np.asarray(final)))
    np.testing.assert_array_equal(np.asarray(layer), np.zeros(16))


def test_argmax_first_tie():
    d = random_inputs(0, 16, 256)
    d["present"][:] = 0.0
    d["req"][:] = 0.0
    d["k8s_score"][:] = 42.0  # all tied
    d["feasible"][:] = 1.0
    (_, _, _, best), (_, _, _, best_ref) = run_both(as_jnp(d))
    assert int(best) == 0 == int(best_ref)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), density=st.floats(0.05, 1.0))
def test_hypothesis_model_vs_ref(seed, density):
    d = as_jnp(random_inputs(seed, 16, 256, feasible_density=density))
    (f1, l1, o1, b1), (f2, l2, o2, b2) = run_both(d)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert int(b1) == int(b2)


def test_example_args_shapes():
    args = example_args(16, 256)
    assert args[0].shape == (16, 256)
    assert args[-1].shape == (5,)
    assert all(a.dtype == jnp.float32 for a in args)
