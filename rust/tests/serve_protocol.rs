//! Serve-protocol conformance tests: NDJSON round-trips for every input
//! event variant, strict-vs-lenient error handling with line numbers,
//! out-of-order rejection, decision/summary line shapes, and the
//! in-process shadow differential holding the serve path byte-identical
//! to the batch `scale --trace` replay — the PR 9 acceptance criteria.

use lrsched::exp::common;
use lrsched::registry::Registry;
use lrsched::serve::{decode_line, encode_line, run_shadow, InEvent, ServeError, Session};
use lrsched::sim::{ErrorMode, SimConfig, Simulation, TraceOptions};
use lrsched::util::json;
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// The config every serve test uses — `scale --trace`'s defaults, which
/// `lrsched serve` hardcodes to keep shadow mode byte-comparable.
fn serve_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(0.3);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.retry_backoff_secs = 5.0;
    cfg.snapshot_every = 1000;
    cfg
}

fn session_sim(nodes: usize) -> Simulation {
    Simulation::new(common::scale_nodes(nodes), Registry::with_corpus(), serve_cfg())
}

// ---------------------------------------------------------------------
// Codec round-trips
// ---------------------------------------------------------------------

fn every_variant() -> Vec<InEvent> {
    vec![
        InEvent::Pod {
            t: 0.0,
            name: None,
            image: "nginx:1.25".into(),
            cpu_milli: 100,
            mem_mb: 128.0,
            duration_secs: None,
        },
        InEvent::Pod {
            t: 1.5,
            name: Some("web-0".into()),
            image: "redis:7.2".into(),
            cpu_milli: 500,
            mem_mb: 512.0,
            duration_secs: Some(30.0),
        },
        InEvent::NodeJoin { t: 2.0 },
        InEvent::NodeDrain { t: 3.0, node: 1 },
        InEvent::NodeCrash { t: 4.0, node: 2 },
        InEvent::Outage { t: 5.0, secs: 10.0 },
        InEvent::Shutdown { t: None },
        InEvent::Shutdown { t: Some(60.0) },
    ]
}

#[test]
fn every_variant_round_trips_through_the_codec() {
    for ev in every_variant() {
        let line = encode_line(&ev);
        let back = decode_line(&line, 1)
            .unwrap_or_else(|e| panic!("decode({line:?}) failed: {e}"))
            .expect("non-blank line decodes to an event");
        assert_eq!(back, ev, "round-trip mismatch for {line}");
        // Encoding is canonical: a second trip is byte-stable.
        assert_eq!(encode_line(&back), line);
    }
}

#[test]
fn blank_lines_and_comments_are_skipped() {
    for line in ["", "   ", "\t", "# a comment", "  # indented comment"] {
        assert_eq!(decode_line(line, 7), Ok(None), "line {line:?} should be skipped");
    }
}

#[test]
fn defaults_are_applied_to_minimal_pod_lines() {
    let ev = decode_line(r#"{"event":"pod","t":0,"image":"nginx:1.25"}"#, 1)
        .unwrap()
        .unwrap();
    match ev {
        InEvent::Pod { cpu_milli, mem_mb, name, duration_secs, .. } => {
            assert_eq!(cpu_milli, 100);
            assert_eq!(mem_mb, 128.0);
            assert_eq!(name, None);
            assert_eq!(duration_secs, None);
        }
        other => panic!("expected a pod event, got {other:?}"),
    }
}

#[test]
fn malformed_lines_carry_their_line_number() {
    let cases: &[&str] = &[
        "not json at all",
        "{\"event\":\"pod\",\"t\":0}",                      // missing image
        "{\"event\":\"warp\",\"t\":0}",                     // unknown kind
        "{\"event\":\"pod\",\"t\":-1,\"image\":\"a\"}",     // negative t
        "{\"event\":\"pod\",\"t\":0,\"image\":\"\"}",       // empty image
        "{\"event\":\"pod\",\"t\":0,\"image\":\"a\",\"cpus\":2}", // unknown key
        "{\"event\":\"outage\",\"t\":0,\"secs\":0}",        // non-positive window
        "{\"event\":\"node-drain\",\"t\":0}",               // missing node
        "{\"event\":\"node-drain\",\"t\":0,\"node\":-3}",   // negative node
        "[1,2,3]",                                          // not an object
    ];
    for (i, line) in cases.iter().enumerate() {
        let lineno = i + 10;
        match decode_line(line, lineno) {
            Err(ServeError::Malformed { line: l, .. }) => {
                assert_eq!(l, lineno, "wrong line number for {line}")
            }
            other => panic!("expected Malformed for {line}, got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------
// Session semantics: strict vs lenient, ordering, validation
// ---------------------------------------------------------------------

#[test]
fn strict_session_aborts_on_first_bad_line_with_its_number() {
    let mut sim = session_sim(4);
    let mut session = Session::new(&mut sim, ErrorMode::Strict, Box::new(|| 0_u64));
    let (mut out, mut diag) = (Vec::new(), Vec::new());
    session
        .handle_line(r#"{"event":"pod","t":0,"image":"nginx:1.25"}"#, 1, &mut out, &mut diag)
        .expect("good line accepted");
    let err = session
        .handle_line("garbage", 2, &mut out, &mut diag)
        .expect_err("strict mode rejects");
    match err {
        ServeError::Malformed { line, .. } => assert_eq!(line, 2),
        other => panic!("expected Malformed, got {other:?}"),
    }
    assert!(diag.is_empty(), "strict mode emits no diagnostics");
}

#[test]
fn lenient_session_skips_counts_and_diagnoses_bad_lines() {
    let mut sim = session_sim(4);
    let mut session = Session::new(&mut sim, ErrorMode::Lenient, Box::new(|| 0_u64));
    let (mut out, mut diag) = (Vec::new(), Vec::new());
    let lines = [
        r#"{"event":"pod","t":0,"image":"nginx:1.25"}"#,
        "garbage",
        r#"{"event":"pod","t":1,"image":"no-such-image:0.0"}"#,
        r#"{"event":"node-crash","t":1,"node":999}"#,
        r#"{"event":"pod","t":2,"image":"redis:7.2"}"#,
    ];
    for (i, line) in lines.iter().enumerate() {
        let shutdown = session
            .handle_line(line, i + 1, &mut out, &mut diag)
            .expect("lenient mode never errors");
        assert!(!shutdown);
    }
    assert_eq!(session.stats.skipped, 3);
    assert_eq!(session.stats.pods, 2);
    assert_eq!(diag.len(), 3, "one diagnostic object per skipped line");
    for d in &diag {
        let j = json::parse(d).expect("diagnostics are valid JSON");
        assert_eq!(j.get("type").and_then(|v| v.as_str()), Some("error"));
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("malformed"));
    }
    let report = session.finish(&mut out);
    assert_eq!(report.submitted, 2);
    assert!(report.accounting_balanced());
}

#[test]
fn out_of_order_timestamps_are_rejected_in_both_modes() {
    // Strict: abort with line number, t, and the frontier.
    let mut sim = session_sim(4);
    let mut session = Session::new(&mut sim, ErrorMode::Strict, Box::new(|| 0_u64));
    let (mut out, mut diag) = (Vec::new(), Vec::new());
    session
        .handle_line(r#"{"event":"pod","t":5,"image":"nginx:1.25"}"#, 1, &mut out, &mut diag)
        .unwrap();
    let err = session
        .handle_line(r#"{"event":"pod","t":4,"image":"nginx:1.25"}"#, 2, &mut out, &mut diag)
        .expect_err("time went backwards");
    match err {
        ServeError::OutOfOrder { line, t, last } => {
            assert_eq!(line, 2);
            assert_eq!(t, 4.0);
            assert_eq!(last, 5.0);
        }
        other => panic!("expected OutOfOrder, got {other:?}"),
    }

    // Lenient: skip, count, diagnose — later in-order lines still land.
    let mut sim = session_sim(4);
    let mut session = Session::new(&mut sim, ErrorMode::Lenient, Box::new(|| 0_u64));
    let (mut out, mut diag) = (Vec::new(), Vec::new());
    session
        .handle_line(r#"{"event":"pod","t":5,"image":"nginx:1.25"}"#, 1, &mut out, &mut diag)
        .unwrap();
    session
        .handle_line(r#"{"event":"pod","t":4,"image":"nginx:1.25"}"#, 2, &mut out, &mut diag)
        .unwrap();
    session
        .handle_line(r#"{"event":"pod","t":6,"image":"nginx:1.25"}"#, 3, &mut out, &mut diag)
        .unwrap();
    assert_eq!(session.stats.skipped, 1);
    assert_eq!(session.stats.pods, 2);
    let j = json::parse(&diag[0]).unwrap();
    assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("out-of-order"));
    assert_eq!(j.get("line").and_then(|v| v.as_i64()), Some(2));
}

// ---------------------------------------------------------------------
// Output line shapes
// ---------------------------------------------------------------------

#[test]
fn decision_and_summary_lines_have_the_documented_shape() {
    let mut sim = session_sim(4);
    let mut session = Session::new(&mut sim, ErrorMode::Strict, Box::new(|| 0_u64));
    let (mut out, mut diag) = (Vec::new(), Vec::new());
    session
        .handle_line(
            r#"{"event":"pod","t":0,"name":"web-0","image":"nginx:1.25","cpu_milli":500,"mem_mb":512}"#,
            1,
            &mut out,
            &mut diag,
        )
        .unwrap();
    assert_eq!(out.len(), 1, "one decision per pod");
    let d = json::parse(&out[0]).expect("decision line is valid JSON");
    assert_eq!(d.get("type").and_then(|v| v.as_str()), Some("decision"));
    assert_eq!(d.get("pod_name").and_then(|v| v.as_str()), Some("web-0"));
    assert_eq!(d.get("image").and_then(|v| v.as_str()), Some("nginx:1.25"));
    assert_eq!(d.get("latency_us").and_then(|v| v.as_i64()), Some(0));
    for key in [
        "t", "pod", "node", "node_id", "final_score", "layer_score", "k8s_score", "omega",
        "wan_bytes", "p2p_bytes", "est_secs",
    ] {
        assert!(d.get(key).is_some(), "decision line missing {key:?}: {}", out[0]);
    }
    let breakdown = d.get("breakdown").and_then(|v| v.as_arr()).expect("breakdown array");
    assert!(!breakdown.is_empty(), "per-plugin breakdown is populated");
    for entry in breakdown {
        assert!(entry.get("plugin").and_then(|v| v.as_str()).is_some());
        assert!(entry.get("score").and_then(|v| v.as_f64()).is_some());
    }
    // Canonical rendering: parse → re-encode is byte-stable.
    assert_eq!(d.to_string(), out[0]);

    let report = session.finish(&mut out);
    assert_eq!(report.submitted, 1);
    let s = json::parse(out.last().unwrap()).expect("summary line is valid JSON");
    assert_eq!(s.get("type").and_then(|v| v.as_str()), Some("summary"));
    assert_eq!(s.get("submitted").and_then(|v| v.as_i64()), Some(1));
    assert_eq!(s.get("decisions").and_then(|v| v.as_i64()), Some(1));
    assert_eq!(s.get("skipped_lines").and_then(|v| v.as_i64()), Some(0));
    for key in ["started", "failed_pulls", "unschedulable", "lost_to_crash", "wan_bytes", "p2p_bytes", "cache_hit_rate", "virtual_secs"]
    {
        assert!(s.get(key).is_some(), "summary line missing {key:?}");
    }
}

#[test]
fn shutdown_event_ends_the_session_like_eof() {
    let mut sim = session_sim(4);
    let mut session = Session::new(&mut sim, ErrorMode::Strict, Box::new(|| 0_u64));
    let (mut out, mut diag) = (Vec::new(), Vec::new());
    session
        .handle_line(r#"{"event":"pod","t":0,"image":"nginx:1.25","duration_secs":5}"#, 1, &mut out, &mut diag)
        .unwrap();
    let shutdown = session
        .handle_line(r#"{"event":"shutdown"}"#, 2, &mut out, &mut diag)
        .unwrap();
    assert!(shutdown, "shutdown event signals end of session");
    let report = session.finish(&mut out);
    assert_eq!(report.submitted, 1);
    assert!(report.accounting_balanced());
    assert!(out.last().unwrap().contains("\"type\":\"summary\""));
}

#[test]
fn lifecycle_events_drive_the_engine() {
    let mut sim = session_sim(4);
    let mut session = Session::new(&mut sim, ErrorMode::Strict, Box::new(|| 0_u64));
    let (mut out, mut diag) = (Vec::new(), Vec::new());
    let lines = [
        r#"{"event":"pod","t":0,"image":"nginx:1.25","duration_secs":600}"#,
        r#"{"event":"node-join","t":10}"#,
        r#"{"event":"pod","t":20,"image":"redis:7.2","duration_secs":600}"#,
        r#"{"event":"node-crash","t":30,"node":0}"#,
        r#"{"event":"outage","t":40,"secs":5}"#,
        r#"{"event":"pod","t":50,"image":"nginx:1.25","duration_secs":600}"#,
    ];
    for (i, line) in lines.iter().enumerate() {
        session.handle_line(line, i + 1, &mut out, &mut diag).unwrap();
    }
    let report = session.finish(&mut out);
    // Crash resubmission may rebind pods, so only the identity is exact.
    assert_eq!(report.submitted, 3);
    assert!(report.accounting_balanced());
    assert!(session.stats.decisions >= 3, "each pod got at least one decision");
}

// ---------------------------------------------------------------------
// The shadow differential (also run, via the CLI, in CI)
// ---------------------------------------------------------------------

#[test]
fn shadow_holds_serve_byte_identical_to_batch_replay() {
    let opts = TraceOptions::default();
    let lines = run_shadow(&fixture("alibaba_mini.csv"), &opts, 8, 64.0, &serve_cfg())
        .expect("shadow differential passes on the bundled fixture");
    assert!(lines.len() > 1, "decision stream plus summary");
    for line in &lines[..lines.len() - 1] {
        assert!(line.contains("\"type\":\"decision\""), "unexpected line {line}");
    }
    assert!(lines.last().unwrap().contains("\"type\":\"summary\""));
    // Determinism: a second shadow run reproduces the stream exactly.
    let again = run_shadow(&fixture("alibaba_mini.csv"), &opts, 8, 64.0, &serve_cfg())
        .expect("second shadow run passes");
    assert_eq!(lines, again);
}
