//! Minimal JSON value, parser, and serializer.
//!
//! `serde`/`serde_json` are not available in the vendored dependency set, and
//! the paper's registry metadata cache is literally a `cache.json` file
//! (§V-1, Listing 1), so the repo carries its own small, well-tested JSON
//! implementation. Supports the full JSON grammar (RFC 8259) minus exotic
//! number forms beyond f64 precision; numbers are kept as f64 with an i64
//! fast path for integers, which is lossless for layer sizes (< 2^53 bytes).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable key order), which keeps `cache.json` diffs clean.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// Integer fast path (layer sizes, counts). `Num` is used otherwise.
    Int(i64),
    /// Floating-point number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty JSON object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup; None for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// This value as a string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// This value as an integer (accepts integral floats).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    /// This value as a float (accepts integers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    /// This value as a boolean, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// This value as an array slice, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// This value as an object map, if it is one.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation (human-readable `cache.json`).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(f) => {
                if f.is_finite() {
                    // Shortest roundtrip form; Rust's f64 Display is exact.
                    out.push_str(&format!("{f}"));
                    if f.fract() == 0.0 && !out.ends_with(|c: char| !c.is_ascii_digit() || c == '.') {
                        // "3" would reparse as Int; keep it — Int/Num distinction
                        // is internal and as_f64 handles both.
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// Byte offset of the failure in the input.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("invalid surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                            };
                            s.push(c);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str, so valid UTF-8).
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += ch_len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "d"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("d"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" \\ é""#).unwrap().as_str(),
            Some("a\nb\t\"c\" \\ é")
        );
        // surrogate pair: 😀 U+1F600
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        assert_eq!(parse("\"héllo 世界\"").unwrap().as_str(), Some("héllo 世界"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"layers":[{"layer":"sha256:abc","size":1048576}],"name":"redis","tag":"7.0"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn deterministic_key_order() {
        let mut o = Json::obj();
        o.set("z", Json::Int(1)).set("a", Json::Int(2));
        assert_eq!(o.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn errors_have_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert_eq!(e.offset, 6);
        assert!(parse("[1,]").is_err());
        assert!(parse("01abc").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }

    #[test]
    fn int_boundaries() {
        assert_eq!(parse("9007199254740993").unwrap().as_i64(), Some(9007199254740993));
        assert_eq!(parse(&i64::MAX.to_string()).unwrap(), Json::Int(i64::MAX));
    }

    #[test]
    fn large_int_overflow_falls_to_f64() {
        let v = parse("99999999999999999999999999").unwrap();
        assert!(matches!(v, Json::Num(_)));
    }
}
