//! Cluster event records — the audit stream the API server emits as pods
//! move through the scheduling → pull → run lifecycle. Experiments consume
//! these to build per-step tables (paper Table I).

use super::node::NodeId;
use super::pod::PodId;
use crate::util::units::Bytes;

/// Sentinel pod id for node-scoped records (evictions, node lifecycle,
/// registry outages) — shared by the engine and the sharded event lanes.
pub const NODE_SCOPE: PodId = PodId(u64::MAX);

/// What happened to a pod (or node — node-scoped records use a sentinel
/// pod id) at one instant of the lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Pod submitted to the API server.
    Submitted,
    /// Scheduler picked a node (with the winning score).
    Scheduled {
        /// Chosen node.
        node: NodeId,
        /// Winning final score.
        score: f64,
    },
    /// Scheduler found no feasible node.
    Unschedulable {
        /// Why (plugin rejections or retry bookkeeping).
        reason: String,
    },
    /// Layer pull started on the node.
    PullStarted {
        /// Pulling node.
        node: NodeId,
        /// Bytes this pull transfers (new layers only).
        bytes: Bytes,
        /// Number of new layers.
        layers: usize,
    },
    /// Part of a pull is served by peer edge nodes over the LAN instead
    /// of the WAN registry link (P2P layer sharing; emitted right after
    /// `PullStarted` when any layer found a seeder).
    PeerFetch {
        /// Downloading node.
        node: NodeId,
        /// Bytes fetched from peers.
        bytes: Bytes,
        /// Number of peer-served layers.
        layers: usize,
    },
    /// All layers present; container starting.
    PullFinished {
        /// Pulling node.
        node: NodeId,
        /// Wall (virtual) seconds from pull start.
        secs: f64,
    },
    /// Container running.
    Started {
        /// Hosting node.
        node: NodeId,
    },
    /// Image layers evicted from a node under disk pressure.
    Evicted {
        /// Node under pressure.
        node: NodeId,
        /// Bytes freed.
        bytes: Bytes,
    },
    /// Layers warmed onto a node at bind time by the prefetch-on-intent
    /// cache policy (node-scoped; no pod pull is charged for them).
    Prefetched {
        /// Node the layers were warmed onto.
        node: NodeId,
        /// Bytes installed ahead of need.
        bytes: Bytes,
        /// Number of layers installed.
        layers: usize,
    },
    /// A node joined the cluster (empty layer cache).
    NodeJoined {
        /// The new node.
        node: NodeId,
    },
    /// A node was cordoned: running pods finish, no new bindings.
    NodeDrained {
        /// The cordoned node.
        node: NodeId,
    },
    /// A node crashed; its running/pulling pods were lost.
    NodeCrashed {
        /// The crashed node.
        node: NodeId,
        /// Pod instances lost (they resubmit).
        lost_pods: usize,
    },
    /// A crash-lost pod re-entered the scheduling queue (does not count
    /// against the retry limit).
    Resubmitted,
    /// An in-flight layer pull stalled on a registry outage; it resumes
    /// and completes at `until`.
    PullStalled {
        /// Pulling node.
        node: NodeId,
        /// When the stalled pull completes.
        until: f64,
    },
    /// The registry became unreachable until `until` (watcher keeps its
    /// last good cache; WAN pulls stall).
    RegistryOutageStart {
        /// When connectivity returns.
        until: f64,
    },
    /// Registry connectivity restored.
    RegistryOutageEnd,
}

/// One audit record: what happened to whom, when.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Virtual time (seconds).
    pub at: f64,
    /// Subject pod (`PodId(u64::MAX)` for node-scoped records).
    pub pod: PodId,
    /// What happened.
    pub kind: EventKind,
}

/// Append-only event log.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<Event>,
}

impl EventLog {
    /// An empty log.
    pub fn new() -> EventLog {
        EventLog::default()
    }

    /// Append one record.
    pub fn record(&mut self, at: f64, pod: PodId, kind: EventKind) {
        self.events.push(Event { at, pod, kind });
    }

    /// Every record, in append order.
    pub fn all(&self) -> &[Event] {
        &self.events
    }

    /// Records concerning one pod.
    pub fn for_pod(&self, pod: PodId) -> impl Iterator<Item = &Event> {
        self.events.iter().filter(move |e| e.pod == pod)
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Render the whole log as one line per record, with lossless float
    /// formatting — the determinism fingerprint `scale --events-out`
    /// writes and the shard-equivalence tests diff. Two logs render
    /// identically iff they are bit-identical.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut s = String::with_capacity(self.events.len() * 48);
        for e in &self.events {
            let _ = writeln!(s, "{:?} {} {:?}", e.at, e.pod.0, e.kind);
        }
        s
    }

    /// Is the log empty?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_kinds_recorded_for_table_accounting() {
        // Node-level records use the sentinel pod id, like Evicted does.
        let mut log = EventLog::new();
        let node_scope = PodId(u64::MAX);
        log.record(1.0, node_scope, EventKind::NodeJoined { node: NodeId(4) });
        log.record(2.0, node_scope, EventKind::NodeDrained { node: NodeId(1) });
        log.record(3.0, node_scope, EventKind::NodeCrashed { node: NodeId(2), lost_pods: 3 });
        log.record(3.0, PodId(7), EventKind::Resubmitted);
        log.record(4.0, PodId(8), EventKind::PullStalled { node: NodeId(0), until: 9.0 });
        log.record(4.0, node_scope, EventKind::RegistryOutageStart { until: 9.0 });
        log.record(9.0, node_scope, EventKind::RegistryOutageEnd);
        assert_eq!(log.len(), 7);
        assert_eq!(log.for_pod(PodId(7)).count(), 1);
        let crashes = log
            .all()
            .iter()
            .filter(|e| matches!(e.kind, EventKind::NodeCrashed { .. }))
            .count();
        assert_eq!(crashes, 1);
    }

    #[test]
    fn record_and_query() {
        let mut log = EventLog::new();
        log.record(0.0, PodId(1), EventKind::Submitted);
        log.record(0.1, PodId(1), EventKind::Scheduled { node: NodeId(2), score: 88.0 });
        log.record(0.2, PodId(2), EventKind::Submitted);
        assert_eq!(log.len(), 3);
        assert_eq!(log.for_pod(PodId(1)).count(), 2);
        assert_eq!(log.for_pod(PodId(9)).count(), 0);
        assert!(matches!(
            log.for_pod(PodId(1)).last().unwrap().kind,
            EventKind::Scheduled { node: NodeId(2), .. }
        ));
    }
}
