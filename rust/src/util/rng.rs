//! Deterministic PCG-XSH-RR 64/32 random number generator.
//!
//! The `rand` crate is not in the vendored dependency set; experiments must
//! also be exactly reproducible across runs and platforms, so the repo uses
//! its own PCG implementation (O'Neill 2014) with explicit seeding. Every
//! workload trace and property test derives from a seed printed in its
//! output.

/// PCG-XSH-RR with 64-bit state and 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id. Different streams with
    /// the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Pcg {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Pcg {
        Pcg::new(seed, 0)
    }

    /// Next 32-bit output of the generator.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 bits (two 32-bit outputs concatenated).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "range: lo >= hi ({lo} >= {hi})");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Sample from an exponential distribution with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // Inverse CDF; 1-f64() is in (0,1] so ln is finite.
        -mean * (1.0 - self.f64()).ln()
    }

    /// Weighted index sample; weights need not be normalized.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: non-positive total");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn reference_vector() {
        // PCG32 reference: seed=42, stream=54 produces this known prefix
        // (from the pcg-random.org minimal C implementation demo).
        let mut rng = Pcg::new(42, 54);
        let expected: [u32; 6] = [
            0xa15c02b7, 0x7b47f409, 0xba1d3330, 0x83d2f293, 0xbfa4784b, 0xcbed606e,
        ];
        for e in expected {
            assert_eq!(rng.next_u32(), e);
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg::seeded(1);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg::seeded(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn range_bounds() {
        let mut rng = Pcg::seeded(9);
        for _ in 0..1_000 {
            let v = rng.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg::seeded(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg::seeded(13);
        let n = 20_000;
        let mean = 4.0;
        let sum: f64 = (0..n).map(|_| rng.exponential(mean)).sum();
        let got = sum / n as f64;
        assert!((got - mean).abs() < 0.15, "mean {got}");
    }
}
