//! Integration tests across registry → cache → framework → LRScheduler:
//! the full §V pipeline (watch, match, score, dynamic weights) plus the
//! placement-constraint plugins acting together.

use lrsched::cluster::pod::{AffinityTerm, TopologySpread};
use lrsched::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
use lrsched::registry::{hub, ImageMetadata, ImageRef, LayerMetadata, MetadataCache, Registry, Watcher};
use lrsched::sched::queue::SchedulingQueue;
use lrsched::sched::{default_framework, CycleContext, LrScheduler};
use lrsched::util::units::{Bandwidth, Bytes};

fn paper_cluster() -> ClusterState {
    let mut s = ClusterState::new();
    let specs = [(4.0, 30.0), (2.0, 30.0), (4.0, 20.0), (4.0, 20.0)];
    for (i, (mem, disk)) in specs.iter().enumerate() {
        s.add_node(Node::new(
            NodeId(i as u32),
            &format!("worker{}", i + 1),
            Resources::cores_gb(4.0, *mem),
            Bytes::from_gb(*disk),
            Bandwidth::from_mbps(10.0),
        ));
    }
    s
}

fn filled_cache() -> (Registry, MetadataCache) {
    let registry = Registry::with_corpus();
    let mut cache = MetadataCache::new("/tmp/lrsched-int-cache.json");
    Watcher::with_default_interval().poll(0.0, &registry, &mut cache);
    (registry, cache)
}

#[test]
fn watcher_discovers_new_images_over_time() {
    // An image pushed after boot becomes layer-schedulable after the next
    // poll — the paper's automation contribution (§V-1).
    let (mut registry, mut cache) = filled_cache();
    let mut watcher = Watcher::new(10.0);
    watcher.poll(0.0, &registry, &mut cache);

    let custom = ImageMetadata::new(
        "sha256:custom",
        "acme-app",
        "1.0",
        vec![
            LayerMetadata { digest: hub::digest_for("os.debian12"), size: Bytes::from_mb(49.0) },
            LayerMetadata { digest: "sha256:acme".into(), size: Bytes::from_mb(30.0) },
        ],
    );
    registry.push(custom.clone());
    assert!(cache.lookup(&ImageRef::new("acme-app", "1.0")).is_none());

    // Before the interval: no refresh. After: visible.
    let mut state = paper_cluster();
    assert!(!watcher.tick(5.0, &registry, &mut cache));
    assert!(watcher.tick(10.0, &registry, &mut cache));
    let meta = cache.lookup(&ImageRef::new("acme-app", "1.0")).unwrap();
    assert_eq!(meta.total_size, Bytes::from_mb(79.0));

    // The new image scores through layer sharing with the debian base.
    let wp = hub::corpus().into_iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
    let (_, wp_layers) = state.intern_image(&wp);
    state.install_image(NodeId(3), &wp.image_ref(), &wp_layers).unwrap();

    let pod = PodBuilder::new().build("acme-app:1.0", Resources::cores_gb(0.5, 0.5));
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    let mut lr = LrScheduler::lr_scheduler(default_framework());
    let d = lr.schedule(&ctx).unwrap();
    assert_eq!(d.node, NodeId(3), "shares the debian base with wordpress");
    assert!(d.layer_score > 50.0, "49/79 MB shared: {}", d.layer_score);
}

#[test]
fn selectors_taints_and_volumes_compose() {
    let (_, cache) = filled_cache();
    let mut state = ClusterState::new();
    state.add_node(
        Node::new(NodeId(0), "gpu-node", Resources::cores_gb(4.0, 4.0), Bytes::from_gb(30.0), Bandwidth::from_mbps(10.0))
            .with_label("accel", "gpu")
            .with_taint("dedicated", "ml", true),
    );
    state.add_node(
        Node::new(NodeId(1), "storage-node", Resources::cores_gb(4.0, 4.0), Bytes::from_gb(30.0), Bandwidth::from_mbps(10.0))
            .with_label("disk", "ssd"),
    );
    state.add_node(Node::new(
        NodeId(2), "plain", Resources::cores_gb(4.0, 4.0), Bytes::from_gb(30.0), Bandwidth::from_mbps(10.0),
    ));

    let mut b = PodBuilder::new();
    let mut lr = LrScheduler::lr_scheduler(default_framework());

    // Selector forces the ssd node.
    let pod = b.build("redis:7.2", Resources::cores_gb(0.2, 0.2)).with_selector("disk", "ssd");
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    assert_eq!(lr.schedule(&ctx).unwrap().node, NodeId(1));

    // The hard taint excludes gpu-node unless tolerated.
    let pod = b.build("redis:7.2", Resources::cores_gb(0.2, 0.2)).with_selector("accel", "gpu");
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    assert!(lr.schedule(&ctx).is_err(), "selector matches only the tainted node");

    let pod = b
        .build("redis:7.2", Resources::cores_gb(0.2, 0.2))
        .with_selector("accel", "gpu")
        .with_toleration("dedicated", "ml");
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    assert_eq!(lr.schedule(&ctx).unwrap().node, NodeId(0));

    // Volume claims filter nodes without capacity.
    let mut small = paper_cluster();
    small.node_mut(NodeId(0)).volume_capacity = Bytes::from_gb(1.0);
    small.node_mut(NodeId(1)).volume_capacity = Bytes::from_gb(1.0);
    small.node_mut(NodeId(2)).volume_capacity = Bytes::from_gb(1.0);
    small.node_mut(NodeId(3)).volume_capacity = Bytes::from_gb(50.0);
    let pod = b.build("mysql:8.2", Resources::cores_gb(0.2, 0.2)).with_volume(Bytes::from_gb(10.0));
    let (meta, req, bytes) = CycleContext::prepare(&mut small, &cache, &pod);
    let ctx = CycleContext::new(&small, &pod, meta, req, bytes);
    assert_eq!(lr.schedule(&ctx).unwrap().node, NodeId(3));
}

#[test]
fn affinity_and_topology_spread_shape_scores() {
    let (_, cache) = filled_cache();
    let mut state = ClusterState::new();
    for (i, zone) in ["a", "a", "b"].iter().enumerate() {
        state.add_node(
            Node::new(
                NodeId(i as u32),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(30.0),
                Bandwidth::from_mbps(10.0),
            )
            .with_label("zone", zone),
        );
    }
    let mut b = PodBuilder::new();
    // Two web pods in zone a.
    for node in [0u32, 1] {
        let p = b.build("nginx:1.25", Resources::cores_gb(0.2, 0.2)).with_label("app", "web");
        let pid = state.submit_pod(p);
        state.bind(pid, NodeId(node)).unwrap();
    }
    // Spread constraint pushes the third replica to zone b.
    let mut pod = b.build("nginx:1.25", Resources::cores_gb(0.2, 0.2)).with_label("app", "web");
    pod.topology_spread.push(TopologySpread { topology_key: "zone".into(), max_skew: 1 });
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    let mut lr = LrScheduler::default_scheduler(default_framework());
    assert_eq!(lr.schedule(&ctx).unwrap().node, NodeId(2));

    // Preferred node affinity pulls toward zone a despite spread pressure
    // when weighted heavily (NodeAffinity weight 2 in the profile).
    let mut pod = b.build("nginx:1.25", Resources::cores_gb(0.2, 0.2));
    pod.affinity.preferred.push(AffinityTerm { key: "zone".into(), values: vec!["a".into()], weight: 100 });
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    let d = lr.schedule(&ctx).unwrap();
    assert!(d.node == NodeId(0) || d.node == NodeId(1), "affinity wins: {:?}", d.node);
}

#[test]
fn dynamic_weight_flips_under_load() {
    // The same pod+cluster flips from ω₁ to ω₂ when the candidate node
    // crosses the CPU threshold — the paper's load-adaptivity claim.
    let (_, cache) = filled_cache();
    let mut state = paper_cluster();
    let redis = hub::corpus().into_iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
    let (_, layers) = state.intern_image(&redis);
    state.install_image(NodeId(0), &redis.image_ref(), &layers).unwrap();

    let mut b = PodBuilder::new();
    let pod = b.build("redis:7.2", Resources::cores_gb(0.2, 0.2));
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    {
        let ctx = CycleContext::new(&state, &pod, meta, req.clone(), bytes);
        let mut lr = LrScheduler::lr_scheduler(default_framework());
        let d = lr.schedule(&ctx).unwrap();
        assert_eq!((d.node, d.omega), (NodeId(0), 2.0), "idle: gate passes");
    }
    // Load worker1 beyond h_cpu = 0.6.
    let filler = b.build("busybox:1.36", Resources::cores_gb(2.8, 2.8));
    let fid = state.submit_pod(filler);
    state.bind(fid, NodeId(0)).unwrap();
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    let mut lr = LrScheduler::lr_scheduler(default_framework());
    let d = lr.schedule(&ctx).unwrap();
    if d.node == NodeId(0) {
        assert_eq!(d.omega, 0.5, "busy node must be scored with ω₂");
    } else {
        // The 100-point layer score at ω₂ no longer outweighs the idle
        // nodes' k8s advantage — also correct adaptive behaviour.
        assert_eq!(d.layer_score, 0.0);
    }
}

#[test]
fn queue_retries_unschedulable_pods() {
    let (_, cache) = filled_cache();
    let mut state = paper_cluster();
    let mut b = PodBuilder::new();
    // Fill the cluster CPU.
    for i in 0..4 {
        let filler = b.build("busybox:1.36", Resources::cores_gb(3.9, 0.1));
        let fid = state.submit_pod(filler);
        state.bind(fid, NodeId(i)).unwrap();
    }
    let pod = b.build("redis:7.2", Resources::cores_gb(1.0, 0.5));
    let pid = state.submit_pod(pod.clone());

    let mut queue = SchedulingQueue::new();
    queue.push(pid);
    let mut lr = LrScheduler::lr_scheduler(default_framework());

    // First attempt fails; pod parks.
    let got = queue.pop().unwrap();
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    {
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        assert!(lr.schedule(&ctx).is_err());
    }
    queue.park(got, 0.0);
    assert_eq!(queue.release_due(5.0), 1);

    // A filler finishes; retry succeeds.
    state.unbind(lrsched::cluster::PodId(0)).unwrap();
    let got = queue.pop().unwrap();
    assert_eq!(got, pid);
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    let d = lr.schedule(&ctx).unwrap();
    assert_eq!(d.node, NodeId(0));
}

#[test]
fn unknown_image_still_schedules_on_k8s_score() {
    // cache.json has never seen the image: LRScheduler degrades to the
    // default scheduler's behaviour instead of failing (§V-2 fallback).
    let (_, cache) = filled_cache();
    let mut state = paper_cluster();
    let pod = PodBuilder::new().build("private-app:9.9", Resources::cores_gb(0.5, 0.5));
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    assert!(meta.is_none());
    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
    let mut lr = LrScheduler::lr_scheduler(default_framework());
    let d = lr.schedule(&ctx).unwrap();
    assert_eq!(d.layer_score, 0.0);
    assert_eq!(d.download_cost, Bytes::ZERO, "unknown size treated as zero");
}
