//! PodTopologySpread — "implements container topology spread by selecting
//! the node with the highest score for each topology pair" (paper §IV-B).
//!
//! For each of the pod's spread constraints, count pods with matching
//! labels in each node's topology domain; raw score = total matching count
//! (skew badness), normalized inversely so the emptiest domain scores 100.

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{normalize_inverse, ScorePlugin};

/// PodTopologySpread: spread label-matched pods evenly across topology
/// domains (lower skew scores higher).
pub struct PodTopologySpread;

impl ScorePlugin for PodTopologySpread {
    fn name(&self) -> &'static str {
        "PodTopologySpread"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        if ctx.pod.topology_spread.is_empty() {
            return 0.0; // neutral; normalize_inverse maps all-0 to all-100
        }
        let mut count = 0usize;
        for constraint in &ctx.pod.topology_spread {
            let domain = match node.labels.get(&constraint.topology_key) {
                Some(d) => d,
                None => continue,
            };
            // Count already-bound pods with labels matching ours, on any
            // node in the same domain.
            for other in ctx.state.nodes() {
                if other.labels.get(&constraint.topology_key) != Some(domain) {
                    continue;
                }
                count += ctx
                    .state
                    .pods_on(other.id)
                    .filter(|p| {
                        ctx.pod
                            .labels
                            .iter()
                            .any(|(k, v)| p.labels.get(k) == Some(v))
                    })
                    .count();
            }
        }
        count as f64
    }

    fn normalize(&self, _ctx: &CycleContext, scores: &mut [f64]) {
        normalize_inverse(scores);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::TopologySpread;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::{Bandwidth, Bytes};

    fn setup() -> (ClusterState, PodBuilder) {
        let mut s = ClusterState::new();
        for (i, zone) in ["a", "a", "b"].iter().enumerate() {
            s.add_node(
                Node::new(
                    NodeId(i as u32),
                    &format!("n{i}"),
                    Resources::cores_gb(4.0, 4.0),
                    Bytes::from_gb(20.0),
                    Bandwidth::from_mbps(10.0),
                )
                .with_label("zone", zone),
            );
        }
        (s, PodBuilder::new())
    }

    #[test]
    fn prefers_empty_domain() {
        let (mut state, mut b) = setup();
        // Two "app=web" pods already in zone a.
        for _ in 0..2 {
            let p = b.build("nginx:1.25", Resources::ZERO).with_label("app", "web");
            let pid = state.submit_pod(p);
            state.bind(pid, NodeId(0)).unwrap();
        }
        let mut pod = b.build("nginx:1.25", Resources::ZERO).with_label("app", "web");
        pod.topology_spread.push(TopologySpread { topology_key: "zone".into(), max_skew: 1 });
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);

        // Both zone-a nodes see the 2 pods in their domain; zone b sees 0.
        let raw: Vec<f64> = (0..3)
            .map(|i| PodTopologySpread.score(&ctx, state.node(NodeId(i))))
            .collect();
        assert_eq!(raw, vec![2.0, 2.0, 0.0]);
        let mut scores = raw;
        PodTopologySpread.normalize(&ctx, &mut scores);
        assert_eq!(scores, vec![0.0, 0.0, 100.0]);
    }

    #[test]
    fn no_constraint_is_neutral() {
        let (state, mut b) = setup();
        let pod = b.build("nginx:1.25", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let mut scores: Vec<f64> = (0..3)
            .map(|i| PodTopologySpread.score(&ctx, state.node(NodeId(i))))
            .collect();
        PodTopologySpread.normalize(&ctx, &mut scores);
        assert_eq!(scores, vec![100.0, 100.0, 100.0]);
    }

    #[test]
    fn unlabeled_pods_do_not_count() {
        let (mut state, mut b) = setup();
        let other = b.build("redis:7.2", Resources::ZERO); // no labels
        let pid = state.submit_pod(other);
        state.bind(pid, NodeId(0)).unwrap();
        let mut pod = b.build("nginx:1.25", Resources::ZERO).with_label("app", "web");
        pod.topology_spread.push(TopologySpread { topology_key: "zone".into(), max_skew: 1 });
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        assert_eq!(PodTopologySpread.score(&ctx, state.node(NodeId(0))), 0.0);
    }
}
