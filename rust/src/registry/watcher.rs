//! Registry watcher — the paper's `Registry.Watcher()` goroutine (§V-1):
//! periodically fetch the catalog, walk tags and manifests, filter layer
//! ids + sizes, and refresh the local metadata cache. Default poll interval
//! is 10 seconds, matching the paper.
//!
//! The watcher is driven by the simulator's virtual clock (or real time in
//! the CLI), and tolerates transient registry failures by keeping the last
//! good cache — exactly the behaviour the paper motivates for unstable edge
//! links.

use super::cache::MetadataCache;
use super::catalog::Registry;

/// Poll interval from the paper: "waits for 10 seconds by default".
pub const DEFAULT_POLL_SECS: f64 = 10.0;

/// The periodic registry poller.
#[derive(Debug, Clone)]
pub struct Watcher {
    /// Seconds between polls.
    pub poll_interval_secs: f64,
    next_poll_at: f64,
    /// Registry reachability. During an outage window polls fail fast and
    /// the last good cache stays in place.
    online: bool,
    /// Polls attempted (statistics for observability/tests).
    pub polls: u64,
    /// Manifests walked across all successful polls.
    pub images_seen: u64,
    /// Polls that failed (registry offline).
    pub failures: u64,
}

impl Watcher {
    /// A watcher polling every `poll_interval_secs`, due immediately.
    pub fn new(poll_interval_secs: f64) -> Watcher {
        Watcher {
            poll_interval_secs,
            next_poll_at: 0.0,
            online: true,
            polls: 0,
            images_seen: 0,
            failures: 0,
        }
    }

    /// Flip registry reachability (driven by the simulator's
    /// `RegistryOutage` events).
    pub fn set_online(&mut self, online: bool) {
        self.online = online;
    }

    /// Is the registry currently reachable?
    pub fn is_online(&self) -> bool {
        self.online
    }

    /// A watcher at the paper's 10-second default interval.
    pub fn with_default_interval() -> Watcher {
        Watcher::new(DEFAULT_POLL_SECS)
    }

    /// Is a poll due at virtual time `now`?
    pub fn due(&self, now: f64) -> bool {
        now >= self.next_poll_at
    }

    /// Time of the next scheduled poll.
    pub fn next_poll_at(&self) -> f64 {
        self.next_poll_at
    }

    /// Run one poll: catalog → tags → manifests → cache refresh.
    /// Returns the number of images refreshed.
    pub fn poll(&mut self, now: f64, registry: &Registry, cache: &mut MetadataCache) -> usize {
        self.polls += 1;
        self.next_poll_at = now + self.poll_interval_secs;
        if !self.online {
            // Registry unreachable: keep the last good cache — the paper's
            // motivated behaviour for unstable edge links.
            self.failures += 1;
            return 0;
        }
        let mut fresh = MetadataCache::new(&cache.cache_file);
        for name in registry.catalog() {
            let tags = match registry.tags(&name) {
                Ok(t) => t,
                Err(_) => {
                    self.failures += 1;
                    continue;
                }
            };
            for tag in tags {
                match registry.manifest(&super::image::ImageRef::new(&name, &tag)) {
                    Ok(meta) => {
                        fresh.insert(meta.clone());
                        self.images_seen += 1;
                    }
                    Err(_) => self.failures += 1,
                }
            }
        }
        // Atomic swap: the scheduler never observes a half-filled cache.
        let n = fresh.len();
        *cache = fresh;
        n
    }

    /// Drive the watcher from a clock: polls if due, otherwise no-op.
    /// Returns true if a poll ran.
    pub fn tick(&mut self, now: f64, registry: &Registry, cache: &mut MetadataCache) -> bool {
        if self.due(now) {
            self.poll(now, registry, cache);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::image::ImageRef;

    #[test]
    fn poll_fills_cache() {
        let reg = Registry::with_corpus();
        let mut cache = MetadataCache::new("/tmp/unused.json");
        let mut w = Watcher::with_default_interval();
        let n = w.poll(0.0, &reg, &mut cache);
        assert_eq!(n, 30);
        assert_eq!(cache.len(), 30);
        assert!(cache.lookup(&ImageRef::new("mysql", "8.2")).is_some());
        assert_eq!(w.polls, 1);
        assert_eq!(w.failures, 0);
    }

    #[test]
    fn respects_interval() {
        let reg = Registry::with_corpus();
        let mut cache = MetadataCache::new("/tmp/unused.json");
        let mut w = Watcher::new(10.0);
        assert!(w.tick(0.0, &reg, &mut cache)); // first poll immediate
        assert!(!w.tick(5.0, &reg, &mut cache));
        assert!(!w.tick(9.99, &reg, &mut cache));
        assert!(w.tick(10.0, &reg, &mut cache));
        assert_eq!(w.polls, 2);
    }

    #[test]
    fn outage_keeps_last_good_cache() {
        let reg = Registry::with_corpus();
        let mut cache = MetadataCache::new("/tmp/unused.json");
        let mut w = Watcher::new(10.0);
        w.poll(0.0, &reg, &mut cache);
        assert_eq!(cache.len(), 30);
        w.set_online(false);
        assert_eq!(w.poll(10.0, &reg, &mut cache), 0);
        assert_eq!(cache.len(), 30, "outage must not wipe the cache");
        assert_eq!(w.failures, 1);
        assert_eq!(w.next_poll_at(), 20.0, "polling cadence continues");
        w.set_online(true);
        assert!(w.poll(20.0, &reg, &mut cache) > 0);
    }

    #[test]
    fn poll_replaces_stale_entries() {
        let mut reg = Registry::new();
        let mut cache = MetadataCache::new("/tmp/unused.json");
        let mut w = Watcher::new(10.0);
        // Image that later disappears from the registry.
        reg.push(crate::registry::hub::corpus().remove(0));
        w.poll(0.0, &reg, &mut cache);
        assert_eq!(cache.len(), 1);
        let reg2 = Registry::new(); // registry wiped
        w.poll(10.0, &reg2, &mut cache);
        assert_eq!(cache.len(), 0, "stale entries must not survive a poll");
    }
}
