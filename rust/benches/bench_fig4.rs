//! Bench target regenerating paper Fig. 4: download time vs bandwidth.
//! Run: `cargo bench --bench bench_fig4`

use lrsched::exp::fig4;
use lrsched::testing::bench::{bench, header};

fn main() {
    let fig = fig4::run(42, 20, 4);
    print!("{}", fig.print());

    println!("\n{}", header());
    let r = bench("fig4: 15 runs (3 scheds x 5 bandwidths)", 2_000, || {
        std::hint::black_box(fig4::run(42, 20, 4));
    });
    println!("{}", r.report());
}
