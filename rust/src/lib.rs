//! # lrsched — LRScheduler reproduction
//!
//! A layer-aware, resource-adaptive container scheduler for edge computing,
//! reproducing Tang et al., *LRScheduler* (MSN 2024), as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: a Kubernetes-scheduling-framework analog with the
//!   paper's LRScheduler plugin, a Docker-registry substrate, an edge-cluster
//!   discrete-event simulator, and the experiment harnesses for every figure
//!   and table in the paper's evaluation.
//! - **L2/L1 (`python/compile/`)**: the batched node-scoring pipeline
//!   (layer-sharing score, resource scores, Iverson-gated dynamic weights)
//!   as a JAX graph wrapping a Pallas kernel, AOT-lowered to HLO text.
//! - **Runtime (`runtime`)**: loads the AOT artifacts via PJRT (`xla` crate)
//!   and serves them on the scheduling hot path; a pure-rust scorer provides
//!   the always-available fallback and the differential-testing oracle.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `docs/ARCHITECTURE.md` for the module map, event lifecycle, and trace
//! pipeline.
//!
//! ## Quickstart: a small `scale`-style run
//!
//! Build an edge fleet, generate a seeded workload, and drive it through
//! the event engine — the same path the `lrsched scale` subcommand takes
//! (timed arrivals, finite pod lifetimes, accounting identity at the end):
//!
//! ```
//! use lrsched::cluster::{Node, NodeId, Resources};
//! use lrsched::registry::Registry;
//! use lrsched::sim::{Popularity, SimConfig, Simulation, WorkloadConfig, WorkloadGen};
//! use lrsched::util::units::{Bandwidth, Bytes};
//!
//! // A uniform 3-node edge fleet: 4 cores / 8 GB / 64 GB disk per node.
//! let nodes: Vec<Node> = (0..3)
//!     .map(|i| {
//!         Node::new(
//!             NodeId(i),
//!             &format!("edge{i}"),
//!             Resources::cores_gb(4.0, 8.0),
//!             Bytes::from_gb(64.0),
//!             Bandwidth::from_mbps(100.0),
//!         )
//!     })
//!     .collect();
//!
//! // A seeded 8-pod workload with Zipf image popularity and finite
//! // lifetimes, drawn from the synthetic Docker Hub corpus.
//! let registry = Registry::with_corpus();
//! let workload = WorkloadConfig {
//!     seed: 7,
//!     popularity: Popularity::Zipf(1.1),
//!     duration_range: Some((30.0, 120.0)),
//!     ..WorkloadConfig::default()
//! };
//! let pods = WorkloadGen::new(&registry, workload).trace(8);
//!
//! // Timed arrivals every 0.5 s; pulls overlap across nodes.
//! let mut cfg = SimConfig::default();
//! cfg.inter_arrival_secs = Some(0.5);
//! let mut sim = Simulation::new(nodes, registry, cfg);
//! let report = sim.run_trace(pods);
//!
//! assert_eq!(report.submitted, 8);
//! assert_eq!(report.completed(), 8);
//! // No dropped events: every pod is in exactly one terminal bucket.
//! assert!(report.accounting_balanced());
//! assert!(report.total_download() > Bytes::ZERO);
//! ```
//!
//! To replay a *real* cluster trace instead of the synthetic generator,
//! see [`sim::trace`] and `docs/SCALE.md`.
//!
//! ## Quickstart: an online `serve` session
//!
//! The same engine as a decision service: open a [`serve::Session`] over
//! a fresh simulation, feed it protocol lines, and read back one NDJSON
//! decision per pod — `lrsched serve` wraps exactly this loop, and
//! `docs/SERVE.md` documents the wire protocol field by field:
//!
//! ```
//! use lrsched::exp::common;
//! use lrsched::registry::Registry;
//! use lrsched::serve::Session;
//! use lrsched::sim::{ErrorMode, SimConfig, Simulation};
//!
//! let mut cfg = SimConfig::default();
//! cfg.inter_arrival_secs = Some(0.3); // timed-arrival protocol, like `scale`
//! let mut sim = Simulation::new(common::scale_nodes(4), Registry::with_corpus(), cfg);
//! // The wall clock is injected (determinism contract R2): tests pin
//! // `latency_us` to 0, the CLI passes an `Instant`-based counter.
//! let mut session = Session::new(&mut sim, ErrorMode::Strict, Box::new(|| 0_u64));
//!
//! let (mut out, mut diag) = (Vec::new(), Vec::new());
//! let line = r#"{"event":"pod","t":0.0,"image":"nginx:1.25","cpu_milli":500,"mem_mb":512}"#;
//! let shutdown = session.handle_line(line, 1, &mut out, &mut diag).unwrap();
//! assert!(!shutdown);
//! assert_eq!(out.len(), 1, "one decision line per pod event");
//! assert!(out[0].contains("\"type\":\"decision\""));
//! assert!(out[0].contains("\"breakdown\""));
//!
//! // EOF: drain to quiescence and append the summary line.
//! let report = session.finish(&mut out);
//! assert_eq!(report.submitted, 1);
//! assert!(report.accounting_balanced());
//! assert!(out.last().unwrap().contains("\"type\":\"summary\""));
//! ```

#![warn(missing_docs)]

pub mod cli;
pub mod cluster;
pub mod exp;
pub mod lint;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod testing;
pub mod registry;
pub mod util;
