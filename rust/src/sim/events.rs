//! The discrete-event core: a unified, timestamp-ordered event queue.
//!
//! Every state transition the simulator performs — pod arrivals, pull
//! completions, pod terminations, registry-watcher ticks, kubelet GC
//! pressure sweeps, scheduling-queue back-off releases, and cluster
//! volatility (node join/drain/crash, registry outage windows) — is a
//! first-class timestamped event popped in order from one `BinaryHeap`.
//! This replaces the seed engine's "process everything at the next
//! arrival" linear scans, which could only observe completions at arrival
//! instants and never fired terminations due after the final pull.
//!
//! Ordering is total and deterministic:
//! 1. ascending timestamp,
//! 2. at equal timestamps, ascending *class* — capacity restoration
//!    (outage end, node join) lands before the pod lifecycle it could
//!    unblock, capacity loss (drain, crash, outage start) after it, and
//!    scheduling attempts (back-off releases, arrivals) last, so a
//!    same-instant retry sees the fully updated cluster,
//! 3. at equal (timestamp, class), FIFO by insertion sequence.
//!
//! The canonical 12-class table lives in `docs/ARCHITECTURE.md`
//! ("Same-timestamp ordering"); the private `EventPayload::class`
//! method is its implementation, and `equal_times_order_by_class` in
//! this module's tests pins every row.
//!
//! The sharded engine ([`crate::sim::shard`]) additionally classifies
//! every payload as *node-local* ([`EventPayload::is_node_local`]) or
//! coordinator-only, and may [`EventQueue::cancel`] a speculatively
//! scheduled event before it fires (see `docs/ARCHITECTURE.md`,
//! "Sharded event lanes").

use crate::cluster::{NodeId, Pod, PodId};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet};

/// What happens when an event fires.
#[derive(Debug)]
pub enum EventPayload {
    /// Registry watcher poll (paper §V-1; re-armed while work remains).
    WatcherTick,
    /// Registry connectivity restored (stalled pulls resume; wake-up
    /// source for parked pods).
    RegistryOutageEnd,
    /// A cold node (empty layer cache) joins the cluster; capacity-driven
    /// wake-up source.
    NodeJoin,
    /// All layers for `pod`'s image are present on its node.
    PullComplete {
        /// The pod whose pull finished.
        pod: PodId,
    },
    /// A finite-duration pod's run ends; its resources release. `epoch`
    /// guards against stale terminations after a crash resubmitted the pod
    /// (a rebound pod's old timer must not kill the new instance).
    PodTermination {
        /// The terminating pod.
        pod: PodId,
        /// Instance epoch this timer belongs to.
        epoch: u64,
    },
    /// A node is cordoned: running pods finish, no new bindings.
    NodeDrain {
        /// The node to cordon.
        node: NodeId,
    },
    /// A node crashes: its running/pulling pods resubmit to the
    /// scheduling queue (without counting against the retry limit).
    NodeCrash {
        /// The node that crashes.
        node: NodeId,
    },
    /// The registry becomes unreachable until `until`: watcher polls fail
    /// (last good cache kept) and in-flight WAN pulls stall.
    RegistryOutageStart {
        /// Absolute end of the outage window.
        until: f64,
    },
    /// Kubelet image-GC pressure sweep across all nodes.
    GcSweep,
    /// Kubelet image-GC pressure check for a single node — scheduled by a
    /// pod termination (only that node's in-use image set changed). Unlike
    /// the cluster-wide [`EventPayload::GcSweep`], this class is
    /// node-local, so the sharded engine can run it on the node's lane.
    GcSweepNode {
        /// The node whose disk pressure is re-checked.
        node: NodeId,
    },
    /// Scheduling-queue back-off expiry: parked pods become schedulable.
    BackoffRelease,
    /// A pod is submitted to the API server.
    Arrival {
        /// The arriving pod spec.
        pod: Pod,
    },
}

impl EventPayload {
    /// Same-timestamp ordering class (lower fires first; see module docs).
    fn class(&self) -> u8 {
        match self {
            EventPayload::WatcherTick => 0,
            EventPayload::RegistryOutageEnd => 1,
            EventPayload::NodeJoin => 2,
            EventPayload::PullComplete { .. } => 3,
            EventPayload::PodTermination { .. } => 4,
            EventPayload::NodeDrain { .. } => 5,
            EventPayload::NodeCrash { .. } => 6,
            EventPayload::RegistryOutageStart { .. } => 7,
            EventPayload::GcSweep => 8,
            EventPayload::GcSweepNode { .. } => 9,
            EventPayload::BackoffRelease => 10,
            EventPayload::Arrival { .. } => 11,
        }
    }

    /// Is this a recurring watcher tick (not "real" pending work)?
    pub fn is_watcher(&self) -> bool {
        matches!(self, EventPayload::WatcherTick)
    }

    /// Does this event only touch one node's state (pull completions, pod
    /// terminations, per-node GC checks)? Node-local classes are the ones
    /// the sharded engine routes onto per-node event lanes; everything
    /// else is coordinator-only and acts as an epoch barrier (see
    /// `docs/ARCHITECTURE.md`, "Sharded event lanes").
    pub fn is_node_local(&self) -> bool {
        matches!(
            self,
            EventPayload::PullComplete { .. }
                | EventPayload::PodTermination { .. }
                | EventPayload::GcSweepNode { .. }
        )
    }

    /// Among the node-local classes, could this event free capacity and
    /// thereby wake a parked pod? A termination always releases its pod's
    /// requests, and a per-node GC check wakes if it actually evicts; a
    /// pull completion never wakes — the sequential handler treats a
    /// finish-side eviction as disk bookkeeping, not a wake-up source.
    /// Cure-aware window collection uses this to decide which events may
    /// have to close a parallel window when capacity-curable pods are
    /// parked (see `docs/ARCHITECTURE.md`, "Sharded event lanes").
    pub fn is_wake_candidate(&self) -> bool {
        matches!(
            self,
            EventPayload::PodTermination { .. } | EventPayload::GcSweepNode { .. }
        )
    }
}

/// A scheduled event. Ord is (at, class, seq); timestamps are finite by
/// construction (`EventQueue::push` rejects non-finite times).
#[derive(Debug)]
pub struct QueuedEvent {
    /// Absolute virtual time the event fires.
    pub at: f64,
    class: u8,
    seq: u64,
    /// What happens when it fires.
    pub payload: EventPayload,
}

impl QueuedEvent {
    /// Globally unique insertion sequence number — the FIFO tie-break at
    /// equal (time, class), and the handle [`EventQueue::cancel`] takes.
    pub fn seq(&self) -> u64 {
        self.seq
    }
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for QueuedEvent {}

impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // Finite timestamps: total order is safe.
        self.at
            .partial_cmp(&other.at)
            .expect("event timestamps are finite")
            .then(self.class.cmp(&other.class))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of simulation events with deterministic tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<QueuedEvent>>,
    next_seq: u64,
    /// Events that represent real pending work (everything but WatcherTick)
    /// — used to decide when the recurring watcher may stop re-arming.
    non_watcher: usize,
    /// Sequence numbers cancelled before firing ([`EventQueue::cancel`]);
    /// their heap entries are dropped silently on the way out.
    cancelled: HashSet<u64>,
    /// Total events ever pushed (observability for the scale harness).
    /// Cancelled events are subtracted again, so the counter reads as if
    /// they were never scheduled.
    pub pushed_total: u64,
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `payload` at absolute time `at` (must be finite). Returns
    /// the event's sequence number — the handle [`EventQueue::cancel`]
    /// takes; most callers ignore it.
    pub fn push(&mut self, at: f64, payload: EventPayload) -> u64 {
        assert!(at.is_finite(), "non-finite event time {at}");
        if !payload.is_watcher() {
            self.non_watcher += 1;
        }
        let seq = self.next_seq;
        let ev = QueuedEvent { at, class: payload.class(), seq, payload };
        self.next_seq += 1;
        self.pushed_total += 1;
        self.heap.push(std::cmp::Reverse(ev));
        seq
    }

    /// Cancel a scheduled (non-watcher) event before it fires: the entry
    /// is dropped silently when it reaches the head, and the push/pending
    /// counters are rolled back so the queue reads as if the event was
    /// never scheduled. Used by the sharded engine to retract a
    /// speculatively scheduled termination whose pull turned out to wedge.
    /// Cancelling an already-fired or unknown seq is a no-op only if the
    /// seq is never reused — callers must pass seqs of live events.
    pub fn cancel(&mut self, seq: u64) {
        if self.cancelled.insert(seq) {
            self.pushed_total -= 1;
            self.non_watcher -= 1;
        }
    }

    /// Drop cancelled entries sitting at the heap head so peek/pop see a
    /// live event.
    fn drop_cancelled_head(&mut self) {
        while let Some(head) = self.heap.peek() {
            let seq = head.0.seq;
            if self.cancelled.remove(&seq) {
                self.heap.pop();
            } else {
                break;
            }
        }
    }

    /// Pop the next live event in (time, class, seq) order.
    pub fn pop(&mut self) -> Option<QueuedEvent> {
        self.drop_cancelled_head();
        let ev = self.heap.pop()?.0;
        if !ev.payload.is_watcher() {
            self.non_watcher -= 1;
        }
        Some(ev)
    }

    /// The next live event, without removing it — the sharded engine peeks
    /// to decide whether the head extends the current lane window.
    pub fn peek(&mut self) -> Option<&QueuedEvent> {
        self.drop_cancelled_head();
        self.heap.peek().map(|e| &e.0)
    }

    /// Time of the next event, if any. (May report a cancelled entry that
    /// has not been skipped yet; [`EventQueue::peek`] never does.)
    pub fn peek_at(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.at)
    }

    /// Are any non-watcher (real work) events outstanding?
    pub fn has_pending_work(&self) -> bool {
        self.non_watcher > 0
    }

    /// Events currently queued (may include cancelled entries not yet
    /// skipped out of the heap).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn times_and_classes(q: &mut EventQueue) -> Vec<(f64, u8)> {
        let mut out = Vec::new();
        while let Some(ev) = q.pop() {
            out.push((ev.at, ev.payload.class()));
        }
        out
    }

    #[test]
    fn pops_in_timestamp_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventPayload::PullComplete { pod: PodId(1) });
        q.push(1.0, EventPayload::PullComplete { pod: PodId(2) });
        q.push(2.0, EventPayload::PodTermination { pod: PodId(3), epoch: 0 });
        let order = times_and_classes(&mut q);
        assert_eq!(order.iter().map(|(t, _)| *t).collect::<Vec<_>>(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn equal_times_order_by_class() {
        let mut q = EventQueue::new();
        // Push in reverse-class order; pops must come back sorted per the
        // module-doc table: watcher, outage end, join, pull, termination,
        // drain, crash, outage start, gc, per-node gc, backoff, arrival.
        let mut b = crate::cluster::PodBuilder::new();
        q.push(5.0, EventPayload::Arrival { pod: b.build("redis:7.2", crate::cluster::Resources::ZERO) });
        q.push(5.0, EventPayload::BackoffRelease);
        q.push(5.0, EventPayload::GcSweepNode { node: NodeId(3) });
        q.push(5.0, EventPayload::GcSweep);
        q.push(5.0, EventPayload::RegistryOutageStart { until: 9.0 });
        q.push(5.0, EventPayload::NodeCrash { node: NodeId(2) });
        q.push(5.0, EventPayload::NodeDrain { node: NodeId(1) });
        q.push(5.0, EventPayload::PodTermination { pod: PodId(1), epoch: 0 });
        q.push(5.0, EventPayload::PullComplete { pod: PodId(2) });
        q.push(5.0, EventPayload::NodeJoin);
        q.push(5.0, EventPayload::RegistryOutageEnd);
        q.push(5.0, EventPayload::WatcherTick);
        let order = times_and_classes(&mut q);
        assert_eq!(
            order.iter().map(|(_, c)| *c).collect::<Vec<_>>(),
            (0..=11).collect::<Vec<_>>()
        );
    }

    #[test]
    fn node_local_classes_are_the_lane_set() {
        assert!(EventPayload::PullComplete { pod: PodId(1) }.is_node_local());
        assert!(EventPayload::PodTermination { pod: PodId(1), epoch: 0 }.is_node_local());
        assert!(EventPayload::GcSweepNode { node: NodeId(0) }.is_node_local());
        for p in [
            EventPayload::WatcherTick,
            EventPayload::RegistryOutageEnd,
            EventPayload::NodeJoin,
            EventPayload::NodeDrain { node: NodeId(0) },
            EventPayload::NodeCrash { node: NodeId(0) },
            EventPayload::RegistryOutageStart { until: 1.0 },
            EventPayload::GcSweep,
            EventPayload::BackoffRelease,
        ] {
            assert!(!p.is_node_local(), "{p:?} must be coordinator-only");
        }
    }

    #[test]
    fn wake_candidates_are_the_terminate_and_sweep_classes() {
        // The cure-aware window contract: of the three node-local
        // classes, only terminations and per-node GC checks can wake a
        // parked pod in the sequential engine. Pull completions must stay
        // non-candidates — they may evict on the finish side, but the
        // sequential handler never calls `wake_parked` for them.
        assert!(EventPayload::PodTermination { pod: PodId(1), epoch: 0 }.is_wake_candidate());
        assert!(EventPayload::GcSweepNode { node: NodeId(0) }.is_wake_candidate());
        assert!(!EventPayload::PullComplete { pod: PodId(1) }.is_wake_candidate());
        // Every wake candidate is node-local (coordinator classes wake
        // inline and never enter a window in the first place).
        for p in [
            EventPayload::PodTermination { pod: PodId(1), epoch: 0 },
            EventPayload::GcSweepNode { node: NodeId(0) },
        ] {
            assert!(p.is_node_local(), "{p:?} must be a lane class");
        }
    }

    #[test]
    fn cancelled_events_never_fire_and_counters_roll_back() {
        let mut q = EventQueue::new();
        q.push(1.0, EventPayload::GcSweep);
        let seq = q.push(2.0, EventPayload::PodTermination { pod: PodId(9), epoch: 0 });
        q.push(3.0, EventPayload::BackoffRelease);
        assert_eq!(q.pushed_total, 3);
        q.cancel(seq);
        assert_eq!(q.pushed_total, 2, "cancel reads as never-scheduled");
        let order = times_and_classes(&mut q);
        assert_eq!(order.iter().map(|(t, _)| *t).collect::<Vec<_>>(), vec![1.0, 3.0]);
    }

    #[test]
    fn peek_skips_cancelled_head() {
        let mut q = EventQueue::new();
        let seq = q.push(1.0, EventPayload::GcSweep);
        q.push(2.0, EventPayload::BackoffRelease);
        q.cancel(seq);
        let head = q.peek().expect("live event remains");
        assert_eq!(head.at, 2.0);
        assert!(q.has_pending_work());
        assert_eq!(q.pop().unwrap().at, 2.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_time_and_class_is_fifo() {
        let mut q = EventQueue::new();
        for pod in 0..10u64 {
            q.push(1.0, EventPayload::PullComplete { pod: PodId(pod) });
        }
        let mut pods = Vec::new();
        while let Some(ev) = q.pop() {
            if let EventPayload::PullComplete { pod } = ev.payload {
                pods.push(pod.0);
            }
        }
        assert_eq!(pods, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn watcher_events_do_not_count_as_work() {
        let mut q = EventQueue::new();
        q.push(0.0, EventPayload::WatcherTick);
        assert!(!q.has_pending_work());
        q.push(1.0, EventPayload::GcSweep);
        assert!(q.has_pending_work());
        q.pop(); // watcher
        assert!(q.has_pending_work());
        q.pop(); // gc
        assert!(!q.has_pending_work());
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_non_finite_times() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, EventPayload::GcSweep);
    }

    #[test]
    fn peek_reports_next_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_at(), None);
        q.push(4.0, EventPayload::GcSweep);
        q.push(2.0, EventPayload::BackoffRelease);
        assert_eq!(q.peek_at(), Some(2.0));
        assert_eq!(q.len(), 2);
    }
}
