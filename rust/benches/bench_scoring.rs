//! Scoring-path benchmarks + design ablations:
//! - native vs XLA backend at both artifact shapes (the L3 hot path)
//! - full scheduling cycle (filter + 8 plugins + LR combination)
//! - ω-policy ablation (TwoLevel / ThreeLevel / Linear / Static)
//! - plugin-subset ablation (full default profile vs resources-only)
//! Run: `cargo bench --bench bench_scoring`

use lrsched::cluster::{PodBuilder, Resources};
use lrsched::registry::{hub, Registry};
use lrsched::runtime::XlaScorer;
use lrsched::sched::dynamic_weight::{weight_for, WeightParams, WeightPolicy};
use lrsched::sched::scoring::{NativeScorer, ScoreInputs, ScoringBackend};
use lrsched::sched::{default_framework, CycleContext, FrameworkConfig, LrScheduler};
use lrsched::testing::bench::{bench, header};
use lrsched::testing::fixtures;
use lrsched::util::rng::Pcg;

fn random_inputs(rng: &mut Pcg, n: usize, l: usize) -> ScoreInputs {
    let mut x = ScoreInputs::zeros(n, l, WeightParams::default());
    for v in x.present.iter_mut() {
        *v = if rng.chance(0.3) { 1.0 } else { 0.0 };
    }
    for j in 0..l {
        x.req[j] = if rng.chance(0.2) { 1.0 } else { 0.0 };
        x.sizes_mb[j] = rng.f64_range(0.1, 300.0) as f32;
    }
    for i in 0..n {
        x.cpu_cap[i] = 4000.0;
        x.mem_cap[i] = 4.0e9;
        x.cpu_used[i] = rng.f64_range(0.0, 3000.0) as f32;
        x.mem_used[i] = rng.f64_range(0.0, 3.0e9) as f32;
        x.k8s_score[i] = rng.f64_range(0.0, 800.0) as f32;
        x.feasible[i] = 1.0;
    }
    x
}

fn main() {
    println!("{}", header());
    let mut rng = Pcg::seeded(9);

    // --- dense scorer backends -------------------------------------------
    for (n, l) in [(16usize, 256usize), (64, 1024)] {
        let x = random_inputs(&mut rng, n, l);
        let mut native = NativeScorer;
        let r = bench(&format!("native scorer {n}x{l}"), 300, || {
            std::hint::black_box(native.score(&x));
        });
        println!("{}", r.report());
    }
    match XlaScorer::load_default() {
        Ok(mut xla) => {
            for (n, l) in [(16usize, 256usize), (64, 1024)] {
                let x = random_inputs(&mut rng, n, l);
                let r = bench(&format!("xla scorer {n}x{l} (PJRT execute)"), 300, || {
                    std::hint::black_box(xla.score(&x));
                });
                println!("{}", r.report());
            }
        }
        Err(e) => println!("xla scorer skipped: {e:#}"),
    }

    // --- full scheduling cycle --------------------------------------------
    let mut state = fixtures::uniform_cluster(4);
    let cache = fixtures::corpus_cache();
    // Warm two nodes so layer scores are nontrivial.
    for (node, name) in [(0u32, "wordpress"), (1, "ghost")] {
        let m = hub::corpus().into_iter().find(|m| m.name == name).unwrap();
        let (_, layers) = state.intern_image(&m);
        state
            .install_image(lrsched::cluster::NodeId(node), &m.image_ref(), &layers)
            .unwrap();
    }
    let pod = PodBuilder::new().build("wordpress:6.4", Resources::cores_gb(0.5, 0.5));
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let meta = meta.cloned();
    {
        let ctx = CycleContext::new(&state, &pod, meta.as_ref(), req.clone(), bytes);
        let mut lr = LrScheduler::lr_scheduler(default_framework());
        let r = bench("full cycle: filter+8 plugins+LR (4 nodes)", 300, || {
            std::hint::black_box(lr.schedule(&ctx).unwrap());
        });
        println!("{}", r.report());

        let mut min = LrScheduler::lr_scheduler(FrameworkConfig::resources_only().build("min"));
        let r = bench("ablation: resources-only profile (4 nodes)", 300, || {
            std::hint::black_box(min.schedule(&ctx).unwrap());
        });
        println!("{}", r.report());
    }

    // --- omega-policy ablation --------------------------------------------
    let params = WeightParams::default();
    let node = state.node(lrsched::cluster::NodeId(0));
    let local = lrsched::util::units::Bytes::from_mb(120.0);
    for policy in [
        WeightPolicy::TwoLevel,
        WeightPolicy::ThreeLevel,
        WeightPolicy::Linear,
        WeightPolicy::Static(4.0),
    ] {
        let r = bench(&format!("omega policy {policy:?}"), 50, || {
            std::hint::black_box(weight_for(policy, &params, node, local));
        });
        println!("{}", r.report());
    }

    // --- end-to-end simulation throughput ----------------------------------
    let r = bench("simulate 20 pods / 4 nodes (LR, native)", 1_000, || {
        let reg = Registry::with_corpus();
        let trace = lrsched::sim::WorkloadGen::new(&reg, Default::default()).trace(20);
        let mut sim = lrsched::sim::Simulation::new(
            lrsched::exp::common::paper_nodes(4),
            reg,
            Default::default(),
        );
        std::hint::black_box(sim.run_trace(trace));
    });
    println!("{}", r.report());
}
