//! Workload generation — the paper's §VI-A protocol: "we randomly request
//! these images, setting random CPU and memory limits for each request."
//!
//! Pods draw an image uniformly (or Zipf-weighted, the realistic variant)
//! from the corpus, CPU requests uniform in [100m, 1000m], memory uniform
//! in [100 MB, 1 GB]. Traces are reproducible from the seed.

use crate::cluster::{Pod, PodBuilder, Resources};
use crate::registry::Registry;
use crate::util::rng::Pcg;
use crate::util::units::{Bytes, MilliCpu};

/// Image-popularity model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Popularity {
    /// Uniform over the catalog (the paper's protocol).
    Uniform,
    /// Zipf(s) over the catalog — container registries see heavy-tailed
    /// pull distributions; used by the ablation benches.
    Zipf(f64),
}

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub seed: u64,
    pub popularity: Popularity,
    /// CPU request range in millicores.
    pub cpu_range: (u64, u64),
    /// Memory request range in bytes.
    pub mem_range: (u64, u64),
    /// Restrict to the images the paper names (None = whole corpus).
    pub image_allowlist: Option<Vec<String>>,
    /// Pod lifetime range in seconds; None = services that run forever
    /// (the paper's protocol). Finite lifetimes model churn workloads.
    pub duration_range: Option<(f64, f64)>,
}

impl Default for WorkloadConfig {
    fn default() -> WorkloadConfig {
        // Ranges sized like the paper's testbed: 20 pods must fit the
        // 3-worker cluster (12 cores, 10 GB) with headroom to spare.
        WorkloadConfig {
            seed: 42,
            popularity: Popularity::Uniform,
            cpu_range: (100, 800),
            mem_range: (50_000_000, 500_000_000),
            image_allowlist: None,
            duration_range: None,
        }
    }
}

/// Generates pods from a registry catalog.
pub struct WorkloadGen {
    rng: Pcg,
    builder: PodBuilder,
    /// (name, tag) choices with popularity weights.
    choices: Vec<(String, String)>,
    weights: Vec<f64>,
    cfg: WorkloadConfig,
}

impl WorkloadGen {
    pub fn new(registry: &Registry, cfg: WorkloadConfig) -> WorkloadGen {
        let mut choices: Vec<(String, String)> = registry
            .all_manifests()
            .filter(|m| match &cfg.image_allowlist {
                Some(allow) => allow.iter().any(|a| *a == m.name),
                None => true,
            })
            .map(|m| (m.name.clone(), m.tag.clone()))
            .collect();
        choices.sort(); // deterministic order independent of map iteration
        assert!(!choices.is_empty(), "workload: empty image catalog");
        let weights = match cfg.popularity {
            Popularity::Uniform => vec![1.0; choices.len()],
            Popularity::Zipf(s) => (1..=choices.len())
                .map(|r| 1.0 / (r as f64).powf(s))
                .collect(),
        };
        WorkloadGen { rng: Pcg::new(cfg.seed, 7), builder: PodBuilder::new(), choices, weights, cfg }
    }

    /// Generate the next pod.
    pub fn next_pod(&mut self) -> Pod {
        let idx = self.rng.weighted(&self.weights);
        let (name, tag) = &self.choices[idx];
        let cpu = self.rng.range(self.cfg.cpu_range.0 as usize, self.cfg.cpu_range.1 as usize + 1);
        let mem = self.rng.range(self.cfg.mem_range.0 as usize, self.cfg.mem_range.1 as usize + 1);
        let mut pod = self.builder.build(
            &format!("{name}:{tag}"),
            Resources::new(MilliCpu(cpu as u64), Bytes(mem as u64)),
        );
        if let Some((lo, hi)) = self.cfg.duration_range {
            pod = pod.with_duration(self.rng.f64_range(lo, hi));
        }
        pod
    }

    /// Generate a trace of `n` pods.
    pub fn trace(&mut self, n: usize) -> Vec<Pod> {
        (0..n).map(|_| self.next_pod()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let reg = Registry::with_corpus();
        let t1 = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        let t2 = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.image, b.image);
            assert_eq!(a.requests, b.requests);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let reg = Registry::with_corpus();
        let t1 = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        let mut cfg = WorkloadConfig::default();
        cfg.seed = 43;
        let t2 = WorkloadGen::new(&reg, cfg).trace(10);
        assert!(t1.iter().zip(&t2).any(|(a, b)| a.image != b.image));
    }

    #[test]
    fn requests_within_ranges() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(200);
        for p in &trace {
            assert!((100..=800).contains(&p.requests.cpu.0), "{:?}", p.requests.cpu);
            assert!((50_000_000..=500_000_000).contains(&p.requests.memory.0));
        }
    }

    #[test]
    fn allowlist_restricts_images() {
        let reg = Registry::with_corpus();
        let mut cfg = WorkloadConfig::default();
        cfg.image_allowlist = Some(
            crate::registry::hub::paper_images().iter().map(|s| s.to_string()).collect(),
        );
        let trace = WorkloadGen::new(&reg, cfg).trace(100);
        let allowed = crate::registry::hub::paper_images();
        for p in &trace {
            assert!(allowed.contains(&p.image.name.as_str()), "{}", p.image);
        }
    }

    #[test]
    fn zipf_skews_popularity() {
        let reg = Registry::with_corpus();
        let mut cfg = WorkloadConfig::default();
        cfg.popularity = Popularity::Zipf(1.5);
        let trace = WorkloadGen::new(&reg, cfg).trace(500);
        let mut counts = std::collections::HashMap::new();
        for p in &trace {
            *counts.entry(p.image.key()).or_insert(0usize) += 1;
        }
        let max = *counts.values().max().unwrap();
        assert!(max > 500 / 30 * 3, "head image should dominate: max={max}");
    }
}
