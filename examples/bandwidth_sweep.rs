//! Bandwidth sweep (the paper's Fig. 4 scenario as an API example):
//! how the three schedulers behave as the edge uplink degrades, including
//! a constrained shared registry uplink.
//!
//! Run: `cargo run --release --example bandwidth_sweep`

use lrsched::exp::common;

fn main() {
    let trace = common::paper_trace(7, 20);
    println!("per-node bandwidth sweep (total download seconds, 20 pods, 4 nodes)\n");
    println!("{:>10} {:>12} {:>12} {:>12}", "MB/s", "Default", "Layer", "LRScheduler");
    for bw in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0] {
        let reports = common::run_all(4, &trace, |cfg| cfg.bandwidth_mbps = Some(bw));
        println!(
            "{:>10.1} {:>12.1} {:>12.1} {:>12.1}",
            bw,
            reports[0].total_download_secs(),
            reports[1].total_download_secs(),
            reports[2].total_download_secs()
        );
    }

    println!("\nwith a shared 8 MB/s registry uplink (contention):\n");
    println!("{:>10} {:>12} {:>12} {:>12}", "MB/s", "Default", "Layer", "LRScheduler");
    for bw in [4.0, 16.0, 64.0] {
        let reports = common::run_all(4, &trace, |cfg| {
            cfg.bandwidth_mbps = Some(bw);
            cfg.registry_uplink_mbps = Some(8.0);
            cfg.inter_arrival_secs = Some(5.0); // overlapping pulls contend
        });
        println!(
            "{:>10.1} {:>12.1} {:>12.1} {:>12.1}",
            bw,
            reports[0].total_download_secs(),
            reports[1].total_download_secs(),
            reports[2].total_download_secs()
        );
    }
    let base = common::run_all(4, &trace, |cfg| cfg.bandwidth_mbps = Some(2.0));
    let reduction = 1.0 - base[2].total_download_secs() / base[0].total_download_secs();
    println!("\nLRScheduler reduction vs Default at 2 MB/s: {:.0}%", reduction * 100.0);
}
