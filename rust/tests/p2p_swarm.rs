//! Differential tests for the peer-swarm pull source (P2P layer sharing):
//! swarm-on vs swarm-off accounting identity on the same workload, with
//! strictly lower WAN bytes when the swarm is on; byte-identical
//! report/event-log fingerprints across shard counts {1, 4} and repeated
//! runs under churn; seeder-cap saturation forcing registry fallback
//! (and the cap invariant: no seeder ever serves more than C concurrent
//! uploads); a crash mid-seed on either end of a peer transfer releasing
//! its bookings; a registry-outage run that completes via peers
//! without a single stalled pull; and GC eviction on the last seeder
//! dropping the layers from the swarm index (registry fallback).

use lrsched::cluster::{EventKind, Node, NodeId, Pod, PodBuilder, PodId, Resources};
use lrsched::registry::{hub, Registry};
use lrsched::sim::{
    ChurnConfig, EventPayload, SimConfig, SimReport, Simulation, WorkloadConfig, WorkloadGen,
};
use lrsched::util::units::{Bandwidth, Bytes};

fn nodes(n: u32) -> Vec<Node> {
    (0..n)
        .map(|i| {
            Node::new(
                NodeId(i),
                &format!("edge{:02}", i + 1),
                Resources::cores_gb(4.0, 8.0),
                Bytes::from_gb(64.0),
                Bandwidth::from_mbps(10.0),
            )
        })
        .collect()
}

/// Everything observable about a run: the full report plus the audit log.
fn fingerprint(report: &SimReport, sim: &Simulation) -> String {
    format!("{}\n---\n{}", report.render(), sim.events.render())
}

/// Run a seeded random workload, optionally with the swarm on.
fn run_workload(
    seed: u64,
    n_pods: usize,
    n_nodes: u32,
    p2p: Option<(f64, usize)>,
    shards: usize,
    churn: Option<ChurnConfig>,
) -> (SimReport, String) {
    let registry = Registry::with_corpus();
    let wl = WorkloadConfig { seed, duration_range: Some((20.0, 200.0)), ..Default::default() };
    let trace = WorkloadGen::new(&registry, wl).trace(n_pods);
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(0.5);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 10;
    cfg.shards = shards;
    cfg.churn = churn;
    if let Some((lan, cap)) = p2p {
        cfg.p2p_lan_mbps = Some(lan);
        cfg.p2p_seeder_cap = cap;
    }
    let mut sim = Simulation::new(nodes(n_nodes), registry, cfg);
    let report = sim.run_trace(trace);
    sim.state.check_invariants().expect("cluster invariants");
    let fp = fingerprint(&report, &sim);
    (report, fp)
}

#[test]
fn swarm_lowers_wan_bytes_and_keeps_accounting() {
    let (off, _) = run_workload(11, 60, 6, None, 1, None);
    let (on, _) = run_workload(11, 60, 6, Some((125.0, 4)), 1, None);
    assert!(off.accounting_balanced(), "swarm-off run dropped pods");
    assert!(on.accounting_balanced(), "swarm-on run dropped pods");
    assert_eq!(off.submitted, on.submitted);
    // Without the swarm nothing moves over the LAN — and the peak-upload
    // counter stays at its resting zero.
    assert_eq!(off.total_p2p(), Bytes::ZERO);
    assert_eq!(off.peak_peer_uploads, 0);
    // With the swarm, repeat images are served by peers: real LAN traffic,
    // strictly less WAN traffic, and the cap invariant holds.
    assert!(on.total_p2p() > Bytes::ZERO, "no layer was ever peer-served");
    assert!(
        on.total_download() < off.total_download(),
        "swarm-on WAN bytes ({}) not strictly below swarm-off ({})",
        on.total_download(),
        off.total_download()
    );
    assert!(on.peak_peer_uploads >= 1);
    assert!(
        on.peak_peer_uploads <= 4,
        "seeder served {} concurrent uploads, cap is 4",
        on.peak_peer_uploads
    );
}

#[test]
fn swarm_runs_are_byte_identical_across_shards_and_repeats() {
    let churn = || {
        Some(ChurnConfig {
            seed: 9,
            horizon_secs: 120.0,
            joins: 2,
            drains: 1,
            crash_fraction: 0.2,
            outages: 1,
            outage_secs: 20.0,
            ..Default::default()
        })
    };
    let p2p = Some((125.0, 4));
    let (seq, fp_seq) = run_workload(23, 80, 8, p2p, 1, churn());
    let (par, fp_par) = run_workload(23, 80, 8, p2p, 4, churn());
    let (_, fp_par_again) = run_workload(23, 80, 8, p2p, 4, churn());
    assert!(seq.accounting_balanced() && par.accounting_balanced());
    assert!(seq.total_p2p() > Bytes::ZERO, "scenario never exercised the swarm");
    assert!(
        fp_seq == fp_par,
        "4-shard swarm run diverged from sequential; first differing line: {:?}",
        fp_seq.lines().zip(fp_par.lines()).find(|(a, b)| a != b)
    );
    assert!(fp_par == fp_par_again, "4-shard swarm run not reproducible");
}

/// Three identical 3.9-core wordpress pods, one per node, arriving 30 s
/// apart so the first install completes before the second pull plans.
fn saturation_run(cap: usize) -> SimReport {
    let reg = Registry::with_corpus();
    let mut b = PodBuilder::new();
    let pods: Vec<Pod> = (0..3)
        .map(|_| b.build("wordpress:6.4", Resources::cores_gb(3.9, 1.0)).with_duration(600.0))
        .collect();
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(30.0);
    cfg.p2p_lan_mbps = Some(1.0); // slow LAN: seeds stay busy for minutes
    cfg.p2p_seeder_cap = cap;
    let mut sim = Simulation::new(nodes(4), reg, cfg);
    let report = sim.run_trace(pods);
    sim.state.check_invariants().expect("cluster invariants");
    report
}

#[test]
fn saturated_seeder_cap_forces_registry_fallback() {
    // Cap 1: the sole seeder saturates after one layer; the rest of the
    // image — and the whole third pull — fall back to the registry.
    let tight = saturation_run(1);
    assert!(tight.accounting_balanced());
    assert_eq!(
        tight.peak_peer_uploads, 1,
        "a cap of 1 must never let a seeder serve concurrent uploads"
    );
    assert!(tight.total_p2p() > Bytes::ZERO, "the first layer is peer-served");

    // Cap 6: the whole second image rides the LAN instead.
    let wide = saturation_run(6);
    assert!(wide.accounting_balanced());
    assert!(wide.peak_peer_uploads > 1);
    assert!(wide.peak_peer_uploads <= 6, "cap invariant: {} > 6", wide.peak_peer_uploads);
    assert!(
        wide.total_p2p() > tight.total_p2p(),
        "a wider cap must shift more bytes onto the LAN"
    );
    assert!(
        wide.total_download() < tight.total_download(),
        "registry fallback must show up as extra WAN bytes under the tight cap"
    );
}

/// Two wordpress pods on a 1 MB/s LAN (a multi-minute seed window): the
/// first binds node 0 and seeds, the second binds node 1 at t=40 and
/// fetches from it; `crash` takes down a node at `crash_at`, squarely
/// mid-transfer.
fn crash_mid_seed_run(cap: usize, crash: NodeId, crash_at: f64) -> (SimReport, Simulation) {
    let reg = Registry::with_corpus();
    let mut b = PodBuilder::new();
    let pods: Vec<Pod> = (0..2)
        .map(|_| b.build("wordpress:6.4", Resources::cores_gb(3.9, 1.0)).with_duration(600.0))
        .collect();
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(40.0);
    cfg.retry_limit = 20;
    cfg.p2p_lan_mbps = Some(1.0);
    cfg.p2p_seeder_cap = cap;
    let mut sim = Simulation::new(nodes(3), reg, cfg);
    sim.inject_event(crash_at, EventPayload::NodeCrash { node: crash });
    let report = sim.run_trace(pods);
    sim.state.check_invariants().expect("cluster invariants");
    (report, sim)
}

#[test]
fn seeder_crash_mid_seed_removes_it_from_the_swarm() {
    // Pod 0 binds node 0 (idle-cluster tie-break) and seeds; pod 1 binds
    // node 1 at t=40 and peer-fetches the whole image (cap 6 covers all
    // six layers). Node 0 crashes at t=100, mid-seed.
    let wp = hub::corpus().into_iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
    let (report, sim) = crash_mid_seed_run(6, NodeId(0), 100.0);
    assert_eq!(report.nodes_crashed, 1);
    assert_eq!(report.resubmitted, 1, "the seeder's own pod is lost and resubmitted");
    assert!(report.accounting_balanced());
    assert_eq!(report.records.len(), 3, "two first binds plus one rebind");
    // Pod 1's fetch was booked before the crash: the in-flight transfer
    // completes (40 s arrival + 243 MB at 1 MB/s), it does not restart.
    let b_started = sim
        .events
        .all()
        .iter()
        .find(|e| e.pod == PodId(1) && matches!(e.kind, EventKind::Started { .. }))
        .map(|e| e.at)
        .expect("pod 1 started");
    assert!(
        (b_started - (40.0 + wp.total_size.as_mb())).abs() < 1e-6,
        "peer fetch must run to its booked finish, got {b_started}"
    );
    // The rebind of the lost pod plans *after* the crash: the dead node
    // must be gone from every holder list, so the pull is pure WAN.
    let rebind = report.records.last().unwrap();
    assert_eq!(rebind.pod, PodId(0));
    assert_eq!(rebind.p2p, Bytes::ZERO, "crashed seeder still advertised in the swarm");
    assert_eq!(rebind.download, wp.total_size);
}

#[test]
fn downloader_crash_mid_seed_releases_the_upload_slot() {
    // Cap 1: at t=40 pod 1 peer-fetches one layer (the 49 MB base, the
    // cap admits nothing more) with the seeder slot booked until t=89.
    // The *downloader* (node 1) crashes at t=70, mid-transfer.
    let (report, _) = crash_mid_seed_run(1, NodeId(1), 70.0);
    assert_eq!(report.nodes_crashed, 1);
    assert_eq!(report.resubmitted, 1, "the downloader's pod resubmits");
    assert!(report.accounting_balanced());
    assert_eq!(report.peak_peer_uploads, 1, "cap 1 held throughout");
    // The rebind plans at t=70 while the dead fetch's original booking ran
    // to t=89. If the crash failed to release that slot, the sole seeder
    // would look saturated and the rebind would be pure WAN.
    let rebind = report.records.last().unwrap();
    assert_eq!(rebind.pod, PodId(1));
    assert!(
        rebind.p2p > Bytes::ZERO,
        "dead downloader's booking still pinning the seeder's only slot"
    );
}

#[test]
fn registry_outage_is_survivable_when_peers_hold_the_layers() {
    // Pod 0 pulls redis over the WAN at t=0 (done by ~6.4 s) and fills
    // node 0. The registry goes dark from t=30 to t=300. Pod 1 arrives at
    // t=60 needing the same image on another node.
    let run = |p2p: bool| {
        let reg = Registry::with_corpus();
        let mut b = PodBuilder::new();
        let pods: Vec<Pod> = (0..2)
            .map(|_| b.build("redis:7.2", Resources::cores_gb(3.9, 1.0)).with_duration(600.0))
            .collect();
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(60.0);
        if p2p {
            cfg.p2p_lan_mbps = Some(100.0);
        }
        let mut sim = Simulation::new(nodes(2), reg, cfg);
        sim.inject_event(30.0, EventPayload::RegistryOutageStart { until: 300.0 });
        let report = sim.run_trace(pods);
        sim.state.check_invariants().expect("cluster invariants");
        let started = sim
            .events
            .all()
            .iter()
            .find(|e| e.pod == PodId(1) && matches!(e.kind, EventKind::Started { .. }))
            .map(|e| e.at)
            .expect("pod 1 started");
        (report, started)
    };
    let (swarm, started_swarm) = run(true);
    let (registry_only, started_registry) = run(false);
    assert!(swarm.accounting_balanced() && registry_only.accounting_balanced());
    // Registry-only: the pull planned during the outage stalls until the
    // window closes.
    assert_eq!(registry_only.pulls_stalled, 1);
    assert!(started_registry >= 300.0, "stalled pull cannot finish mid-outage");
    // Swarm: every missing layer has a Ready holder, the fetch is
    // LAN-only, and the outage is invisible to it.
    assert_eq!(swarm.pulls_stalled, 0, "peer-only pull must not stall");
    assert!(
        started_swarm < 70.0,
        "peer-served pod must start right after arrival, got {started_swarm}"
    );
    assert_eq!(swarm.records[1].download, Bytes::ZERO, "no WAN bytes during the outage");
    assert!(swarm.records[1].p2p > Bytes::ZERO);
}

#[test]
fn evicting_the_last_seeder_drops_its_layers_from_the_swarm_index() {
    // Cache-policy GC can evict an image from the only node seeding it;
    // the swarm index must stop advertising those layers so the next
    // pull plan falls back to the registry instead of booking a transfer
    // from a node that no longer holds the bytes.
    use lrsched::cluster::ClusterState;
    use lrsched::sim::{plan_sources, LinkModel, SwarmIndex};

    let mut state = ClusterState::new();
    for n in nodes(2) {
        state.add_node(n);
    }
    let redis = hub::corpus().into_iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
    let (ids, layers) = state.intern_image(&redis);
    state.install_image(NodeId(1), &redis.image_ref(), &layers).unwrap();
    let mut ix = SwarmIndex::new();
    ix.sync(&state);

    // Seeded: every missing layer rides the LAN.
    let mut links = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
    let plan = plan_sources(
        &state, &ix, &mut links, Bandwidth::from_mbps(125.0), 16, NodeId(0), &ids, 0.0,
    );
    assert_eq!(plan.peer_layers.len(), ids.len(), "warm seeder must serve every layer");
    assert_eq!(plan.registry_bytes, Bytes::ZERO);

    // GC evicts the image from its last seeder; the kubelet marks the
    // node dirty exactly as the engine's eviction path does.
    state.remove_image(NodeId(1), &redis.image_ref());
    state.evict_layers(NodeId(1), &ids);
    ix.mark_dirty(NodeId(1));
    ix.sync(&state);

    let mut links = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
    let plan = plan_sources(
        &state, &ix, &mut links, Bandwidth::from_mbps(125.0), 16, NodeId(0), &ids, 0.0,
    );
    assert!(
        plan.peer_layers.is_empty(),
        "evicted layers still advertised by the drained seeder"
    );
    assert_eq!(plan.peer_bytes, Bytes::ZERO);
    assert_eq!(plan.registry_bytes, redis.total_size, "plan must fall back to the registry");
}
