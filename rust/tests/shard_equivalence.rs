//! Differential property tests for the sharded engine: random workloads
//! (with and without churn, GC, outages, retries) driven through the
//! sequential engine (`shards = 1`) and the sharded engine (`shards > 1`)
//! must produce **byte-identical** reports and event logs, identical
//! queued-event counts, and the terminal-outcome accounting identity
//! (`completed + failed_pulls + unschedulable + lost_to_crash ==
//! submitted`) — the PR 4 acceptance criteria, in-process.
//!
//! The parked-heavy cases extend the property to the cure-aware-window
//! regime: disk-starved overloads keep the scheduling queue non-empty,
//! so windows must cut at wake-relevant events, and shards {1, 2, 4} —
//! with and without `cure_aware_windows` — must agree byte-for-byte,
//! including the wake-up and retry counters.
//!
//! The CLI-level twin of this suite is the CI `determinism` job, which
//! diffs `scale --shards {1,4} --report-out/--events-out` files.

use lrsched::exp::common;
use lrsched::registry::Registry;
use lrsched::sim::{ChurnConfig, SimConfig, SimReport, Simulation, WorkloadConfig, WorkloadGen};
use lrsched::testing::prop::{check, PropConfig};
use lrsched::util::rng::Pcg;
use lrsched::{prop_assert, prop_assert_eq};

/// Everything observable about a run, rendered losslessly: the full
/// report (counters, records, snapshots, ω trace) plus the audit log.
fn fingerprint(report: &SimReport, sim: &Simulation) -> String {
    format!("{}\n---\n{}", report.render(), sim.events.render())
}

struct Scenario {
    seed: u64,
    n_pods: usize,
    n_nodes: usize,
    arrival: f64,
    gc: bool,
    wake: bool,
    retry_limit: u32,
    churn: Option<ChurnConfig>,
}

fn random_scenario(rng: &mut Pcg) -> Scenario {
    let churn = if rng.chance(0.6) {
        Some(ChurnConfig {
            seed: rng.next_u64(),
            horizon_secs: rng.f64_range(40.0, 120.0),
            joins: rng.range(0, 3),
            drains: rng.range(0, 2),
            crash_fraction: rng.f64_range(0.0, 0.4),
            outages: rng.range(0, 2),
            outage_secs: rng.f64_range(5.0, 25.0),
            ..Default::default()
        })
    } else {
        None
    };
    Scenario {
        seed: rng.next_u64(),
        n_pods: rng.range(30, 90),
        n_nodes: rng.range(2, 9),
        arrival: rng.f64_range(0.2, 1.0),
        gc: rng.chance(0.7),
        wake: rng.chance(0.8),
        retry_limit: rng.range(2, 12) as u32,
        churn,
    }
}

fn run_scenario(sc: &Scenario, shards: usize) -> (String, u64, bool) {
    let registry = Registry::with_corpus();
    let wl = WorkloadConfig {
        seed: sc.seed,
        duration_range: Some((10.0, 120.0)),
        ..Default::default()
    };
    let trace = WorkloadGen::new(&registry, wl).trace(sc.n_pods);
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(sc.arrival);
    cfg.gc_enabled = sc.gc;
    cfg.wake_on_capacity = sc.wake;
    cfg.retry_limit = sc.retry_limit;
    cfg.snapshot_every = 10;
    cfg.shards = shards;
    cfg.churn = sc.churn.clone();
    let mut sim = Simulation::new(common::scale_nodes(sc.n_nodes), registry, cfg);
    let report = sim.run_trace(trace);
    sim.state.check_invariants().expect("cluster invariants");
    (fingerprint(&report, &sim), sim.events_queued(), report.accounting_balanced())
}

#[test]
fn sharded_runs_match_sequential_on_random_workloads() {
    let cases = PropConfig::default();
    // Differential runs are whole simulations; keep the case count sane.
    let cases = PropConfig { cases: cases.cases.clamp(4, 24), ..cases };
    check(cases, |rng, _| {
        let sc = random_scenario(rng);
        let shards = rng.range(2, 5);
        let (seq, ev_seq, balanced_seq) = run_scenario(&sc, 1);
        let (par, ev_par, balanced_par) = run_scenario(&sc, shards);
        prop_assert!(balanced_seq, "sequential run dropped events");
        prop_assert!(balanced_par, "sharded run dropped events");
        prop_assert_eq!(ev_seq, ev_par);
        prop_assert!(
            seq == par,
            "shards={shards} diverged from sequential (pods={}, nodes={}, churn={})\n\
             first differing line: {:?}",
            sc.n_pods,
            sc.n_nodes,
            sc.churn.is_some(),
            seq.lines().zip(par.lines()).find(|(a, b)| a != b),
        );
        Ok(())
    });
}

#[test]
fn sharded_runs_are_stable_across_repeats() {
    // The sharded engine must be deterministic against itself, too: same
    // scenario, same shard count, repeated — identical output (thread
    // scheduling must never leak into results).
    check(PropConfig { cases: 6, ..Default::default() }, |rng, _| {
        let sc = random_scenario(rng);
        let shards = rng.range(2, 5);
        let (a, _, _) = run_scenario(&sc, shards);
        let (b, _, _) = run_scenario(&sc, shards);
        prop_assert!(a == b, "sharded run not reproducible at shards={shards}");
        Ok(())
    });
}

/// A parked-heavy run: disk-starved nodes + fast arrivals so the
/// scheduling queue stays non-empty and windows must cut at
/// wake-relevant events (the cure-aware-windows regime). Returns the
/// fingerprint plus the wake/retry counters and the parked sim-time
/// occupancy so the caller can assert the case is non-vacuous.
fn run_parked_scenario(
    sc: &Scenario,
    shards: usize,
    cure_aware: bool,
) -> (String, u64, u64, u64, f64) {
    let registry = Registry::with_corpus();
    let wl = WorkloadConfig {
        seed: sc.seed,
        duration_range: Some((5.0, 40.0)),
        ..Default::default()
    };
    let trace = WorkloadGen::new(&registry, wl).trace(sc.n_pods);
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(sc.arrival);
    cfg.gc_enabled = sc.gc;
    cfg.wake_on_capacity = sc.wake;
    cfg.retry_limit = sc.retry_limit;
    cfg.snapshot_every = 10;
    cfg.shards = shards;
    cfg.cure_aware_windows = cure_aware;
    cfg.churn = sc.churn.clone();
    // 2 GB disks on a small fleet: pods overload both capacity and disk,
    // so parks (and their cures — terminations, evicting sweeps) are the
    // norm rather than the exception.
    let mut sim =
        Simulation::new(common::scale_nodes_with_disk(sc.n_nodes, 2.0), registry, cfg);
    let report = sim.run_trace(trace);
    sim.state.check_invariants().expect("cluster invariants");
    assert!(report.accounting_balanced(), "parked run dropped events");
    let occupancy = sim.window_stats().parked_busy_secs / sim.clock.now().max(1e-9);
    (fingerprint(&report, &sim), sim.events_queued(), report.wakeups, report.retries, occupancy)
}

fn parked_scenario(rng: &mut Pcg) -> Scenario {
    let mut sc = random_scenario(rng);
    // Force the overload: few nodes, arrivals far faster than the 5–40 s
    // pod durations drain them.
    sc.n_pods = rng.range(50, 120);
    sc.n_nodes = rng.range(2, 5);
    sc.arrival = rng.f64_range(0.05, 0.15);
    sc.gc = true;
    sc.wake = true;
    sc
}

#[test]
fn parked_heavy_runs_match_sequential_across_shard_counts() {
    // The tentpole differential: with pods parked for most of sim-time,
    // shards {1, 2, 4} must stay byte-identical — fingerprints AND the
    // wake-up/retry accounting — and so must the pre-PR conservative
    // guard (`cure_aware_windows = false`).
    let cases = PropConfig::default();
    let cases = PropConfig { cases: cases.cases.clamp(4, 16), ..cases };
    check(cases, |rng, _| {
        let sc = parked_scenario(rng);
        let (seq, ev_seq, wake_seq, retry_seq, occ) = run_parked_scenario(&sc, 1, true);
        prop_assert!(
            occ > 0.0,
            "parked-heavy scenario never parked a pod (pods={}, nodes={}) — vacuous case",
            sc.n_pods,
            sc.n_nodes
        );
        for shards in [2usize, 4] {
            let (par, ev_par, wake_par, retry_par, _) = run_parked_scenario(&sc, shards, true);
            prop_assert_eq!(ev_seq, ev_par);
            prop_assert!(
                wake_seq == wake_par,
                "wake-up accounting diverged at shards={shards}: {wake_seq} vs {wake_par}"
            );
            prop_assert!(
                retry_seq == retry_par,
                "retry accounting diverged at shards={shards}: {retry_seq} vs {retry_par}"
            );
            prop_assert!(
                seq == par,
                "parked shards={shards} diverged from sequential (pods={}, nodes={}, churn={})\n\
                 first differing line: {:?}",
                sc.n_pods,
                sc.n_nodes,
                sc.churn.is_some(),
                seq.lines().zip(par.lines()).find(|(a, b)| a != b),
            );
        }
        // Cure-aware windows vs the conservative guard: purely a window-
        // shape change, never an observable one.
        let (cons, ev_cons, wake_cons, retry_cons, _) = run_parked_scenario(&sc, 4, false);
        prop_assert_eq!(ev_seq, ev_cons);
        prop_assert_eq!(wake_seq, wake_cons);
        prop_assert_eq!(retry_seq, retry_cons);
        prop_assert!(seq == cons, "conservative-guard run diverged from sequential");
        Ok(())
    });
}

#[test]
fn parked_soak_keeps_the_queue_busy_and_the_lanes_identical() {
    // One pinned overload soak (no randomness): the queue must sit
    // non-empty for ≥80% of sim-time — the regime the `engine_parked`
    // bench measures — and shards {1, 4} must agree byte-for-byte, with
    // and without cure-aware windows.
    let sc = Scenario {
        seed: 77,
        n_pods: 400,
        n_nodes: 3,
        arrival: 0.08,
        gc: true,
        wake: true,
        retry_limit: 10,
        churn: Some(ChurnConfig {
            seed: 9,
            horizon_secs: 32.0,
            joins: 1,
            drains: 1,
            crash_fraction: 0.1,
            outages: 1,
            outage_secs: 10.0,
            ..Default::default()
        }),
    };
    let (seq, ev_seq, wake_seq, retry_seq, occ) = run_parked_scenario(&sc, 1, true);
    assert!(
        occ >= 0.8,
        "soak parked the queue only {:.0}% of sim-time; the overload is miscalibrated",
        occ * 100.0
    );
    assert!(wake_seq > 0, "an 80%-parked overload must wake pods on capacity");
    let (par, ev_par, wake_par, retry_par, _) = run_parked_scenario(&sc, 4, true);
    let (cons, ev_cons, wake_cons, retry_cons, _) = run_parked_scenario(&sc, 4, false);
    assert_eq!(ev_seq, ev_par);
    assert_eq!(ev_seq, ev_cons);
    assert_eq!((wake_seq, retry_seq), (wake_par, retry_par));
    assert_eq!((wake_seq, retry_seq), (wake_cons, retry_cons));
    assert!(seq == par, "parked soak diverged at shards=4");
    assert!(seq == cons, "parked soak diverged under the conservative guard");
}

#[test]
fn shard_count_never_changes_the_accounting_identity() {
    // A 500-pod churny soak at 4 shards: the accounting identity and the
    // byte-identity hold at a size where windows actually batch.
    let sc = Scenario {
        seed: 2024,
        n_pods: 500,
        n_nodes: 24,
        arrival: 0.25,
        gc: true,
        wake: true,
        retry_limit: 10,
        churn: Some(ChurnConfig {
            seed: 7,
            horizon_secs: 125.0,
            joins: 3,
            drains: 2,
            crash_fraction: 0.1,
            outages: 1,
            outage_secs: 30.0,
            ..Default::default()
        }),
    };
    let (seq, ev_seq, balanced_seq) = run_scenario(&sc, 1);
    let (par, ev_par, balanced_par) = run_scenario(&sc, 4);
    assert!(balanced_seq && balanced_par, "accounting identity violated");
    assert_eq!(ev_seq, ev_par, "queued-event counts diverged");
    assert!(seq == par, "4-shard soak diverged from the sequential engine");
}
