//! Micro-benchmark harness (criterion is not in the vendored set).
//! Runs warmup + measured iterations, reports min/mean/p50/p95 wall time.
//! Used by the `rust/benches/*.rs` targets (harness = false).

use std::time::Instant;

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Measured iterations (after warmup).
    pub iters: usize,
    /// Mean wall time per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Fastest iteration, nanoseconds.
    pub min_ns: f64,
    /// Median iteration, nanoseconds.
    pub p50_ns: f64,
    /// 95th-percentile iteration, nanoseconds.
    pub p95_ns: f64,
}

impl BenchResult {
    /// One formatted result row (pair with [`header`]).
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}   ({} iters)",
            self.name,
            fmt_ns(self.min_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

/// Column header row for [`BenchResult::report`] output.
pub fn header() -> String {
    format!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "min", "p50", "mean", "p95"
    )
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Time `f` with automatic iteration count targeting ~`budget_ms` of
/// measurement after 3 warmup runs.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    for _ in 0..3 {
        f();
    }
    let per_iter = t0.elapsed().as_nanos() as f64 / 3.0;
    let iters = ((budget_ms as f64 * 1e6 / per_iter.max(1.0)).ceil() as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: samples[0],
        p50_ns: samples[samples.len() / 2],
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 5, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.iters >= 5);
        assert!(r.min_ns > 0.0);
        assert!(r.mean_ns >= r.min_ns);
        assert!(r.p95_ns >= r.p50_ns);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
