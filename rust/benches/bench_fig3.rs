//! Bench target regenerating paper Fig. 3 (a–f): performance with
//! different numbers of nodes, plus wall-time for the full experiment.
//! Run: `cargo bench --bench bench_fig3`

use lrsched::exp::fig3;
use lrsched::testing::bench::{bench, header};

fn main() {
    let fig = fig3::run(42, 20);
    print!("{}", fig.print());

    println!("\n{}", header());
    let r = bench("fig3: 9 runs (3 scheds x 3 node counts) + 3d probes", 2_000, || {
        std::hint::black_box(fig3::run(42, 20));
    });
    println!("{}", r.report());
}
