//! Scheduling queue — FIFO of pending pods with a back-off parking lot for
//! unschedulable ones, a small analog of kube-scheduler's active/backoff
//! queues so the simulator can retry pods that failed filtering.

use crate::cluster::PodId;
use std::collections::VecDeque;

#[derive(Debug, Clone, Default)]
pub struct SchedulingQueue {
    active: VecDeque<PodId>,
    /// (pod, retry-at time).
    backoff: Vec<(PodId, f64)>,
    pub backoff_secs: f64,
}

impl SchedulingQueue {
    pub fn new() -> SchedulingQueue {
        SchedulingQueue { active: VecDeque::new(), backoff: Vec::new(), backoff_secs: 5.0 }
    }

    pub fn push(&mut self, pod: PodId) {
        self.active.push_back(pod);
    }

    /// Next pod to schedule, if any.
    pub fn pop(&mut self) -> Option<PodId> {
        self.active.pop_front()
    }

    /// Park an unschedulable pod until `now + backoff_secs`; returns the
    /// release time so event-driven callers can schedule the release.
    pub fn park(&mut self, pod: PodId, now: f64) -> f64 {
        let release_at = now + self.backoff_secs;
        self.backoff.push((pod, release_at));
        release_at
    }

    /// Move pods whose back-off expired back to the active queue.
    pub fn release_due(&mut self, now: f64) -> usize {
        let mut released = 0;
        let mut i = 0;
        while i < self.backoff.len() {
            if self.backoff[i].1 <= now {
                let (pod, _) = self.backoff.swap_remove(i);
                self.active.push_back(pod);
                released += 1;
            } else {
                i += 1;
            }
        }
        released
    }

    /// Earliest back-off expiry (for event-driven simulation).
    pub fn next_release_at(&self) -> Option<f64> {
        self.backoff.iter().map(|(_, t)| *t).min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty() && self.backoff.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn parked_len(&self) -> usize {
        self.backoff.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = SchedulingQueue::new();
        q.push(PodId(1));
        q.push(PodId(2));
        assert_eq!(q.pop(), Some(PodId(1)));
        assert_eq!(q.pop(), Some(PodId(2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backoff_and_release() {
        let mut q = SchedulingQueue::new();
        assert_eq!(q.park(PodId(1), 0.0), 5.0);
        assert!(q.pop().is_none());
        assert_eq!(q.parked_len(), 1);
        assert_eq!(q.next_release_at(), Some(5.0));
        assert_eq!(q.release_due(4.9), 0);
        assert_eq!(q.release_due(5.0), 1);
        assert_eq!(q.pop(), Some(PodId(1)));
        assert!(q.is_empty());
    }

    #[test]
    fn multiple_backoffs_release_independently() {
        let mut q = SchedulingQueue::new();
        q.park(PodId(1), 0.0);
        q.park(PodId(2), 3.0);
        assert_eq!(q.release_due(5.0), 1);
        assert_eq!(q.parked_len(), 1);
        assert_eq!(q.release_due(8.0), 1);
    }
}
