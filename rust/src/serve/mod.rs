//! `lrsched serve` — the simulator's scoring core as an online decision
//! service.
//!
//! The paper's headline claim is *full process automation from task
//! information acquisition to container deployment*: LRScheduler is a
//! live scheduler, not a replay harness. This module closes that gap
//! without forking the engine: a serve session feeds pod and node
//! lifecycle events — NDJSON over stdin ([`Session`]) or a localhost
//! HTTP endpoint ([`run_http`]) — into the *same* deterministic
//! discrete-event engine every batch experiment uses, through the same
//! [`crate::sim::ArrivalSource`] pipeline (a
//! [`crate::sim::StreamSource`] instead of a trace or workload source).
//! Each pod event runs the full filter → layer-score → dynamic-weight →
//! bind pipeline and emits one NDJSON decision line: chosen node,
//! per-plugin score breakdown, estimated pull bytes split WAN/P2P, and
//! wall-clock decision latency in microseconds.
//!
//! Because serve and batch share one code path, equivalence is testable:
//! [`run_shadow`] replays a trace through the session and holds the
//! decision stream byte-identical to the `scale --trace` replay — the
//! house differential style ([`crate::sim::shard`],
//! [`crate::sim::cache`]) extended to the service boundary. See
//! `docs/SERVE.md` for the operator's guide (protocol reference, flags,
//! copy-pasteable sessions) and `docs/ARCHITECTURE.md`, "Serve mode",
//! for the byte-identity argument.
//!
//! Module layout mirrors the pipeline: [`protocol`] (wire types),
//! [`codec`] (line decode with strict/lenient [`crate::sim::ErrorMode`]
//! handling), [`session`] (the live loop over an open engine stream),
//! [`shadow`] (the differential), [`http`] (the listener front-end).

pub mod codec;
pub mod http;
pub mod protocol;
pub mod session;
pub mod shadow;

pub use codec::{decode_line, encode_line};
pub use http::run_http;
pub use protocol::{error_to_json, InEvent, ServeError};
pub use session::{Session, SessionStats};
pub use shadow::run_shadow;
