//! Experiment drivers — one module per figure/table of the paper's
//! evaluation (§VI), plus the shared testbed preset. Each driver exposes
//! `run(...) -> Struct` (consumed by benches and tests) and a `print()`
//! that emits the same rows/series the paper reports.

pub mod common;
pub mod export;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod report;
pub mod table1;
