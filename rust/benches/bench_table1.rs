//! Bench target regenerating paper Table I: per-container download size,
//! time, and STD for 20 containers under all three schedulers.
//! Run: `cargo bench --bench bench_table1`

use lrsched::exp::table1;
use lrsched::testing::bench::{bench, header};

fn main() {
    let t = table1::run(42, 20, 4);
    print!("{}", t.print());

    println!("\n{}", header());
    let r = bench("table1: 3 sequential 20-pod runs", 2_000, || {
        std::hint::black_box(table1::run(42, 20, 4));
    });
    println!("{}", r.report());
}
