"""L2: the batched node-scoring pipeline (paper Algorithm 1) as a JAX
graph calling the L1 Pallas kernel for the Eq.-2 reduction.

This is the compute the rust coordinator offloads per scheduling cycle:
given the node-layer presence matrix, the pod's requirement vector, layer
sizes, per-node resource usage, the default-scheduler score vector and a
feasibility mask, produce final scores, layer scores, the dynamic weights
(Eq. 13), and the argmax (Eq. 5).

Lowered once by aot.py to HLO text per shape variant; never imported at
runtime.
"""

import jax
import jax.numpy as jnp

from .kernels.ref import NEG_MASK
from .kernels.shared_bytes import shared_bytes

# AOT shape variants: (name, n_nodes, n_layers). The rust runtime pads its
# inputs to the smallest variant that fits (runtime/scorer.rs).
VARIANTS = (
    ("small", 16, 256),
    ("large", 64, 1024),
)


def score_pipeline(
    present,
    req,
    sizes_mb,
    cpu_used,
    cpu_cap,
    mem_used,
    mem_cap,
    k8s_score,
    feasible,
    params,
):
    """Algorithm-1 scoring; same contract as ref.score_pipeline_ref but the
    Eq.-2 reduction runs through the Pallas kernel."""
    w1 = params[0]
    w2 = params[1]
    h_size = params[2]
    h_cpu = params[3]
    h_std = params[4]

    shared = shared_bytes(present, req, sizes_mb)  # L1 kernel (Eq. 2)
    total = jnp.sum(req * sizes_mb)
    layer = jnp.where(total > 0.0, shared / jnp.maximum(total, 1e-30) * 100.0, 0.0)  # Eq. 3

    cpu_frac = cpu_used / jnp.maximum(cpu_cap, 1e-30)  # Eq. 12
    mem_frac = mem_used / jnp.maximum(mem_cap, 1e-30)
    s_std = jnp.abs(cpu_frac - mem_frac) / 2.0  # Eq. 11

    gate = (shared > h_size) & (cpu_frac < h_cpu) & (s_std < h_std)  # Eq. 13
    omega = jnp.where(gate, w1, w2)

    final = jnp.where(feasible > 0.5, omega * layer + k8s_score, NEG_MASK)  # Eq. 4
    best = jnp.argmax(final).astype(jnp.int32)  # Eq. 5
    return final, layer, omega, best


def example_args(n_nodes, n_layers):
    """ShapeDtypeStructs for AOT lowering of one variant."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n_nodes, n_layers), f32),  # present
        jax.ShapeDtypeStruct((n_layers,), f32),  # req
        jax.ShapeDtypeStruct((n_layers,), f32),  # sizes_mb
        jax.ShapeDtypeStruct((n_nodes,), f32),  # cpu_used
        jax.ShapeDtypeStruct((n_nodes,), f32),  # cpu_cap
        jax.ShapeDtypeStruct((n_nodes,), f32),  # mem_used
        jax.ShapeDtypeStruct((n_nodes,), f32),  # mem_cap
        jax.ShapeDtypeStruct((n_nodes,), f32),  # k8s_score
        jax.ShapeDtypeStruct((n_nodes,), f32),  # feasible
        jax.ShapeDtypeStruct((5,), f32),  # params
    )
