//! The discrete-event simulation engine — also the API-server facade: it
//! receives pod requests, drives the watcher, invokes the scheduler, binds
//! pods, and runs the kubelet pull/start lifecycle against the link model.
//!
//! The engine is a true event-driven core: arrivals, pull completions,
//! terminations, watcher ticks, GC sweeps, and scheduling-queue back-off
//! releases are timestamped events popped in order from one
//! [`EventQueue`] (`sim::events`). Unschedulable pods are not dropped:
//! they park in a [`SchedulingQueue`] with back-off and retry until they
//! bind or exhaust `SimConfig::retry_limit`.
//!
//! Two arrival modes reproduce the paper's protocols:
//! - **Sequential** (`inter_arrival_secs = None`): deploy, wait until the
//!   container is ready (or the pod gives up), then submit the next pod —
//!   §VI-B's measurement protocol for Table I / Fig. 5.
//! - **Timed arrivals** (`Some(dt)`): pods arrive every `dt` seconds and
//!   pulls overlap — the load-test mode used by the concurrency tests and
//!   the 100k-pod `scale` harness.

use super::bandwidth::LinkModel;
use super::clock::Clock;
use super::download::PullManager;
use super::events::{EventPayload, EventQueue};
use super::kubelet::{self, ImageLayerStore, PendingStart};
use super::metrics::{self, ClusterSnapshot, PodRecord};
use crate::cluster::{ClusterState, EventKind, EventLog, Node, Pod, PodId};
use crate::registry::{MetadataCache, Registry, Watcher};
use crate::sched::queue::SchedulingQueue;
use crate::sched::rl::{RlParams, RlScheduler};
use crate::sched::scoring::ScoringBackend;
use crate::sched::{CycleContext, FrameworkConfig, LrScheduler, WeightParams};
use crate::util::units::{Bandwidth, Bytes};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};

/// Which of the paper's three schedulers to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerChoice {
    /// Kubernetes default plugins only.
    Default,
    /// Layer scheduler with static ω = 4.
    Layer,
    /// The paper's LRScheduler (dynamic ω).
    LR,
    /// Contextual-bandit scheduler — the paper's §VII future-work
    /// direction (long-term optimization via reinforcement learning).
    Rl,
}

impl SchedulerChoice {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerChoice::Default => "Default",
            SchedulerChoice::Layer => "Layer",
            SchedulerChoice::LR => "LRScheduler",
            SchedulerChoice::Rl => "RLScheduler",
        }
    }

    pub fn all() -> [SchedulerChoice; 3] {
        [SchedulerChoice::Default, SchedulerChoice::Layer, SchedulerChoice::LR]
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub scheduler: SchedulerChoice,
    pub params: WeightParams,
    pub framework: FrameworkConfig,
    /// Override every node's bandwidth (Fig. 4 sweeps this).
    pub bandwidth_mbps: Option<f64>,
    /// Optional shared registry uplink cap.
    pub registry_uplink_mbps: Option<f64>,
    /// None ⇒ sequential protocol; Some(dt) ⇒ timed arrivals.
    pub inter_arrival_secs: Option<f64>,
    /// Enable kubelet image GC under disk pressure.
    pub gc_enabled: bool,
    /// GC sweep trigger: disk usage fraction (kubelet
    /// ImageGCHighThresholdPercent analog).
    pub gc_high_pct: f64,
    /// GC sweep target: evict unused images until usage ≤ this fraction
    /// (ImageGCLowThresholdPercent analog).
    pub gc_low_pct: f64,
    /// Cloud-edge collaborative layer sharing (paper §VII): when set,
    /// layers cached on peer edge nodes transfer at this LAN bandwidth
    /// instead of being re-downloaded from the registry.
    pub p2p_lan_mbps: Option<f64>,
    /// Registry watcher poll interval (paper §V-1 default: 10 s).
    pub watcher_interval_secs: f64,
    /// Retries granted to an unschedulable pod after its first failed
    /// cycle before it is counted unschedulable (kube-scheduler's backoff
    /// queue retries indefinitely; a cap keeps simulations terminating).
    pub retry_limit: u32,
    /// Back-off before an unschedulable pod re-enters the active queue.
    pub retry_backoff_secs: f64,
    /// Record a cluster snapshot every N successful placements (1 = every
    /// placement, the paper-experiment default; the 100k-pod scale harness
    /// raises this to bound memory). A final snapshot is always taken.
    pub snapshot_every: usize,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            scheduler: SchedulerChoice::LR,
            params: WeightParams::default(),
            framework: FrameworkConfig::default(),
            bandwidth_mbps: None,
            registry_uplink_mbps: None,
            inter_arrival_secs: None,
            gc_enabled: false,
            gc_high_pct: 0.85,
            gc_low_pct: 0.70,
            p2p_lan_mbps: None,
            watcher_interval_secs: crate::registry::watcher::DEFAULT_POLL_SECS,
            retry_limit: 3,
            retry_backoff_secs: 5.0,
            snapshot_every: 1,
        }
    }
}

/// Aggregated outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub scheduler: &'static str,
    pub records: Vec<PodRecord>,
    pub snapshots: Vec<ClusterSnapshot>,
    /// Pods submitted to the API server.
    pub submitted: usize,
    /// Pods that exhausted their retries without binding.
    pub unschedulable: usize,
    /// Bound pods whose image install wedged (ImagePullBackOff analog).
    pub failed_pulls: usize,
    /// Scheduling-cycle failures that parked a pod for retry.
    pub retries: u64,
    pub omega1_used: u64,
    pub omega2_used: u64,
    /// Decisions taken at a mid-range ω (ThreeLevel / Linear policies).
    pub omega_mid_used: u64,
    pub omega_trace: Vec<f64>,
}

impl SimReport {
    pub fn total_download(&self) -> Bytes {
        self.records.iter().map(|r| r.download).sum()
    }

    pub fn total_download_secs(&self) -> f64 {
        self.records.iter().map(|r| r.download_secs).sum()
    }

    pub fn final_std(&self) -> f64 {
        self.snapshots.last().map(|s| s.std_score).unwrap_or(0.0)
    }

    /// Pods the scheduler bound (includes pulls that later wedged).
    pub fn deployed(&self) -> usize {
        self.records.len()
    }

    /// Pods that bound *and* started (deployed minus wedged pulls).
    pub fn completed(&self) -> usize {
        self.records.len() - self.failed_pulls
    }

    /// No dropped events: every submitted pod is accounted for as
    /// completed, wedged, or unschedulable-after-retries.
    pub fn accounting_balanced(&self) -> bool {
        self.completed() + self.failed_pulls + self.unschedulable == self.submitted
    }
}

/// The scheduler driving a simulation: the paper's Algorithm-1 family or
/// the §VII learning-based extension.
enum SchedImpl {
    Lr(LrScheduler),
    Rl(RlScheduler),
}

impl SchedImpl {
    fn build(cfg: &SimConfig) -> SchedImpl {
        let framework = cfg.framework.build("sim");
        match cfg.scheduler {
            SchedulerChoice::Default => SchedImpl::Lr(LrScheduler::default_scheduler(framework)),
            SchedulerChoice::Layer => SchedImpl::Lr(LrScheduler::layer_scheduler(framework)),
            SchedulerChoice::LR => {
                let mut s = LrScheduler::lr_scheduler(framework);
                s.params = cfg.params;
                SchedImpl::Lr(s)
            }
            SchedulerChoice::Rl => {
                SchedImpl::Rl(RlScheduler::new(framework, RlParams::default(), 2024))
            }
        }
    }
}

/// Monotonic suffix so every `Simulation` gets its own metadata-cache path
/// (the seed hard-coded one `/tmp` path, leaking state between runs that
/// chose to persist the cache).
static CACHE_PATH_SEQ: AtomicU64 = AtomicU64::new(0);

fn unique_cache_path() -> String {
    std::env::temp_dir()
        .join(format!(
            "lrsched-sim-cache-{}-{}.json",
            std::process::id(),
            CACHE_PATH_SEQ.fetch_add(1, Ordering::Relaxed)
        ))
        .to_string_lossy()
        .into_owned()
}

/// The simulator.
pub struct Simulation {
    pub state: ClusterState,
    pub registry: Registry,
    pub cache: MetadataCache,
    watcher: Watcher,
    pub clock: Clock,
    links: LinkModel,
    pulls: PullManager,
    scheduler: SchedImpl,
    /// In-flight pulls keyed by pod (completion fires as an event).
    pending: HashMap<PodId, PendingStart>,
    /// containerd-image-store analog, scoped to this simulation.
    images: ImageLayerStore,
    /// The unified discrete-event queue.
    queue: EventQueue,
    /// Active/back-off queues for pods awaiting (re)scheduling.
    sched_queue: SchedulingQueue,
    /// Failed scheduling cycles per still-pending pod.
    retry_counts: HashMap<PodId, u32>,
    /// Sequential-protocol pods not yet submitted (next arrives when the
    /// current pod resolves: starts, wedges, or gives up).
    seq_backlog: VecDeque<Pod>,
    /// Is a WatcherTick event currently scheduled?
    watcher_armed: bool,
    pub events: EventLog,
    pub records: Vec<PodRecord>,
    pub snapshots: Vec<ClusterSnapshot>,
    pub submitted: usize,
    pub unschedulable: usize,
    pub failed_pulls: usize,
    pub retries: u64,
    cfg: SimConfig,
}

impl Simulation {
    pub fn new(nodes: Vec<Node>, registry: Registry, cfg: SimConfig) -> Simulation {
        let mut state = ClusterState::new();
        let mut bws = Vec::new();
        for mut n in nodes {
            if let Some(mbps) = cfg.bandwidth_mbps {
                n.bandwidth = Bandwidth::from_mbps(mbps);
            }
            bws.push(n.bandwidth);
            state.add_node(n);
        }
        let mut links = LinkModel::new(bws);
        if let Some(up) = cfg.registry_uplink_mbps {
            links.registry_uplink = Some(Bandwidth::from_mbps(up));
        }
        let scheduler = SchedImpl::build(&cfg);
        let n_nodes = state.node_count();
        let mut sched_queue = SchedulingQueue::new();
        sched_queue.backoff_secs = cfg.retry_backoff_secs;
        Simulation {
            state,
            registry,
            cache: MetadataCache::new(&unique_cache_path()),
            watcher: Watcher::new(cfg.watcher_interval_secs),
            clock: Clock::new(),
            links,
            pulls: PullManager::new(n_nodes),
            scheduler,
            pending: HashMap::new(),
            images: ImageLayerStore::new(),
            queue: EventQueue::new(),
            sched_queue,
            retry_counts: HashMap::new(),
            seq_backlog: VecDeque::new(),
            watcher_armed: false,
            events: EventLog::new(),
            records: Vec::new(),
            snapshots: Vec::new(),
            submitted: 0,
            unschedulable: 0,
            failed_pulls: 0,
            retries: 0,
            cfg,
        }
    }

    /// Install the XLA scoring backend (otherwise native math runs).
    /// The RL scheduler has no dense-scoring path; it keeps native math.
    pub fn with_backend(mut self, backend: Box<dyn ScoringBackend>) -> Simulation {
        self.scheduler = match SchedImpl::build(&self.cfg) {
            SchedImpl::Lr(s) => SchedImpl::Lr(s.with_backend(backend)),
            rl @ SchedImpl::Rl(_) => rl,
        };
        self
    }

    /// Total events ever queued (observability for the scale harness).
    pub fn events_queued(&self) -> u64 {
        self.queue.pushed_total
    }

    // --- event loop -------------------------------------------------------

    /// Schedule the next watcher poll if none is pending.
    fn arm_watcher(&mut self, now: f64) {
        if self.watcher_armed {
            return;
        }
        let at = self.watcher.next_poll_at().max(now);
        if at.is_finite() {
            self.queue.push(at, EventPayload::WatcherTick);
            self.watcher_armed = true;
        }
    }

    /// Pop and dispatch events until the simulation quiesces. The watcher
    /// re-arms itself only while real work remains, so the loop terminates.
    fn run_events(&mut self) {
        while let Some(ev) = self.queue.pop() {
            if ev.payload.is_watcher() && !self.queue.has_pending_work() {
                // Nothing left that a poll could affect: let the sim drain.
                self.watcher_armed = false;
                continue;
            }
            self.clock.advance_to(ev.at);
            let t = self.clock.now();
            match ev.payload {
                EventPayload::WatcherTick => {
                    self.watcher_armed = false;
                    self.watcher.poll(t, &self.registry, &mut self.cache);
                    let next = self.watcher.next_poll_at();
                    if self.queue.has_pending_work() && next.is_finite() && next > t {
                        self.queue.push(next, EventPayload::WatcherTick);
                        self.watcher_armed = true;
                    }
                }
                EventPayload::Arrival { pod } => {
                    let pid = self.state.submit_pod(pod);
                    self.submitted += 1;
                    self.events.record(t, pid, EventKind::Submitted);
                    self.sched_queue.push(pid);
                    self.drain_sched_queue();
                }
                EventPayload::BackoffRelease => {
                    if self.sched_queue.release_due(t) > 0 {
                        self.drain_sched_queue();
                    }
                }
                EventPayload::PullComplete { pod } => {
                    if let Some(p) = self.pending.remove(&pod) {
                        let duration = self.state.pod(pod).and_then(|x| x.duration_secs);
                        let started = self.finish_pull(p);
                        self.pulls.gc(t);
                        if started {
                            if let Some(d) = duration {
                                self.queue.push(t + d, EventPayload::PodTermination { pod });
                            }
                        }
                        self.chain_next_arrival(t);
                    }
                }
                EventPayload::PodTermination { pod } => {
                    // Resources release; layers stay cached until GC needs
                    // them (image retention is the kubelet's GC job).
                    let _ = self.state.unbind(pod);
                    if self.cfg.gc_enabled {
                        self.queue.push(t, EventPayload::GcSweep);
                    }
                }
                EventPayload::GcSweep => self.gc_pressure_sweep(),
            }
        }
    }

    /// In the sequential protocol, the next pod arrives once the current
    /// one resolves (container started, pull wedged, or retries exhausted).
    fn chain_next_arrival(&mut self, t: f64) {
        if self.cfg.inter_arrival_secs.is_none() {
            if let Some(pod) = self.seq_backlog.pop_front() {
                self.queue.push(t, EventPayload::Arrival { pod });
            }
        }
    }

    fn drain_sched_queue(&mut self) {
        while let Some(pid) = self.sched_queue.pop() {
            self.try_schedule(pid);
        }
    }

    // --- scheduling cycle -------------------------------------------------

    /// One scheduling cycle for `pid`: filter + score + bind + begin pull,
    /// or park with back-off / give up.
    fn try_schedule(&mut self, pid: PodId) {
        let now = self.clock.now();
        self.gc_pressure_sweep();

        let pod = self.state.pod(pid).cloned().expect("queued pod exists");
        let (meta, required, bytes) = CycleContext::prepare(&mut self.state, &self.cache, &pod);
        let ctx = CycleContext::new(&self.state, &pod, meta, required.clone(), bytes);
        let decision = match &mut self.scheduler {
            SchedImpl::Lr(s) => s.schedule(&ctx),
            SchedImpl::Rl(s) => s.schedule(&ctx).map(|node| {
                // Build an equivalent decision record for the RL pick.
                let n = ctx.state.node(node);
                let local = crate::sched::layer_score::local_bytes(&ctx, n);
                crate::sched::Decision {
                    node,
                    final_score: 0.0,
                    layer_score: crate::sched::layer_score::layer_sharing_score(
                        local,
                        ctx.required_bytes,
                    ),
                    k8s_score: 0.0,
                    omega: 0.0,
                    download_cost: crate::sched::layer_score::download_cost(&ctx, n),
                }
            }),
        };
        let decision = match decision {
            Ok(d) => d,
            Err(u) => {
                drop(ctx);
                let attempts = {
                    let c = self.retry_counts.entry(pid).or_insert(0);
                    *c += 1;
                    *c
                };
                if attempts > self.cfg.retry_limit {
                    // Retries exhausted: the pod is unschedulable for good.
                    self.retry_counts.remove(&pid);
                    self.unschedulable += 1;
                    self.events
                        .record(now, pid, EventKind::Unschedulable { reason: u.to_string() });
                    self.chain_next_arrival(now);
                } else {
                    // Park with back-off and retry (kube-scheduler's
                    // unschedulable queue, instead of dropping the pod).
                    self.retries += 1;
                    let release_at = self.sched_queue.park(pid, now);
                    self.queue.push(release_at, EventPayload::BackoffRelease);
                    self.events.record(
                        now,
                        pid,
                        EventKind::Unschedulable {
                            reason: format!(
                                "parked for retry {attempts}/{} (0/{} nodes available)",
                                self.cfg.retry_limit,
                                u.rejections.len()
                            ),
                        },
                    );
                }
                return;
            }
        };
        drop(ctx);
        self.retry_counts.remove(&pid);

        self.events.record(
            now,
            pid,
            EventKind::Scheduled { node: decision.node, score: decision.final_score },
        );
        self.state.bind(pid, decision.node).expect("bind after schedule");

        let pending = kubelet::begin_pull(
            &self.state,
            &mut self.pulls,
            &mut self.links,
            now,
            pid,
            decision.node,
            &pod.image,
            &required,
            self.cfg.p2p_lan_mbps.map(Bandwidth::from_mbps),
        );
        self.events.record(
            now,
            pid,
            EventKind::PullStarted {
                node: decision.node,
                bytes: pending.plan.bytes,
                layers: pending.plan.new_layers.len(),
            },
        );
        let (wan_bytes, p2p_bytes) = (pending.wan_bytes, pending.p2p_bytes);
        let ready_at = pending.plan.ready_at;
        let download_secs = ready_at - now;
        self.pending.insert(pid, pending);
        self.queue.push(ready_at, EventPayload::PullComplete { pod: pid });

        let std_after = metrics::cluster_std(&self.state);
        if let SchedImpl::Rl(s) = &mut self.scheduler {
            // Online reward: the paper's two objectives as one scalar.
            s.learn(wan_bytes.as_mb(), std_after);
        }
        self.records.push(PodRecord {
            pod: pid,
            image: pod.image.key(),
            node: self.state.node(decision.node).name.clone(),
            download: wan_bytes,
            p2p: p2p_bytes,
            download_secs,
            std_after,
            omega: decision.omega,
            layer_score: decision.layer_score,
            final_score: decision.final_score,
            at: now,
        });
        let every = self.cfg.snapshot_every.max(1);
        if self.records.len() % every == 0 {
            self.snapshots.push(metrics::snapshot(&self.state, now));
        }
    }

    // --- kubelet ----------------------------------------------------------

    /// Kubelet image GC: when a node crosses the high disk-usage threshold
    /// (kubelet's ImageGCHighThresholdPercent analog, 85%), evict unused
    /// images down to the low threshold (70%).
    fn gc_pressure_sweep(&mut self) {
        if !self.cfg.gc_enabled {
            return;
        }
        let now = self.clock.now();
        for i in 0..self.state.node_count() {
            let node = crate::cluster::NodeId(i as u32);
            let n = self.state.node(node);
            let (disk, used) = (n.disk.0 as f64, n.disk_used.0 as f64);
            if disk > 0.0 && used / disk > self.cfg.gc_high_pct {
                // Free down to the low-threshold usage.
                let target = Bytes((disk * (1.0 - self.cfg.gc_low_pct)) as u64);
                let freed = kubelet::gc_images(&mut self.state, &self.images, node, target);
                if freed > Bytes::ZERO {
                    self.events.record(
                        now,
                        crate::cluster::PodId(u64::MAX), // node-level event
                        EventKind::Evicted { node, bytes: freed },
                    );
                }
            }
        }
    }

    /// Install the pulled image and start the container. Returns whether
    /// the container actually started.
    fn finish_pull(&mut self, p: PendingStart) -> bool {
        let now = p.plan.ready_at;
        if self.cfg.gc_enabled {
            let need = p.layers.difference_bytes(
                &self.state.node(p.node).layers,
                &self.state.interner,
            );
            if need > self.state.node(p.node).disk_free() {
                let freed = kubelet::gc_images(&mut self.state, &self.images, p.node, need);
                if freed > Bytes::ZERO {
                    self.events.record(
                        now,
                        p.pod,
                        EventKind::Evicted { node: p.node, bytes: freed },
                    );
                }
            }
        }
        match kubelet::complete_pull(&mut self.state, &p) {
            Ok(_) => {
                self.images.remember(&p.image, &p.layers);
                self.events.record(
                    now,
                    p.pod,
                    EventKind::PullFinished { node: p.node, secs: now - p.plan.start },
                );
                self.events.record(now, p.pod, EventKind::Started { node: p.node });
                true
            }
            Err(e) => {
                // Disk overcommitted by concurrent binds: the pod wedges
                // (ImagePullBackOff analog). Counted, surfaced in events.
                self.failed_pulls += 1;
                self.events.record(
                    now,
                    p.pod,
                    EventKind::Unschedulable { reason: format!("pull failed: {e}") },
                );
                false
            }
        }
    }

    // --- public driving API ----------------------------------------------

    /// Deploy one pod at the current virtual time and run the event loop to
    /// quiescence. Returns false if the scheduler never found a feasible
    /// node (even after retries).
    pub fn deploy(&mut self, pod: Pod) -> bool {
        let pid = pod.id;
        let now = self.clock.now();
        self.arm_watcher(now);
        self.queue.push(now, EventPayload::Arrival { pod });
        self.run_events();
        // A record exists iff the pod bound. (The binding itself may be
        // gone already: a finite-duration pod can terminate inside the
        // same drain.)
        self.records.iter().rev().any(|r| r.pod == pid)
    }

    /// Run a whole trace through the event queue. Timed mode enqueues all
    /// arrivals up front; sequential mode chains each arrival to the
    /// previous pod's resolution. Returns once every event — including
    /// terminations and back-off releases due after the last pull — fired.
    pub fn run_trace(&mut self, pods: Vec<Pod>) -> SimReport {
        let t0 = self.clock.now();
        self.arm_watcher(t0);
        match self.cfg.inter_arrival_secs {
            Some(dt) => {
                for (i, pod) in pods.into_iter().enumerate() {
                    self.queue.push(t0 + i as f64 * dt, EventPayload::Arrival { pod });
                }
            }
            None => {
                self.seq_backlog.extend(pods);
                if let Some(pod) = self.seq_backlog.pop_front() {
                    self.queue.push(t0, EventPayload::Arrival { pod });
                }
            }
        }
        self.run_events();
        // Final snapshot so end-of-run metrics (final_std, disk usage) see
        // the fully drained state — terminations included.
        self.snapshots.push(metrics::snapshot(&self.state, self.clock.now()));
        self.report()
    }

    pub fn report(&self) -> SimReport {
        let (w1, w2, wmid, trace) = match &self.scheduler {
            SchedImpl::Lr(s) => (
                s.stats.omega1_used,
                s.stats.omega2_used,
                s.stats.omega_mid_used,
                s.stats.omega_trace.clone(),
            ),
            SchedImpl::Rl(_) => (0, 0, 0, Vec::new()),
        };
        SimReport {
            scheduler: self.cfg.scheduler.label(),
            records: self.records.clone(),
            snapshots: self.snapshots.clone(),
            submitted: self.submitted,
            unschedulable: self.unschedulable,
            failed_pulls: self.failed_pulls,
            retries: self.retries,
            omega1_used: w1,
            omega2_used: w2,
            omega_mid_used: wmid,
            omega_trace: trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use crate::cluster::Resources;
    use crate::sim::workload::{WorkloadConfig, WorkloadGen};

    fn nodes(n: u32) -> Vec<Node> {
        (0..n)
            .map(|i| {
                Node::new(
                    NodeId(i),
                    &format!("worker{}", i + 1),
                    Resources::cores_gb(4.0, 4.0),
                    Bytes::from_gb(30.0),
                    Bandwidth::from_mbps(10.0),
                )
            })
            .collect()
    }

    #[test]
    fn sequential_run_deploys_everything() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        let mut sim = Simulation::new(nodes(4), reg, SimConfig::default());
        let report = sim.run_trace(trace);
        assert_eq!(report.deployed(), 10);
        assert_eq!(report.submitted, 10);
        assert_eq!(report.unschedulable, 0);
        assert_eq!(report.failed_pulls, 0);
        assert!(report.accounting_balanced());
        assert!(report.total_download() > Bytes::ZERO);
        sim.state.check_invariants().unwrap();
        // Clock advanced by the total download time.
        assert!(sim.clock.now() > 0.0);
    }

    #[test]
    fn repeat_images_download_less() {
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let first = gen.next_pod();
        // Same image five times.
        let mut pods = vec![first.clone()];
        for _ in 0..4 {
            let mut p = gen.next_pod();
            p.image = first.image.clone();
            pods.push(p);
        }
        let mut sim = Simulation::new(nodes(3), reg, SimConfig::default());
        let report = sim.run_trace(pods);
        // After the first few placements every node can hold the image, so
        // at least one later deployment is a zero-byte pull.
        assert!(report.records.iter().skip(1).any(|r| r.download == Bytes::ZERO));
    }

    #[test]
    fn lr_downloads_less_than_default() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(20);
        let mut total = std::collections::HashMap::new();
        for choice in SchedulerChoice::all() {
            let mut cfg = SimConfig::default();
            cfg.scheduler = choice;
            let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
            let report = sim.run_trace(trace.clone());
            assert_eq!(report.deployed(), 20, "{choice:?}");
            total.insert(choice.label(), report.total_download());
        }
        assert!(
            total["LRScheduler"] < total["Default"],
            "LR {} !< Default {}",
            total["LRScheduler"],
            total["Default"]
        );
        // Layer (static ω=4) also beats Default; its ordering vs. LR varies
        // per trace (the paper's Table I shows the same per-step flips).
        assert!(
            total["Layer"] < total["Default"],
            "Layer {} !< Default {}",
            total["Layer"],
            total["Default"]
        );
        let _ = reg;
    }

    #[test]
    fn timed_arrivals_overlap_pulls() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(8);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.deployed(), 8);
        // Arrivals every 1s while pulls take tens of seconds ⇒ the clock
        // at the last arrival is ~8s but the drain runs far past it.
        assert!(sim.clock.now() > 8.0);
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn omega_stats_recorded_for_lr_only() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(12);
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
        let report = sim.run_trace(trace.clone());
        assert_eq!(report.omega1_used + report.omega2_used, 12);
        assert_eq!(report.omega_mid_used, 0, "TwoLevel has no mid weight");
        assert_eq!(report.omega_trace.len(), 12);

        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::Default;
        let mut sim = Simulation::new(nodes(4), Registry::with_corpus(), cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.omega1_used + report.omega2_used, 0);
        let _ = reg;
    }

    #[test]
    fn unschedulable_pods_counted_not_fatal() {
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let mut big = gen.next_pod();
        big.requests = Resources::cores_gb(64.0, 64.0);
        let ok = gen.next_pod();
        let mut sim = Simulation::new(nodes(2), reg, SimConfig::default());
        let report = sim.run_trace(vec![big, ok]);
        assert_eq!(report.unschedulable, 1);
        assert_eq!(report.deployed(), 1);
        // The impossible pod exercised the back-off queue before giving up.
        assert_eq!(report.retries as u32, SimConfig::default().retry_limit);
        assert!(report.accounting_balanced());
    }

    #[test]
    fn terminations_fire_after_final_pull() {
        // Seed bug: the drain only advanced to the last pull's ready_at,
        // so terminations due later never fired and resources stayed bound.
        let reg = Registry::with_corpus();
        let mut gen = WorkloadGen::new(&reg, WorkloadConfig::default());
        let pods: Vec<Pod> = (0..6).map(|_| gen.next_pod().with_duration(40.0)).collect();
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        let mut sim = Simulation::new(nodes(3), reg, cfg);
        let report = sim.run_trace(pods);
        assert_eq!(report.deployed(), 6);
        for node in sim.state.nodes() {
            assert_eq!(node.used, Resources::ZERO, "{}: resources still bound", node.name);
            assert!(node.pods.is_empty());
        }
        // The final snapshot reflects the drained cluster.
        let last = report.snapshots.last().unwrap();
        assert_eq!(last.cpu_util, 0.0);
        assert_eq!(last.mem_util, 0.0);
        assert!((report.final_std() - 0.0).abs() < 1e-12);
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn retried_pod_binds_when_capacity_frees() {
        let reg = Registry::with_corpus();
        let mut b = crate::cluster::PodBuilder::new();
        // Pod A fills the single node; pod B must wait for A to die.
        let a = b.build("redis:7.2", Resources::cores_gb(3.9, 0.5)).with_duration(30.0);
        let bpod = b.build("nginx:1.25", Resources::cores_gb(3.9, 0.5));
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(1.0);
        cfg.retry_limit = 20;
        let mut sim = Simulation::new(nodes(1), reg, cfg);
        let report = sim.run_trace(vec![a, bpod]);
        assert_eq!(report.deployed(), 2, "retry must eventually bind pod B");
        assert_eq!(report.unschedulable, 0);
        assert!(report.retries > 0, "pod B must have parked at least once");
        assert!(report.accounting_balanced());
        sim.state.check_invariants().unwrap();
    }

    #[test]
    fn per_instance_cache_paths_differ() {
        let a = Simulation::new(nodes(1), Registry::with_corpus(), SimConfig::default());
        let b = Simulation::new(nodes(1), Registry::with_corpus(), SimConfig::default());
        assert_ne!(a.cache.cache_file, b.cache.cache_file);
    }

    #[test]
    fn snapshot_cadence_bounds_memory() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(20);
        let mut cfg = SimConfig::default();
        cfg.snapshot_every = 7;
        let mut sim = Simulation::new(nodes(4), reg, cfg);
        let report = sim.run_trace(trace);
        // 20 placements / 7 = 2 periodic snapshots + 1 final.
        assert_eq!(report.snapshots.len(), 3);
    }

    #[test]
    fn accounting_balances_under_churn_and_pressure() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(
            &reg,
            WorkloadConfig {
                seed: 3,
                duration_range: Some((10.0, 120.0)),
                ..WorkloadConfig::default()
            },
        )
        .trace(60);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(0.5);
        cfg.gc_enabled = true;
        let mut sim = Simulation::new(nodes(2), reg, cfg);
        let report = sim.run_trace(trace);
        assert_eq!(report.submitted, 60);
        assert!(
            report.accounting_balanced(),
            "completed {} + failed {} + unschedulable {} != submitted {}",
            report.completed(),
            report.failed_pulls,
            report.unschedulable,
            report.submitted
        );
        sim.state.check_invariants().unwrap();
    }
}
