//! Pod specifications. The paper's tasks map 1:1 to single-container pods
//! (§VI-B: "our Pods contain only one container"), with resource requests,
//! an image reference, and the standard placement constraints consumed by
//! the default plugins: node selectors, affinity, tolerations, topology
//! spread, and volume claims.

use super::resources::Resources;
use crate::registry::ImageRef;
use crate::util::units::Bytes;
use std::collections::BTreeMap;

/// Dense pod identity assigned by the API server.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PodId(pub u64);

/// Node-affinity term: a label that must (or should) match.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AffinityTerm {
    /// Node-label key to match.
    pub key: String,
    /// Matches when the node has `key` with a value in `values`.
    pub values: Vec<String>,
    /// Soft-affinity weight (1..=100); `required` terms filter instead.
    pub weight: u32,
}

/// Node affinity: required terms filter nodes, preferred terms score them.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NodeAffinity {
    /// Terms that filter nodes (all must match).
    pub required: Vec<AffinityTerm>,
    /// Terms that score nodes (weighted).
    pub preferred: Vec<AffinityTerm>,
}

/// Inter-pod affinity term: attract to (or repel from) nodes running pods
/// with a given label, within a topology domain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PodAffinityTerm {
    /// Pod label selector: key=value.
    pub label_key: String,
    /// Value the selector matches.
    pub label_value: String,
    /// Topology key defining the co-location domain (e.g. `zone`,
    /// `kubernetes.io/hostname`).
    pub topology_key: String,
    /// Soft-term weight.
    pub weight: u32,
    /// true ⇒ anti-affinity (repel).
    pub anti: bool,
}

/// Toleration of a node taint (exact key/value match, as the paper's
/// TaintToleration plugin needs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Toleration {
    /// Tolerated taint key.
    pub key: String,
    /// Tolerated taint value.
    pub value: String,
}

/// Topology-spread constraint: spread pods matching our labels evenly
/// across domains of `topology_key`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologySpread {
    /// Node-label key defining the spread domains.
    pub topology_key: String,
    /// Maximum allowed count difference between domains.
    pub max_skew: u32,
}

/// A persistent-volume claim (consumed by the VolumeBinding plugin).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VolumeClaim {
    /// Requested volume size.
    pub size: Bytes,
}

/// A pod: one container (image + requests) plus placement constraints.
#[derive(Debug, Clone, PartialEq)]
pub struct Pod {
    /// Dense pod identity assigned by the API server.
    pub id: PodId,
    /// Pod name (`pod-<id>` from the builder).
    pub name: String,
    /// Container image reference.
    pub image: ImageRef,
    /// Resource requests scheduling reserves.
    pub requests: Resources,
    /// Pod labels (matched by inter-pod affinity and topology spread).
    pub labels: BTreeMap<String, String>,
    /// Hard node-label selector.
    pub node_selector: BTreeMap<String, String>,
    /// Node affinity (required filters + preferred scores).
    pub affinity: NodeAffinity,
    /// Inter-pod (anti-)affinity terms.
    pub pod_affinity: Vec<PodAffinityTerm>,
    /// Tolerated node taints.
    pub tolerations: Vec<Toleration>,
    /// Topology-spread constraints.
    pub topology_spread: Vec<TopologySpread>,
    /// Persistent-volume claims.
    pub volume_claims: Vec<VolumeClaim>,
    /// Which scheduler handles this pod (`schedulerName` in K8s).
    pub scheduler_name: String,
    /// Simulated run time after start; None = runs forever (a service).
    /// Finite durations model batch/churn workloads: on completion the
    /// pod's resources release and its image may become GC-eligible.
    pub duration_secs: Option<f64>,
}

impl Pod {
    /// A pod with no constraints, handled by the `lrscheduler` profile.
    pub fn new(id: PodId, name: &str, image: ImageRef, requests: Resources) -> Pod {
        Pod {
            id,
            name: name.to_string(),
            image,
            requests,
            labels: BTreeMap::new(),
            node_selector: BTreeMap::new(),
            affinity: NodeAffinity::default(),
            pod_affinity: Vec::new(),
            tolerations: Vec::new(),
            topology_spread: Vec::new(),
            volume_claims: Vec::new(),
            scheduler_name: "lrscheduler".to_string(),
            duration_secs: None,
        }
    }

    /// Builder: give the pod a finite run time.
    pub fn with_duration(mut self, secs: f64) -> Pod {
        self.duration_secs = Some(secs);
        self
    }

    /// Builder: add a label.
    pub fn with_label(mut self, key: &str, value: &str) -> Pod {
        self.labels.insert(key.to_string(), value.to_string());
        self
    }

    /// Builder: add a hard node-selector entry.
    pub fn with_selector(mut self, key: &str, value: &str) -> Pod {
        self.node_selector.insert(key.to_string(), value.to_string());
        self
    }

    /// Builder: tolerate a taint.
    pub fn with_toleration(mut self, key: &str, value: &str) -> Pod {
        self.tolerations.push(Toleration { key: key.to_string(), value: value.to_string() });
        self
    }

    /// Builder: add a volume claim.
    pub fn with_volume(mut self, size: Bytes) -> Pod {
        self.volume_claims.push(VolumeClaim { size });
        self
    }

    /// Does any toleration match this taint exactly?
    pub fn tolerates(&self, taint_key: &str, taint_value: &str) -> bool {
        self.tolerations
            .iter()
            .any(|t| t.key == taint_key && t.value == taint_value)
    }
}

/// Builder used by tests and the workload generator.
pub struct PodBuilder {
    next_id: u64,
}

impl PodBuilder {
    /// A builder starting at pod id 0.
    pub fn new() -> PodBuilder {
        PodBuilder { next_id: 0 }
    }

    /// Build a pod with the next dense id (image parsed as `name[:tag]`).
    pub fn build(&mut self, image: &str, requests: Resources) -> Pod {
        let id = PodId(self.next_id);
        self.next_id += 1;
        Pod::new(id, &format!("pod-{}", id.0), ImageRef::parse(image), requests)
    }
}

impl Default for PodBuilder {
    fn default() -> Self {
        PodBuilder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_assigns_unique_ids() {
        let mut b = PodBuilder::new();
        let p1 = b.build("redis:7.2", Resources::cores_gb(0.5, 0.5));
        let p2 = b.build("nginx:1.25", Resources::cores_gb(0.1, 0.1));
        assert_ne!(p1.id, p2.id);
        assert_eq!(p1.image, ImageRef::new("redis", "7.2"));
    }

    #[test]
    fn tolerations() {
        let mut b = PodBuilder::new();
        let p = b
            .build("redis", Resources::ZERO)
            .with_toleration("edge", "unstable");
        assert!(p.tolerates("edge", "unstable"));
        assert!(!p.tolerates("edge", "other"));
        assert!(!p.tolerates("other", "unstable"));
    }

    #[test]
    fn labels_and_selectors() {
        let mut b = PodBuilder::new();
        let p = b
            .build("redis", Resources::ZERO)
            .with_label("app", "cache")
            .with_selector("disk", "ssd");
        assert_eq!(p.labels.get("app").map(|s| s.as_str()), Some("cache"));
        assert_eq!(p.node_selector.get("disk").map(|s| s.as_str()), Some("ssd"));
    }
}
