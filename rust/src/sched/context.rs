//! Scheduling-cycle context — the snapshot handed to every extension point,
//! mirroring `framework.CycleState` + `framework.NodeInfo` in Kubernetes
//! (paper §V-2/§V-3: pod info from `v1.Pod`, node info from
//! `framework.Handle`, layer info from `cache.json`).

use crate::cluster::{ClusterState, Pod};
use crate::registry::{ImageMetadata, LayerSet, MetadataCache};
use crate::util::units::Bytes;

/// One scheduling cycle for one pod.
#[derive(Debug)]
pub struct CycleContext<'a> {
    /// Cluster snapshot the cycle scores against.
    pub state: &'a ClusterState,
    /// The pod being scheduled.
    pub pod: &'a Pod,
    /// Layer metadata for the pod's image, from the registry cache
    /// (None when the cache has never seen the image — the scheduler then
    /// treats the image as all-remote with unknown size).
    pub image_meta: Option<&'a ImageMetadata>,
    /// The pod's required layers L_c, interned.
    pub required_layers: LayerSet,
    /// Total bytes of L_c (denominator of Eq. 3).
    pub required_bytes: Bytes,
}

impl<'a> CycleContext<'a> {
    /// Build a cycle context: resolve the pod's image in the metadata cache
    /// and intern its layers. Interning may extend the interner, hence the
    /// `&mut ClusterState` — callers pass the state back in immutably.
    pub fn prepare(
        state: &mut ClusterState,
        cache: &'a MetadataCache,
        pod: &Pod,
    ) -> (Option<&'a ImageMetadata>, LayerSet, Bytes) {
        match cache.lookup(&pod.image) {
            Some(meta) => {
                let (_, set) = state.intern_image(meta);
                (Some(meta), set, meta.total_size)
            }
            None => (None, LayerSet::new(), Bytes::ZERO),
        }
    }

    /// Assemble a context from already-prepared parts (see
    /// [`CycleContext::prepare`]).
    pub fn new(
        state: &'a ClusterState,
        pod: &'a Pod,
        image_meta: Option<&'a ImageMetadata>,
        required_layers: LayerSet,
        required_bytes: Bytes,
    ) -> CycleContext<'a> {
        CycleContext { state, pod, image_meta, required_layers, required_bytes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Node, NodeId, PodBuilder, Resources};
    use crate::registry::{Registry, Watcher};
    use crate::util::units::{Bandwidth, Bytes as B};

    #[test]
    fn prepare_resolves_layers() {
        let mut state = ClusterState::new();
        state.add_node(Node::new(
            NodeId(0),
            "n0",
            Resources::cores_gb(4.0, 4.0),
            B::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        ));
        let reg = Registry::with_corpus();
        let mut cache = MetadataCache::new("/tmp/unused.json");
        Watcher::with_default_interval().poll(0.0, &reg, &mut cache);

        let mut b = PodBuilder::new();
        let pod = b.build("redis:7.2", Resources::cores_gb(0.5, 0.5));
        let (meta, layers, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        assert!(meta.is_some());
        assert_eq!(layers.len(), meta.unwrap().layers.len());
        assert_eq!(bytes, meta.unwrap().total_size);

        let unknown = b.build("no-such-image:1", Resources::ZERO);
        let (meta2, layers2, bytes2) = CycleContext::prepare(&mut state, &cache, &unknown);
        assert!(meta2.is_none());
        assert!(layers2.is_empty());
        assert_eq!(bytes2, B::ZERO);
    }
}
