//! Cluster event records — the audit stream the API server emits as pods
//! move through the scheduling → pull → run lifecycle. Experiments consume
//! these to build per-step tables (paper Table I).

use super::node::NodeId;
use super::pod::PodId;
use crate::util::units::Bytes;

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Pod submitted to the API server.
    Submitted,
    /// Scheduler picked a node (with the winning score).
    Scheduled { node: NodeId, score: f64 },
    /// Scheduler found no feasible node.
    Unschedulable { reason: String },
    /// Layer pull started on the node.
    PullStarted { node: NodeId, bytes: Bytes, layers: usize },
    /// All layers present; container starting.
    PullFinished { node: NodeId, secs: f64 },
    /// Container running.
    Started { node: NodeId },
    /// Image layers evicted from a node under disk pressure.
    Evicted { node: NodeId, bytes: Bytes },
}

#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Virtual time (seconds).
    pub at: f64,
    pub pod: PodId,
    pub kind: EventKind,
}

/// Append-only event log.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<Event>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    pub fn record(&mut self, at: f64, pod: PodId, kind: EventKind) {
        self.events.push(Event { at, pod, kind });
    }

    pub fn all(&self) -> &[Event] {
        &self.events
    }

    pub fn for_pod(&self, pod: PodId) -> impl Iterator<Item = &Event> {
        self.events.iter().filter(move |e| e.pod == pod)
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut log = EventLog::new();
        log.record(0.0, PodId(1), EventKind::Submitted);
        log.record(0.1, PodId(1), EventKind::Scheduled { node: NodeId(2), score: 88.0 });
        log.record(0.2, PodId(2), EventKind::Submitted);
        assert_eq!(log.len(), 3);
        assert_eq!(log.for_pod(PodId(1)).count(), 2);
        assert_eq!(log.for_pod(PodId(9)).count(), 0);
        assert!(matches!(
            log.for_pod(PodId(1)).last().unwrap().kind,
            EventKind::Scheduled { node: NodeId(2), .. }
        ));
    }
}
