//! Shadow mode: replay a trace through the serve path and hold its
//! decision stream byte-identical to the batch `scale --trace` replay —
//! the house differential-test style extended to the service boundary.
//!
//! Two simulations are built from the same trace file and the same
//! config. The **batch** side runs [`Simulation::run_source`] over the
//! trace's [`crate::sim::TraceSource`] with decision capture on — this
//! is exactly what `lrsched scale --trace` executes. The **serve** side
//! opens the trace a second time and feeds the same `(offset, pod)`
//! pairs one at a time through [`Session::submit_pod`] — the live
//! session path, minus only the NDJSON input codec (which the protocol
//! golden tests and the stdin fixture cover). Every decision line, and
//! the full report fingerprint ([`crate::sim::SimReport::render`]), must
//! match byte-for-byte; the first divergence is reported with its index
//! and both lines. Shadow pins `latency_us` to 0 on both sides so the
//! streams are comparable.

use super::session::Session;
use crate::exp::{common, export};
use crate::sim::{ArrivalSource, ErrorMode, SimConfig, Simulation, TraceOptions, TraceReplay};

/// Run the shadow differential over the trace at `path` (see the module
/// docs). `nodes`/`disk_gb` size the fleet like `scale --nodes
/// --disk-gb`; `cfg` must be the same config the batch comparison run
/// would use (the `serve` CLI builds it with `scale`'s defaults).
/// Returns the serve-side stream — every decision line plus the summary
/// line, ready to print — or an error describing the trace failure or
/// the first divergence.
pub fn run_shadow(
    path: &std::path::Path,
    opts: &TraceOptions,
    nodes: usize,
    disk_gb: f64,
    cfg: &SimConfig,
) -> Result<Vec<String>, String> {
    // --- batch reference: the scale --trace replay ---------------------
    let replay = TraceReplay::open(path, opts).map_err(|e| e.to_string())?;
    let expected = replay.stats.events;
    let registry = replay.synthesize_registry();
    let mut batch_sim =
        Simulation::new(common::scale_nodes_with_disk(nodes, disk_gb), registry, cfg.clone());
    batch_sim.collect_decisions(true);
    let source = replay.into_source();
    let batch_slot = source.error_slot();
    let batch_report = batch_sim.run_source(Box::new(source));
    if let Some(e) = batch_slot.lock().ok().and_then(|mut s| s.take()) {
        return Err(format!("batch replay failed: {e}"));
    }
    let batch_lines: Vec<String> = batch_sim
        .take_decisions()
        .iter()
        .map(|d| export::decision_to_json(d, 0).to_string())
        .collect();

    // --- serve side: the same arrivals through the session path --------
    let replay2 = TraceReplay::open(path, opts).map_err(|e| e.to_string())?;
    let registry2 = replay2.synthesize_registry();
    let mut serve_sim =
        Simulation::new(common::scale_nodes_with_disk(nodes, disk_gb), registry2, cfg.clone());
    let mut trace_src = replay2.into_source();
    let serve_slot = trace_src.error_slot();
    let mut lines = Vec::new();
    let mut session = Session::new(&mut serve_sim, ErrorMode::Strict, Box::new(|| 0_u64));
    while let Some((offset, pod)) = trace_src.next_arrival() {
        session.submit_pod(offset, pod, &mut lines);
    }
    let serve_report = session.finish(&mut lines);
    // Decisions drained inside finish (binds in the post-stream drain
    // tail) count too; everything before the trailing summary line.
    let decisions = session.stats.decisions;
    if let Some(e) = serve_slot.lock().ok().and_then(|mut s| s.take()) {
        return Err(format!("serve replay failed: {e}"));
    }
    if serve_report.submitted != expected {
        return Err(format!(
            "serve replay ended early: submitted {} of {} expected pods",
            serve_report.submitted, expected
        ));
    }

    // --- the differential ----------------------------------------------
    let serve_decisions = &lines[..decisions];
    if batch_lines.len() != serve_decisions.len() {
        return Err(format!(
            "shadow divergence: batch bound {} pods, serve bound {}",
            batch_lines.len(),
            serve_decisions.len()
        ));
    }
    for (i, (b, s)) in batch_lines.iter().zip(serve_decisions).enumerate() {
        if b != s {
            return Err(format!(
                "shadow divergence at decision {i}:\n  batch: {b}\n  serve: {s}"
            ));
        }
    }
    let (br, sr) = (batch_report.render(), serve_report.render());
    if br != sr {
        let diff = br
            .lines()
            .zip(sr.lines())
            .position(|(a, b)| a != b)
            .map(|i| format!(" (first differing line {})", i + 1))
            .unwrap_or_default();
        return Err(format!("shadow divergence: report fingerprints differ{diff}"));
    }
    Ok(lines)
}
