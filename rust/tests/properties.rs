//! Property-based tests over the paper's invariants, using the in-repo
//! harness (`testing::prop`). Case count scales with LRSCHED_PROP_CASES.

use lrsched::cluster::{NodeId, PodBuilder, Resources};
use lrsched::registry::{hub, LayerId, LayerInterner, LayerSet};
use lrsched::sched::dynamic_weight::WeightParams;
use lrsched::sched::scoring::{NativeScorer, ScoreInputs, ScoringBackend, NEG_MASK};
use lrsched::sched::{default_framework, CycleContext, LrScheduler};
use lrsched::sim::{
    ChurnConfig, SchedulerChoice, SimConfig, SimReport, Simulation, WorkloadConfig, WorkloadGen,
};
use lrsched::registry::Registry;
use lrsched::testing::fixtures;
use lrsched::testing::prop::{check, PropConfig};
use lrsched::util::json::{self, Json};
use lrsched::util::rng::Pcg;
use lrsched::util::units::Bytes;
use lrsched::{prop_assert, prop_assert_eq};
use std::collections::HashSet;

#[test]
fn layerset_matches_hashset_model() {
    check(PropConfig::default(), |rng, _| {
        let mut interner = LayerInterner::new();
        let universe = 200;
        for i in 0..universe {
            interner.intern(&format!("sha256:{i}"), Bytes::from_mb(rng.f64_range(0.1, 100.0)));
        }
        let mut set = LayerSet::new();
        let mut model: HashSet<u32> = HashSet::new();
        for _ in 0..rng.range(1, 200) {
            let id = rng.range(0, universe) as u32;
            match rng.range(0, 3) {
                0 => {
                    set.insert(LayerId(id));
                    model.insert(id);
                }
                1 => {
                    set.remove(LayerId(id));
                    model.remove(&id);
                }
                _ => {
                    prop_assert_eq!(set.contains(LayerId(id)), model.contains(&id));
                }
            }
        }
        prop_assert_eq!(set.len(), model.len());
        let collected: HashSet<u32> = set.iter().map(|l| l.0).collect();
        prop_assert_eq!(collected, model);
        Ok(())
    });
}

#[test]
fn eq1_eq2_partition_the_required_bytes() {
    // D_c^n + C_c^n = Σ_{l∈L_c} d_l for random layer sets (Eqs. 1+2).
    check(PropConfig::default(), |rng, _| {
        let mut interner = LayerInterner::new();
        for i in 0..100 {
            interner.intern(&format!("sha256:{i}"), Bytes(rng.below(200_000_000)));
        }
        let rand_set = |rng: &mut Pcg| -> LayerSet {
            (0..100)
                .filter(|_| rng.chance(0.3))
                .map(|i| LayerId(i as u32))
                .collect()
        };
        let req = rand_set(rng);
        let node = rand_set(rng);
        let local = req.intersection_bytes(&node, &interner);
        let missing = req.difference_bytes(&node, &interner);
        prop_assert_eq!(local + missing, req.total_bytes(&interner));
        Ok(())
    });
}

#[test]
fn scorer_outputs_always_bounded() {
    check(PropConfig::default(), |rng, _| {
        let n = rng.range(1, 40);
        let l = rng.range(1, 300);
        let mut x = ScoreInputs::zeros(n, l, WeightParams::default());
        for v in x.present.iter_mut() {
            *v = if rng.chance(0.5) { 1.0 } else { 0.0 };
        }
        for j in 0..l {
            x.req[j] = if rng.chance(0.5) { 1.0 } else { 0.0 };
            x.sizes_mb[j] = rng.f64_range(0.0, 1000.0) as f32;
        }
        for i in 0..n {
            x.cpu_cap[i] = rng.f64_range(1.0, 8000.0) as f32;
            x.mem_cap[i] = rng.f64_range(1.0, 8e9) as f32;
            x.cpu_used[i] = rng.f64_range(0.0, x.cpu_cap[i] as f64) as f32;
            x.mem_used[i] = rng.f64_range(0.0, x.mem_cap[i] as f64) as f32;
            x.k8s_score[i] = rng.f64_range(0.0, 1100.0) as f32;
            x.feasible[i] = if rng.chance(0.7) { 1.0 } else { 0.0 };
        }
        x.feasible[rng.range(0, n)] = 1.0;
        let out = NativeScorer.score(&x);
        for i in 0..n {
            prop_assert!(
                (0.0..=100.0 + 1e-3).contains(&out.layer_score[i]),
                "layer score out of range: {}",
                out.layer_score[i]
            );
            let w = out.omega[i];
            prop_assert!(w == 0.5 || w == 2.0, "omega {w}");
            if x.feasible[i] < 0.5 {
                prop_assert_eq!(out.final_score[i], NEG_MASK);
            } else {
                prop_assert!(out.final_score[i].is_finite(), "non-finite score");
            }
        }
        prop_assert!(x.feasible[out.best] > 0.5, "argmax picked infeasible node");
        Ok(())
    });
}

#[test]
fn scheduler_respects_feasibility_and_argmax() {
    // On random clusters: the LR decision is feasible, and no other
    // feasible node has a strictly higher combined score.
    check(PropConfig { cases: 24, ..Default::default() }, |rng, _| {
        let n_nodes = rng.range(2, 6) as u32;
        let mut state = fixtures::random_cluster(rng, n_nodes);
        let cache = fixtures::corpus_cache();
        // Warm random nodes with random images.
        let corpus = hub::corpus();
        for _ in 0..rng.range(0, 6) {
            let m = &corpus[rng.range(0, corpus.len())];
            let node = NodeId(rng.range(0, state.node_count()) as u32);
            let (_, layers) = state.intern_image(m);
            let _ = state.install_image(node, &m.image_ref(), &layers);
        }
        let m = &corpus[rng.range(0, corpus.len())];
        let pod = PodBuilder::new().build(
            &format!("{}:{}", m.name, m.tag),
            Resources::cores_gb(rng.f64_range(0.1, 1.0), rng.f64_range(0.1, 1.0)),
        );
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        let mut lr = LrScheduler::lr_scheduler(default_framework());
        match lr.schedule(&ctx) {
            Err(_) => Ok(()), // everything filtered is legal
            Ok(d) => {
                let node = state.node(d.node);
                prop_assert!(
                    pod.requests.fits_within(&node.available()),
                    "scheduled onto a node that cannot fit the pod"
                );
                prop_assert!(d.layer_score >= -1e9 && d.layer_score <= 100.0 + 1e-6, "layer score");
                Ok(())
            }
        }
    });
}

#[test]
fn simulation_preserves_cluster_invariants() {
    // Eq. 6/7/8 and the accounting invariants hold after arbitrary runs.
    check(PropConfig { cases: 16, ..Default::default() }, |rng, case| {
        let registry = Registry::with_corpus();
        let wl = WorkloadConfig { seed: case as u64, ..Default::default() };
        let n_pods = rng.range(1, 30);
        let trace = WorkloadGen::new(&registry, wl).trace(n_pods);
        let mut cfg = SimConfig::default();
        cfg.scheduler = [SchedulerChoice::Default, SchedulerChoice::Layer, SchedulerChoice::LR]
            [rng.range(0, 3)];
        cfg.gc_enabled = rng.chance(0.5);
        if rng.chance(0.5) {
            cfg.inter_arrival_secs = Some(rng.f64_range(0.5, 10.0));
        }
        let mut sim = Simulation::new(
            lrsched::exp::common::paper_nodes(rng.range(2, 6)),
            registry,
            cfg,
        );
        let report = sim.run_trace(trace);
        sim.state.check_invariants().map_err(|e| e)?;
        for node in sim.state.nodes() {
            prop_assert!(node.disk_used <= node.disk, "Eq. 6 violated");
            prop_assert!(node.pods.len() <= node.max_containers, "Eq. 7 violated");
        }
        // Eq. 8 + event accounting: every submitted pod resolves exactly
        // once — completed, wedged, or unschedulable after retries.
        prop_assert_eq!(report.submitted, n_pods);
        prop_assert!(
            report.accounting_balanced(),
            "completed {} + failed {} + unschedulable {} != submitted {}",
            report.completed(),
            report.failed_pulls,
            report.unschedulable,
            report.submitted
        );
        Ok(())
    });
}

#[test]
fn events_interleave_in_timestamp_order() {
    // The event-driven core must emit the audit stream in nondecreasing
    // time order even when pulls, terminations, GC sweeps, and back-off
    // releases overlap timed arrivals (the seed engine recorded pull
    // completions out of order because it only drained at arrivals).
    check(PropConfig { cases: 12, ..Default::default() }, |rng, case| {
        let registry = Registry::with_corpus();
        let wl = WorkloadConfig {
            seed: 1000 + case as u64,
            duration_range: Some((rng.f64_range(5.0, 30.0), rng.f64_range(30.0, 200.0))),
            ..Default::default()
        };
        let trace = WorkloadGen::new(&registry, wl).trace(rng.range(5, 40));
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(rng.f64_range(0.2, 3.0));
        cfg.gc_enabled = rng.chance(0.7);
        cfg.retry_limit = rng.range(0, 6) as u32;
        let mut sim = Simulation::new(
            lrsched::exp::common::paper_nodes(rng.range(2, 6)),
            registry,
            cfg,
        );
        let report = sim.run_trace(trace);
        let log = sim.events.all();
        prop_assert!(!log.is_empty(), "no events recorded");
        for w in log.windows(2) {
            prop_assert!(
                w[1].at >= w[0].at - 1e-9,
                "event log out of order: {:?} after {:?}",
                w[1],
                w[0]
            );
        }
        prop_assert!(report.accounting_balanced(), "dropped events");
        sim.state.check_invariants()?;
        Ok(())
    });
}

#[test]
fn retried_pods_bind_once_capacity_frees() {
    // A pod that finds the cluster full parks with back-off and must bind
    // once the blocking pod's finite duration ends — never silently drop.
    check(PropConfig { cases: 12, ..Default::default() }, |rng, _| {
        let registry = Registry::with_corpus();
        let mut b = lrsched::cluster::PodBuilder::new();
        let blocker_secs = rng.f64_range(10.0, 90.0);
        let blocker = b
            .build("redis:7.2", Resources::cores_gb(3.9, 0.5))
            .with_duration(blocker_secs);
        let waiter = b.build("nginx:1.25", Resources::cores_gb(3.9, 0.5));
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(rng.f64_range(0.5, 2.0));
        cfg.retry_backoff_secs = rng.f64_range(1.0, 8.0);
        // Enough retries to outlast the blocker regardless of draws.
        cfg.retry_limit = 200;
        let mut sim = Simulation::new(
            vec![lrsched::cluster::Node::new(
                NodeId(0),
                "only",
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(30.0),
                lrsched::util::units::Bandwidth::from_mbps(10.0),
            )],
            registry,
            cfg,
        );
        let report = sim.run_trace(vec![blocker, waiter]);
        prop_assert_eq!(report.deployed(), 2);
        prop_assert_eq!(report.unschedulable, 0);
        prop_assert!(report.retries > 0, "waiter never parked");
        prop_assert!(report.accounting_balanced(), "accounting");
        // The waiter bound only after the blocker released its resources.
        let waiter_bind = report.records.last().unwrap().at;
        prop_assert!(
            waiter_bind >= blocker_secs,
            "waiter bound at {waiter_bind} before blocker could die ({blocker_secs})"
        );
        sim.state.check_invariants()?;
        Ok(())
    });
}

#[test]
fn simulation_is_deterministic() {
    check(PropConfig { cases: 8, ..Default::default() }, |rng, case| {
        let seed = rng.next_u64();
        let run = || {
            let registry = Registry::with_corpus();
            let wl = WorkloadConfig { seed, ..Default::default() };
            let trace = WorkloadGen::new(&registry, wl).trace(15);
            let mut cfg = SimConfig::default();
            cfg.scheduler = SchedulerChoice::LR;
            let mut sim =
                Simulation::new(lrsched::exp::common::paper_nodes(4), registry, cfg);
            sim.run_trace(trace)
        };
        let a = run();
        let b = run();
        prop_assert_eq!(a.deployed(), b.deployed());
        prop_assert_eq!(a.total_download().0, b.total_download().0);
        for (ra, rb) in a.records.iter().zip(&b.records) {
            prop_assert_eq!(&ra.node, &rb.node);
            prop_assert_eq!(ra.download.0, rb.download.0);
        }
        let _ = case;
        Ok(())
    });
}

/// Render the parts of a run that must be bit-stable across identical
/// seeds: every placement record, every audit event, and the counters.
fn run_fingerprint(report: &SimReport, sim: &Simulation) -> String {
    format!(
        "{:?}|{:?}|{}/{}/{}/{}/{}/{}/{}/{}/{}/{}",
        report.records,
        sim.events.all(),
        report.submitted,
        report.completed(),
        report.failed_pulls,
        report.unschedulable,
        report.lost_to_crash,
        report.retries,
        report.resubmitted,
        report.wakeups,
        report.pulls_stalled,
        report.nodes_crashed,
    )
}

#[test]
fn churn_simulation_is_deterministic() {
    // Identical seeds must give byte-identical reports *with churn
    // enabled*: crashes, wake-up batches, outage stalls, and resubmission
    // order are all part of the deterministic event order.
    check(PropConfig { cases: 6, ..Default::default() }, |rng, _| {
        let seed = rng.next_u64();
        let churn_seed = rng.next_u64();
        let n_nodes = rng.range(3, 6);
        let n_pods = rng.range(20, 60);
        let run = || {
            let registry = Registry::with_corpus();
            let wl = WorkloadConfig {
                seed,
                duration_range: Some((15.0, 120.0)),
                ..Default::default()
            };
            let trace = WorkloadGen::new(&registry, wl).trace(n_pods);
            let mut cfg = SimConfig::default();
            cfg.scheduler = SchedulerChoice::LR;
            cfg.inter_arrival_secs = Some(0.5);
            cfg.gc_enabled = true;
            cfg.retry_limit = 8;
            cfg.churn = Some(ChurnConfig {
                seed: churn_seed,
                horizon_secs: 90.0,
                joins: 2,
                drains: 1,
                crash_fraction: 0.34,
                outages: 1,
                outage_secs: 15.0,
                ..Default::default()
            });
            let mut sim = Simulation::new(
                lrsched::exp::common::paper_nodes(n_nodes),
                registry,
                cfg,
            );
            let report = sim.run_trace(trace);
            (report, sim)
        };
        let (ra, sa) = run();
        let (rb, sb) = run();
        prop_assert_eq!(run_fingerprint(&ra, &sa), run_fingerprint(&rb, &sb));
        Ok(())
    });
}

#[test]
fn churn_accounting_always_balances() {
    // Under arbitrary volatility traces, every submitted pod lands in
    // exactly one terminal bucket:
    // completed + failed + unschedulable + lost_to_crash == submitted.
    check(PropConfig { cases: 10, ..Default::default() }, |rng, case| {
        let registry = Registry::with_corpus();
        let wl = WorkloadConfig {
            seed: 9000 + case as u64,
            duration_range: if rng.chance(0.7) {
                Some((rng.f64_range(5.0, 30.0), rng.f64_range(30.0, 150.0)))
            } else {
                None
            },
            ..Default::default()
        };
        let n_pods = rng.range(10, 60);
        let trace = WorkloadGen::new(&registry, wl).trace(n_pods);
        let mut cfg = SimConfig::default();
        cfg.inter_arrival_secs = Some(rng.f64_range(0.2, 2.0));
        cfg.gc_enabled = rng.chance(0.5);
        cfg.retry_limit = rng.range(0, 8) as u32;
        cfg.wake_on_capacity = rng.chance(0.8);
        cfg.churn = Some(ChurnConfig {
            seed: rng.next_u64(),
            horizon_secs: rng.f64_range(30.0, 200.0),
            joins: rng.range(0, 4),
            drains: rng.range(0, 3),
            crash_fraction: rng.f64_range(0.0, 0.6),
            outages: rng.range(0, 3),
            outage_secs: rng.f64_range(5.0, 60.0),
            ..Default::default()
        });
        let n_nodes = rng.range(2, 6);
        let mut sim = Simulation::new(
            lrsched::exp::common::paper_nodes(n_nodes),
            registry,
            cfg,
        );
        let report = sim.run_trace(trace);
        prop_assert_eq!(report.submitted, n_pods);
        prop_assert!(
            report.accounting_balanced(),
            "completed {} + failed {} + unschedulable {} + lost {} != submitted {}",
            report.completed(),
            report.failed_pulls,
            report.unschedulable,
            report.lost_to_crash,
            report.submitted
        );
        // The audit stream stays time-ordered through churn.
        for w in sim.events.all().windows(2) {
            prop_assert!(
                w[1].at >= w[0].at - 1e-9,
                "event log out of order under churn: {:?} after {:?}",
                w[1],
                w[0]
            );
        }
        sim.state.check_invariants()?;
        Ok(())
    });
}

#[test]
fn wakeups_never_bind_later_than_backoff() {
    // No-starvation regression vs PR 1: on identical blocker/waiter
    // scenarios, a wake-up-released pod binds no later than its fixed
    // back-off release would have.
    check(PropConfig { cases: 10, ..Default::default() }, |rng, _| {
        let blocker_secs = rng.f64_range(10.0, 80.0);
        let backoff = rng.f64_range(1.0, 9.0);
        let arrival = rng.f64_range(0.5, 2.0);
        let run = |wake: bool| {
            let registry = Registry::with_corpus();
            let mut b = PodBuilder::new();
            let blocker = b
                .build("redis:7.2", Resources::cores_gb(3.9, 0.5))
                .with_duration(blocker_secs);
            let waiter = b.build("nginx:1.25", Resources::cores_gb(3.9, 0.5));
            let mut cfg = SimConfig::default();
            cfg.inter_arrival_secs = Some(arrival);
            cfg.retry_backoff_secs = backoff;
            cfg.retry_limit = 500;
            cfg.wake_on_capacity = wake;
            let mut sim = Simulation::new(
                vec![lrsched::cluster::Node::new(
                    NodeId(0),
                    "only",
                    Resources::cores_gb(4.0, 4.0),
                    Bytes::from_gb(30.0),
                    lrsched::util::units::Bandwidth::from_mbps(10.0),
                )],
                registry,
                cfg,
            );
            let report = sim.run_trace(vec![blocker.clone(), waiter.clone()]);
            (report.deployed(), report.records.last().unwrap().at, report.wakeups)
        };
        let (dep_wake, bind_wake, wakeups) = run(true);
        let (dep_timer, bind_timer, _) = run(false);
        prop_assert_eq!(dep_wake, 2);
        prop_assert_eq!(dep_timer, 2);
        prop_assert!(wakeups >= 1, "termination must wake the parked waiter");
        prop_assert!(
            bind_wake <= bind_timer + 1e-9,
            "wake-up bound at {bind_wake}, fixed back-off at {bind_timer}"
        );
        Ok(())
    });
}

#[test]
fn json_roundtrips_random_documents() {
    fn gen_json(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Int(rng.next_u64() as i64 / 2),
            3 => Json::Str(format!("s{}-\"esc\\{}\n", rng.next_u32(), rng.next_u32())),
            4 => Json::Arr((0..rng.range(0, 5)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.range(0, 5) {
                    o.set(&format!("k{i}"), gen_json(rng, depth - 1));
                }
                o
            }
        }
    }
    check(PropConfig::default(), |rng, _| {
        let doc = gen_json(rng, 3);
        let compact = json::parse(&doc.to_string()).map_err(|e| e.to_string())?;
        let pretty = json::parse(&doc.to_string_pretty()).map_err(|e| e.to_string())?;
        prop_assert_eq!(&compact, &doc);
        prop_assert_eq!(&pretty, &doc);
        Ok(())
    });
}

#[test]
fn gc_never_evicts_in_use_layers_under_any_policy() {
    // Whatever the cache policy picks as victims, a layer required by an
    // image of a bound (running or still-pulling) pod must survive GC,
    // and disk usage must respect capacity (Eq. 6) afterwards.
    use lrsched::cluster::{ClusterState, Node};
    use lrsched::sim::kubelet::{gc_images, ImageLayerStore};
    use lrsched::sim::CachePolicyChoice;
    use lrsched::util::units::Bandwidth;

    check(PropConfig { cases: 48, ..Default::default() }, |rng, _| {
        let policies = CachePolicyChoice::all();
        let policy = policies[rng.range(0, policies.len())];
        let mut state = ClusterState::new();
        state.add_node(Node::new(
            NodeId(0),
            "edge01",
            Resources::cores_gb(8.0, 16.0),
            Bytes::from_mb(rng.f64_range(600.0, 3000.0)),
            Bandwidth::from_mbps(10.0),
        ));
        let corpus = hub::corpus();
        let mut images = ImageLayerStore::new();
        let mut installed: Vec<usize> = Vec::new();
        for _ in 0..rng.range(2, corpus.len()) {
            let idx = rng.range(0, corpus.len());
            let m = &corpus[idx];
            let (_, layers) = state.intern_image(m);
            if state.install_image(NodeId(0), &m.image_ref(), &layers).is_ok() {
                images.remember(&m.image_ref(), &layers);
                if !installed.contains(&idx) {
                    installed.push(idx);
                }
                let t = rng.f64_range(0.0, 500.0);
                for l in layers.iter() {
                    state.node_mut(NodeId(0)).touch_layer(l, t, 300.0);
                }
            }
        }
        // Bind a random subset: their layers become untouchable.
        let mut builder = PodBuilder::new();
        let mut protected = LayerSet::new();
        let mut in_use: Vec<usize> = Vec::new();
        for &idx in &installed {
            if rng.chance(0.4) {
                let m = &corpus[idx];
                let pod = builder
                    .build(&format!("{}:{}", m.name, m.tag), Resources::cores_gb(0.1, 0.1));
                let pid = state.submit_pod(pod);
                state.bind(pid, NodeId(0)).unwrap();
                let (_, layers) = state.intern_image(m);
                protected.union_with(&layers);
                in_use.push(idx);
            }
        }
        let free_target = Bytes::from_mb(rng.f64_range(0.0, 3000.0));
        gc_images(
            &mut state,
            &images,
            NodeId(0),
            free_target,
            policy,
            rng.f64_range(1.0, 600.0),
            rng.f64_range(0.0, 1000.0),
        );
        let node = state.node(NodeId(0));
        for l in protected.iter() {
            prop_assert!(
                node.layers.contains(l),
                "{policy:?} evicted layer {l:?} required by a bound pod"
            );
        }
        for &idx in &in_use {
            prop_assert!(
                node.has_image(&corpus[idx].image_ref()),
                "{policy:?} evicted an image a bound pod is using"
            );
        }
        prop_assert!(node.disk_used <= node.disk, "GC left disk over capacity");
        state.check_invariants()?;
        Ok(())
    });
}

#[test]
fn bind_unbind_sequences_keep_state_consistent() {
    check(PropConfig { cases: 24, ..Default::default() }, |rng, _| {
        let mut state = fixtures::uniform_cluster(rng.range(1, 5) as u32);
        let mut builder = PodBuilder::new();
        let mut bound: Vec<lrsched::cluster::PodId> = Vec::new();
        for _ in 0..rng.range(1, 60) {
            if bound.is_empty() || rng.chance(0.6) {
                let pod = builder.build(
                    "busybox:1.36",
                    Resources::cores_gb(rng.f64_range(0.0, 0.3), rng.f64_range(0.0, 0.3)),
                );
                let pid = state.submit_pod(pod);
                let node = NodeId(rng.range(0, state.node_count()) as u32);
                if state.bind(pid, node).is_ok() {
                    bound.push(pid);
                }
            } else {
                let idx = rng.range(0, bound.len());
                let pid = bound.swap_remove(idx);
                state.unbind(pid).map_err(|e| e.to_string())?;
            }
            state.check_invariants()?;
        }
        Ok(())
    });
}
