//! Discrete-event edge-cluster simulator: virtual clock, per-node link
//! model, layer-pull dedup, kubelet lifecycle (pull → install → start,
//! optional image GC), workload generation, real-trace replay
//! ([`trace`]), and metrics collection. Every workload — synthetic or
//! replayed — enters the engine through the pull-based
//! [`arrivals::ArrivalSource`] pipeline (constant-memory ingestion; see
//! `docs/ARCHITECTURE.md`, "Arrival pipeline"). `engine::Simulation` is
//! the API-server facade that glues the scheduler to all of it. See
//! `docs/ARCHITECTURE.md` for the event lifecycle and ordering contract.

pub mod arrivals;
pub mod bandwidth;
pub mod cache;
pub mod clock;
pub mod download;
pub mod engine;
pub mod events;
pub mod kubelet;
pub mod metrics;
pub mod p2p;
pub mod shard;
pub mod trace;
pub mod workload;

pub use arrivals::{ArrivalSource, StreamHandle, StreamSource, VecSource, WorkloadSource};
pub use bandwidth::LinkModel;
pub use cache::{CachePolicy, CachePolicyChoice};
pub use clock::Clock;
pub use download::PullManager;
pub use engine::{DecisionDetail, SchedulerChoice, SimConfig, SimReport, Simulation};
pub use events::{EventPayload, EventQueue};
pub use metrics::{ClusterSnapshot, PodRecord};
pub use p2p::{plan_sources, SourcePlan, Swarm, SwarmIndex};
pub use shard::LanePool;
pub use trace::{
    ErrorMode, IngestPath, Trace, TraceError, TraceErrorSlot, TraceEvent, TraceFormat,
    TraceOptions, TraceReplay, TraceSource, TraceStats,
};
pub use workload::{
    ChurnAction, ChurnConfig, ChurnEvent, ChurnModel, Popularity, WorkloadConfig, WorkloadGen,
};
