//! End-to-end simulation tests: the three schedulers on full traces, the
//! paper's headline orderings, failure injection (disk pressure, GC,
//! overcommit), the concurrent-arrival mode, and XLA-backend runs.

use lrsched::cluster::{EventKind, Node, NodeId, Resources};
use lrsched::exp::common;
use lrsched::registry::Registry;
use lrsched::runtime::XlaScorer;
use lrsched::sim::{
    Popularity, SchedulerChoice, SimConfig, Simulation, WorkloadConfig, WorkloadGen,
};
use lrsched::util::units::{Bandwidth, Bytes};

fn trace(seed: u64, n: usize) -> Vec<lrsched::cluster::Pod> {
    let reg = Registry::with_corpus();
    WorkloadGen::new(&reg, WorkloadConfig { seed, ..Default::default() }).trace(n)
}

#[test]
fn headline_orderings_hold_across_seeds() {
    // LR < Default on download cost for every seed; STD(Default) lowest.
    for seed in [1u64, 7, 42, 1234] {
        let t = trace(seed, 20);
        let reports = common::run_all(4, &t, |_| {});
        let (def, layer, lr) = (&reports[0], &reports[1], &reports[2]);
        assert!(
            lr.total_download() < def.total_download(),
            "seed {seed}: LR {} !< Default {}",
            lr.total_download(),
            def.total_download()
        );
        assert!(
            layer.total_download() < def.total_download(),
            "seed {seed}: Layer !< Default"
        );
        // The layer-aware schedulers trade balance for locality.
        assert!(
            def.final_std() <= lr.final_std() + 0.08,
            "seed {seed}: Default should be most balanced"
        );
    }
}

#[test]
fn gc_enables_progress_under_disk_pressure() {
    // Deterministic churn: one node whose disk fits exactly one large
    // image; short-lived gcc and elasticsearch pods alternate. Without GC
    // the first image squats the disk forever and every pod of the other
    // image is unschedulable (Eq. 6). With the kubelet GC sweep, dead
    // images are evicted between arrivals and everything deploys.
    let node = || {
        vec![Node::new(
            NodeId(0),
            "tiny",
            Resources::cores_gb(16.0, 16.0),
            Bytes::from_mb(900.0), // gcc = 824 MB, elasticsearch = 560 MB
            Bandwidth::from_mbps(100.0),
        )]
    };
    let alternating = || -> Vec<lrsched::cluster::Pod> {
        let mut b = lrsched::cluster::PodBuilder::new();
        (0..10)
            .map(|i| {
                let image = if i % 2 == 0 { "gcc:13" } else { "elasticsearch:8.11" };
                b.build(image, Resources::cores_gb(0.1, 0.1)).with_duration(5.0)
            })
            .collect()
    };

    let run = |gc: bool| {
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        cfg.gc_enabled = gc;
        cfg.gc_high_pct = 0.5; // aggressive kubelet thresholds
        cfg.gc_low_pct = 0.2;
        cfg.inter_arrival_secs = Some(60.0); // pods die between arrivals
        let mut sim = Simulation::new(node(), Registry::with_corpus(), cfg);
        let rep = sim.run_trace(alternating());
        let evictions = sim
            .events
            .all()
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Evicted { .. }))
            .count();
        sim.state.check_invariants().unwrap();
        (rep, evictions)
    };

    let (no_gc, ev0) = run(false);
    let (with_gc, ev1) = run(true);
    assert_eq!(ev0, 0);
    assert_eq!(no_gc.deployed(), 5, "only the squatting image's pods deploy");
    assert_eq!(no_gc.unschedulable, 5);
    assert!(ev1 >= 4, "expected an eviction per alternation, got {ev1}");
    assert_eq!(with_gc.deployed(), 10, "GC must unlock every pod");
    assert_eq!(with_gc.unschedulable, 0);
}

#[test]
fn concurrent_arrivals_with_uplink_contention() {
    let t = trace(11, 15);
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(2.0);
    cfg.registry_uplink_mbps = Some(5.0);
    let mut sim = Simulation::new(common::paper_nodes(4), Registry::with_corpus(), cfg);
    let constrained = sim.run_trace(t.clone());

    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(2.0);
    let mut sim2 = Simulation::new(common::paper_nodes(4), Registry::with_corpus(), cfg);
    let unconstrained = sim2.run_trace(t);

    assert_eq!(constrained.deployed(), unconstrained.deployed());
    // Contention changes pull timing, which feeds back into later layer
    // states and placements — so compare *rates*, not raw byte totals:
    // seconds-per-MB must be strictly worse under the shared uplink.
    let rate = |r: &lrsched::sim::SimReport| r.total_download_secs() / r.total_download().as_mb();
    assert!(
        rate(&constrained) > rate(&unconstrained) * 1.05,
        "uplink contention must slow pulls: {:.3} vs {:.3} s/MB",
        rate(&constrained),
        rate(&unconstrained)
    );
    sim.state.check_invariants().unwrap();
}

#[test]
fn zipf_workload_amplifies_layer_sharing() {
    // Heavy-tailed image popularity → more repeat pulls → larger LR gain.
    let reg = Registry::with_corpus();
    let zipf_trace = WorkloadGen::new(
        &reg,
        WorkloadConfig { seed: 5, popularity: Popularity::Zipf(1.3), ..Default::default() },
    )
    .trace(20);
    let reports = common::run_all(4, &zipf_trace, |_| {});
    let (def, lr) = (&reports[0], &reports[2]);
    let gain = 1.0 - lr.total_download().as_mb() / def.total_download().as_mb();
    assert!(gain > 0.05, "zipf gain {gain}");
}

#[test]
fn xla_backend_runs_full_simulation() {
    let scorer = match XlaScorer::load_default() {
        Ok(s) => s,
        Err(e) => {
            // Without the `xla` feature (or without `make artifacts`) the
            // backend is unavailable by design; the native path is covered
            // by every other test here.
            eprintln!("skipping xla_backend_runs_full_simulation: {e:#}");
            return;
        }
    };
    let t = trace(21, 15);
    let mut cfg = SimConfig::default();
    cfg.scheduler = SchedulerChoice::LR;
    let mut sim =
        Simulation::new(common::paper_nodes(4), Registry::with_corpus(), cfg.clone())
            .with_backend(Box::new(scorer));
    let xla_rep = sim.run_trace(t.clone());

    let mut sim2 = Simulation::new(common::paper_nodes(4), Registry::with_corpus(), cfg);
    let native_rep = sim2.run_trace(t);

    assert_eq!(xla_rep.deployed(), native_rep.deployed());
    let (a, b) = (xla_rep.total_download().as_mb(), native_rep.total_download().as_mb());
    assert!((a - b).abs() < 0.05 * a.max(1.0), "xla {a} vs native {b}");
    sim.state.check_invariants().unwrap();
}

#[test]
fn five_node_cluster_spreads_further() {
    // More nodes, same trace: Default spreads wider (more cold pulls);
    // LR keeps exploiting locality — its lead should not shrink to zero.
    let t = trace(42, 20);
    let r4 = common::run_all(4, &t, |_| {});
    let r5 = common::run_all(5, &t, |_| {});
    for reports in [&r4, &r5] {
        assert!(reports[2].total_download() < reports[0].total_download());
    }
    assert!(
        r5[0].total_download() >= r4[0].total_download(),
        "default downloads at least as much with more nodes"
    );
}

#[test]
fn p2p_layer_sharing_cuts_wan_cost_and_time() {
    // Cloud-edge collaborative layer sharing (§VII): peer-cached layers
    // come over a fast LAN, so WAN download bytes and download time both
    // drop; total layer bytes delivered stays the same.
    let t = trace(42, 20);
    let base = common::run_all(4, &t, |_| {});
    let p2p = common::run_all(4, &t, |cfg| cfg.p2p_lan_mbps = Some(100.0));

    for (b, p) in base.iter().zip(&p2p) {
        let b_wan = b.total_download();
        let p_wan = p.total_download();
        let p_lan: Bytes = p.records.iter().map(|r| r.p2p).sum();
        assert!(p_wan <= b_wan, "{}: p2p must not increase WAN bytes", b.scheduler);
        assert!(
            p.total_download_secs() <= b.total_download_secs() + 1e-9,
            "{}: p2p must not slow pulls",
            b.scheduler
        );
        if b.scheduler == "Default" {
            // The default scheduler spreads pods, so peers hold plenty of
            // reusable layers — P2P must find a substantial share.
            assert!(p_lan > Bytes::ZERO, "no peer transfers happened");
            assert!(p_wan < b_wan, "WAN bytes should strictly drop");
        }
        let _ = p_lan;
    }

    // P2P narrows the Default-vs-LR gap on *time* (Default's penalty was
    // re-downloading layers some edge node already had).
    let gap_base = base[0].total_download_secs() - base[2].total_download_secs();
    let gap_p2p = p2p[0].total_download_secs() - p2p[2].total_download_secs();
    assert!(gap_p2p < gap_base, "p2p should narrow the gap: {gap_p2p} vs {gap_base}");
}

#[test]
fn rl_scheduler_learns_across_the_trace() {
    // The §VII learning-based scheduler: after warm-up it should land
    // between Default and LRScheduler on download cost — it discovers
    // layer sharing from the reward without being told Eq. 3.
    let t = {
        let reg = Registry::with_corpus();
        // Longer trace so the bandit has time to learn.
        WorkloadGen::new(
            &reg,
            WorkloadConfig {
                seed: 9,
                popularity: Popularity::Zipf(1.0),
                cpu_range: (20, 100),
                mem_range: (10_000_000, 60_000_000),
                ..Default::default()
            },
        )
        .trace(120)
    };
    let run = |choice: SchedulerChoice| {
        let mut cfg = SimConfig::default();
        cfg.scheduler = choice;
        let mut sim = Simulation::new(common::paper_nodes(4), Registry::with_corpus(), cfg);
        let rep = sim.run_trace(t.clone());
        sim.state.check_invariants().unwrap();
        rep
    };
    let def = run(SchedulerChoice::Default);
    let rl = run(SchedulerChoice::Rl);
    let lr = run(SchedulerChoice::LR);
    assert_eq!(rl.deployed(), 120);
    // Second-half download rate (post-learning) must beat Default's.
    let half_rate = |rep: &lrsched::sim::SimReport| -> f64 {
        rep.records[60..].iter().map(|r| r.download.as_mb()).sum::<f64>() / 60.0
    };
    assert!(
        half_rate(&rl) < half_rate(&def),
        "RL post-warmup {} !< Default {}",
        half_rate(&rl),
        half_rate(&def)
    );
    // And the principled LRScheduler still beats the learner end-to-end.
    assert!(lr.total_download() < def.total_download());
}

#[test]
#[ignore = "large acceptance run (~100k pods); run with `cargo test --release -- --ignored`"]
fn scale_100k_pods_event_engine_no_dropped_events() {
    // The acceptance bar for the event-driven core: a 100k-pod timed trace
    // with finite-duration pods and GC runs through the event queue and
    // every submitted pod resolves — completed + wedged + unschedulable
    // after retries must equal submitted.
    let registry = Registry::with_corpus();
    let trace = WorkloadGen::new(
        &registry,
        WorkloadConfig {
            seed: 42,
            popularity: Popularity::Zipf(1.1),
            duration_range: Some((30.0, 300.0)),
            ..Default::default()
        },
    )
    .trace(100_000);
    let mut cfg = SimConfig::default();
    cfg.scheduler = SchedulerChoice::LR;
    cfg.inter_arrival_secs = Some(0.3);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 1000;
    let mut sim = Simulation::new(common::scale_nodes(64), registry, cfg);
    let report = sim.run_trace(trace);
    sim.state.check_invariants().unwrap();
    assert_eq!(report.submitted, 100_000);
    assert!(
        report.accounting_balanced(),
        "dropped events: completed {} + failed {} + unschedulable {} != submitted {}",
        report.completed(),
        report.failed_pulls,
        report.unschedulable,
        report.submitted
    );
    assert!(report.deployed() > 50_000, "churn should keep most pods deployable");
}

#[test]
#[ignore = "large acceptance run (~100k pods); run with `cargo test --release -- --ignored`"]
fn scale_100k_pods_with_churn_accounting_holds() {
    // The churn acceptance bar (`scale --churn` equivalent): 100k pods on
    // 64 nodes with joins, drains, a 5% crash rate, and a registry outage
    // window — every pod still resolves into exactly one bucket:
    // completed + failed + unschedulable + lost_to_crash == submitted.
    let registry = Registry::with_corpus();
    let trace = WorkloadGen::new(
        &registry,
        WorkloadConfig {
            seed: 42,
            popularity: Popularity::Zipf(1.1),
            duration_range: Some((30.0, 300.0)),
            ..Default::default()
        },
    )
    .trace(100_000);
    let mut cfg = SimConfig::default();
    cfg.scheduler = SchedulerChoice::LR;
    cfg.inter_arrival_secs = Some(0.3);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 1000;
    cfg.churn = Some(lrsched::sim::ChurnConfig {
        seed: 42,
        horizon_secs: 100_000.0 * 0.3,
        joins: 3,
        drains: 2,
        crash_fraction: 0.05,
        outages: 1,
        outage_secs: 60.0,
        ..Default::default()
    });
    let mut sim = Simulation::new(common::scale_nodes(64), registry, cfg);
    let report = sim.run_trace(trace);
    sim.state.check_invariants().unwrap();
    assert_eq!(report.submitted, 100_000);
    assert_eq!(report.nodes_crashed, 3, "5% of 64 nodes");
    assert_eq!(report.nodes_joined, 3);
    assert!(report.pulls_stalled > 0, "the outage window must hit in-flight pulls");
    assert!(report.resubmitted > 0, "crashes must resubmit running pods");
    assert!(
        report.accounting_balanced(),
        "dropped events: completed {} + failed {} + unschedulable {} + lost {} != submitted {}",
        report.completed(),
        report.failed_pulls,
        report.unschedulable,
        report.lost_to_crash,
        report.submitted
    );
    assert!(report.deployed() > 50_000, "churn should keep most pods deployable");
}

#[test]
fn soak_full_stack_500_pods() {
    // Everything at once: 500 Zipf pods with finite lifetimes, timed
    // arrivals (overlapping pulls), constrained registry uplink, kubelet
    // GC, and P2P layer sharing — invariants must hold throughout and the
    // cluster must keep making progress.
    let reg = Registry::with_corpus();
    let trace_pods = WorkloadGen::new(
        &reg,
        WorkloadConfig {
            seed: 31,
            popularity: Popularity::Zipf(1.1),
            cpu_range: (20, 120),
            mem_range: (10_000_000, 80_000_000),
            duration_range: Some((30.0, 600.0)),
            ..Default::default()
        },
    )
    .trace(500);
    let mut cfg = SimConfig::default();
    cfg.scheduler = SchedulerChoice::LR;
    cfg.inter_arrival_secs = Some(2.0);
    cfg.registry_uplink_mbps = Some(20.0);
    cfg.gc_enabled = true;
    cfg.p2p_lan_mbps = Some(100.0);
    let mut sim = Simulation::new(common::paper_nodes(5), Registry::with_corpus(), cfg);
    let rep = sim.run_trace(trace_pods);
    sim.state.check_invariants().unwrap();
    assert!(
        rep.deployed() >= 450,
        "churn should keep capacity available: {}/500",
        rep.deployed()
    );
    assert_eq!(rep.failed_pulls, 0, "P2P+GC must not corrupt pulls");
    for node in sim.state.nodes() {
        assert!(node.disk_used <= node.disk);
    }
    // P2P actually carried traffic in a warm cluster.
    let p2p_mb: f64 = rep.records.iter().map(|r| r.p2p.as_mb()).sum();
    assert!(p2p_mb > 100.0, "peer transfers too small: {p2p_mb} MB");
}
