//! Pluggable image-cache eviction policies for kubelet GC.
//!
//! The kubelet's disk-pressure sweep (`sim/kubelet.rs`) historically had
//! exactly one victim rule: evict the first cached image, in insertion
//! order, that no running or pulling pod needs. This module makes that
//! rule one of several [`CachePolicy`] implementations, selected per run
//! via `scale --cache-policy`:
//!
//! - [`CachePolicyChoice::PressureSweep`] — the original insertion-order
//!   sweep, byte-identical to the pre-policy engine (the default);
//! - [`CachePolicyChoice::Lru`] — evict the image whose layers were used
//!   least recently (timestamps stamped at bind and install time);
//! - [`CachePolicyChoice::Popularity`] — evict the image whose layers
//!   have the lowest arrival-frequency-decayed popularity;
//! - [`CachePolicyChoice::ScorerKeepSet`] — evict the image the
//!   layer-score plugin values least against the node's retained layers
//!   ([`crate::sched::layer_score::keep_set_score`]);
//! - [`CachePolicyChoice::Prefetch`] — sweep like `PressureSweep`, but
//!   the engine additionally warms popular layers onto the chosen node at
//!   bind time, and GC may reclaim those orphaned prefetched layers.
//!
//! Every policy is a pure function of per-node state — the node's cached
//! images, its [`crate::cluster::LayerUse`] metadata (a `BTreeMap`, so
//! iteration order is the layer-id order), and the event's virtual time —
//! so the sharded engine's lanes reach the same eviction decisions as the
//! sequential engine and every report stays byte-identical across
//! `--shards {1,N}` (see `docs/ARCHITECTURE.md` § "Cache policies").
//!
//! Tie-breaking is part of the contract: when two candidate images score
//! equally, the victim is the one whose **lowest layer id** is smallest,
//! then the earliest-installed (insertion index). The unit tests below
//! pin that order.

use crate::cluster::LayerUse;
use crate::registry::{LayerId, LayerInterner, LayerSet};
use std::collections::BTreeMap;

/// Which cache policy a run uses (the `scale --cache-policy` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicyChoice {
    /// The original fixed sweep: first insertion-ordered unused image.
    PressureSweep,
    /// Least-recently-used image first (max `last_use` over its layers).
    Lru,
    /// Least-popular image first (decayed arrival-frequency weights).
    Popularity,
    /// Image the layer-score plugin values least against the keep set.
    ScorerKeepSet,
    /// `PressureSweep` eviction + bind-time layer prefetch + orphan sweep.
    Prefetch,
}

impl Default for CachePolicyChoice {
    fn default() -> CachePolicyChoice {
        CachePolicyChoice::PressureSweep
    }
}

impl CachePolicyChoice {
    /// Parse a `--cache-policy` flag value.
    pub fn parse(s: &str) -> Option<CachePolicyChoice> {
        match s {
            "pressure" => Some(CachePolicyChoice::PressureSweep),
            "lru" => Some(CachePolicyChoice::Lru),
            "popularity" => Some(CachePolicyChoice::Popularity),
            "scorer" => Some(CachePolicyChoice::ScorerKeepSet),
            "prefetch" => Some(CachePolicyChoice::Prefetch),
            _ => None,
        }
    }

    /// The flag spelling (what [`CachePolicyChoice::parse`] accepts).
    pub fn label(self) -> &'static str {
        match self {
            CachePolicyChoice::PressureSweep => "pressure",
            CachePolicyChoice::Lru => "lru",
            CachePolicyChoice::Popularity => "popularity",
            CachePolicyChoice::ScorerKeepSet => "scorer",
            CachePolicyChoice::Prefetch => "prefetch",
        }
    }

    /// The policy implementation (stateless — all state is per-node).
    pub fn policy(self) -> &'static dyn CachePolicy {
        match self {
            CachePolicyChoice::PressureSweep => &PressureSweep,
            CachePolicyChoice::Lru => &Lru,
            CachePolicyChoice::Popularity => &Popularity,
            CachePolicyChoice::ScorerKeepSet => &ScorerKeepSet,
            CachePolicyChoice::Prefetch => &Prefetch,
        }
    }

    /// Every selectable policy, in flag order (for tests and benches).
    pub fn all() -> [CachePolicyChoice; 5] {
        [
            CachePolicyChoice::PressureSweep,
            CachePolicyChoice::Lru,
            CachePolicyChoice::Popularity,
            CachePolicyChoice::ScorerKeepSet,
            CachePolicyChoice::Prefetch,
        ]
    }
}

/// Everything a policy may look at when scoring one eviction candidate.
///
/// One `VictimCtx` describes one cached image on one node at one event
/// time; [`select_victim`] scores every candidate and applies the
/// documented tie-break.
pub struct VictimCtx<'a> {
    /// The candidate image's layer set (empty if the image is unknown).
    pub layers: &'a LayerSet,
    /// Union of the layers of every *other* image cached on the node —
    /// the keep set the scorer-informed policy protects.
    pub others: &'a LayerSet,
    /// The node's per-layer use metadata ([`crate::cluster::Node::cache_meta`]).
    pub meta: &'a BTreeMap<LayerId, LayerUse>,
    /// Shared layer interner (for sizes).
    pub interner: &'a LayerInterner,
    /// Virtual time of the GC event.
    pub now: f64,
    /// Popularity decay constant in seconds (`--cache-decay`).
    pub decay: f64,
}

/// A deterministic eviction policy: scores candidates, lowest goes first.
///
/// Implementations must be pure functions of the [`VictimCtx`] — no
/// interior state, no ambient time — so lanes and the sequential engine
/// agree byte-for-byte.
pub trait CachePolicy {
    /// The policy's flag name (diagnostics).
    fn name(&self) -> &'static str;

    /// Score one eviction candidate; the candidate with the **lowest**
    /// score is evicted first. `None` means "no preference": a policy
    /// that returns `None` for every candidate keeps the original
    /// insertion-order sweep. A policy must be consistent — either score
    /// every candidate or none.
    fn victim_score(&self, ctx: &VictimCtx<'_>) -> Option<f64>;

    /// Whether GC may additionally reclaim *orphan* layers — layers on
    /// the node that belong to no cached and no in-use image (only the
    /// prefetch policy creates such layers).
    fn sweeps_orphans(&self) -> bool {
        false
    }
}

/// The pre-policy behavior: first insertion-ordered unused image.
pub struct PressureSweep;

impl CachePolicy for PressureSweep {
    fn name(&self) -> &'static str {
        "pressure"
    }

    fn victim_score(&self, _ctx: &VictimCtx<'_>) -> Option<f64> {
        None
    }
}

/// Least-recently-used: an image is as fresh as its freshest layer.
pub struct Lru;

impl CachePolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn victim_score(&self, ctx: &VictimCtx<'_>) -> Option<f64> {
        // Layers with no metadata were never touched — treat as time 0,
        // i.e. the coldest possible.
        let mut last = 0.0f64;
        for l in ctx.layers.iter() {
            if let Some(u) = ctx.meta.get(&l) {
                if u.last_use > last {
                    last = u.last_use;
                }
            }
        }
        Some(last)
    }
}

/// Arrival-frequency popularity, exponentially decayed at `--cache-decay`.
pub struct Popularity;

impl CachePolicy for Popularity {
    fn name(&self) -> &'static str {
        "popularity"
    }

    fn victim_score(&self, ctx: &VictimCtx<'_>) -> Option<f64> {
        let mut score = 0.0f64;
        for l in ctx.layers.iter() {
            if let Some(u) = ctx.meta.get(&l) {
                score += decayed(u.popularity, u.pop_at, ctx.now, ctx.decay);
            }
        }
        Some(score)
    }
}

/// Protect what the layer-score plugin values: candidates sharing little
/// with the node's retained layers score low and are evicted first.
pub struct ScorerKeepSet;

impl CachePolicy for ScorerKeepSet {
    fn name(&self) -> &'static str {
        "scorer"
    }

    fn victim_score(&self, ctx: &VictimCtx<'_>) -> Option<f64> {
        Some(crate::sched::layer_score::keep_set_score(ctx.layers, ctx.others, ctx.interner))
    }
}

/// Bind-time prefetch: eviction stays the insertion-order sweep, but GC
/// may reclaim orphaned prefetched layers under pressure.
pub struct Prefetch;

impl CachePolicy for Prefetch {
    fn name(&self) -> &'static str {
        "prefetch"
    }

    fn victim_score(&self, _ctx: &VictimCtx<'_>) -> Option<f64> {
        None
    }

    fn sweeps_orphans(&self) -> bool {
        true
    }
}

/// A popularity weight decayed from `at` to `now` with time constant
/// `decay` (seconds). Used both when scoring and when bumping weights so
/// every reader sees the same value regardless of when it last wrote.
pub fn decayed(weight: f64, at: f64, now: f64, decay: f64) -> f64 {
    let dt = (now - at).max(0.0);
    weight * (-dt / decay.max(1e-9)).exp()
}

/// Pick the eviction victim among `candidates` (one [`VictimCtx`] per
/// cached-but-unused image, in the node's image insertion order).
///
/// Returns the index of the victim, or `None` when there are no
/// candidates. If the policy declines to score (every score `None` —
/// `PressureSweep`/`Prefetch`), the first candidate wins, reproducing the
/// pre-policy insertion-order sweep exactly. Otherwise the lowest score
/// wins; ties break on the candidate's lowest layer id, then on insertion
/// order. The tie-break is deterministic and part of the policy contract
/// (pinned by the unit tests below).
pub fn select_victim(policy: &dyn CachePolicy, candidates: &[VictimCtx<'_>]) -> Option<usize> {
    if candidates.is_empty() {
        return None;
    }
    let scores: Vec<Option<f64>> = candidates.iter().map(|c| policy.victim_score(c)).collect();
    if scores.iter().all(|s| s.is_none()) {
        return Some(0);
    }
    let mut best: Option<(f64, LayerId, usize)> = None;
    for (i, c) in candidates.iter().enumerate() {
        let s = scores[i].unwrap_or(0.0);
        let min_layer = c.layers.iter().next().unwrap_or(LayerId(u32::MAX));
        let better = match best {
            None => true,
            Some((bs, bl, bi)) => {
                s < bs || (s == bs && (min_layer < bl || (min_layer == bl && i < bi)))
            }
        };
        if better {
            best = Some((s, min_layer, i));
        }
    }
    best.map(|(_, _, i)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(ids: &[u32]) -> LayerSet {
        LayerSet::from_ids(&ids.iter().map(|&i| LayerId(i)).collect::<Vec<_>>())
    }

    fn meta_with(entries: &[(u32, f64, f64)]) -> BTreeMap<LayerId, LayerUse> {
        entries
            .iter()
            .map(|&(id, last, pop)| {
                (LayerId(id), LayerUse { last_use: last, popularity: pop, pop_at: 0.0 })
            })
            .collect()
    }

    fn ctxs<'a>(
        sets: &'a [LayerSet],
        others: &'a LayerSet,
        meta: &'a BTreeMap<LayerId, LayerUse>,
        interner: &'a LayerInterner,
    ) -> Vec<VictimCtx<'a>> {
        sets.iter()
            .map(|layers| VictimCtx { layers, others, meta, interner, now: 0.0, decay: 300.0 })
            .collect()
    }

    #[test]
    fn pressure_sweep_takes_the_first_candidate() {
        let interner = LayerInterner::new();
        let sets = vec![set(&[5]), set(&[1]), set(&[3])];
        let others = LayerSet::new();
        let meta = BTreeMap::new();
        let c = ctxs(&sets, &others, &meta, &interner);
        assert_eq!(select_victim(&PressureSweep, &c), Some(0));
        assert_eq!(select_victim(&Prefetch, &c), Some(0));
        assert_eq!(select_victim(&PressureSweep, &[]), None);
    }

    #[test]
    fn lru_evicts_the_stalest_image() {
        let interner = LayerInterner::new();
        let sets = vec![set(&[0]), set(&[1]), set(&[2])];
        let others = LayerSet::new();
        let meta = meta_with(&[(0, 30.0, 0.0), (1, 10.0, 0.0), (2, 20.0, 0.0)]);
        let c = ctxs(&sets, &others, &meta, &interner);
        assert_eq!(select_victim(&Lru, &c), Some(1), "layer 1 was used least recently");
    }

    #[test]
    fn equal_lru_timestamps_break_on_lowest_layer_id() {
        let interner = LayerInterner::new();
        // Insertion order deliberately puts the higher layer ids first:
        // the documented tie-break is lowest layer id, not position.
        let sets = vec![set(&[7, 9]), set(&[2, 8]), set(&[4])];
        let others = LayerSet::new();
        let meta = meta_with(&[
            (7, 50.0, 0.0),
            (9, 50.0, 0.0),
            (2, 50.0, 0.0),
            (8, 50.0, 0.0),
            (4, 50.0, 0.0),
        ]);
        let c = ctxs(&sets, &others, &meta, &interner);
        assert_eq!(
            select_victim(&Lru, &c),
            Some(1),
            "all timestamps equal: the image containing layer id 2 must go first"
        );
    }

    #[test]
    fn equal_popularity_breaks_on_lowest_layer_id_then_insertion() {
        let interner = LayerInterner::new();
        let sets = vec![set(&[6]), set(&[3]), set(&[3, 6])];
        let others = LayerSet::new();
        // Every layer equally popular, never decayed (pop_at == now == 0).
        let meta = meta_with(&[(3, 0.0, 1.0), (6, 0.0, 1.0)]);
        let c = ctxs(&sets, &others, &meta, &interner);
        // Candidates 0 and 1 both score 1.0; candidate 2 scores 2.0.
        // Between 0 and 1 the lowest layer id (3) wins.
        assert_eq!(select_victim(&Popularity, &c), Some(1));
        // With identical layer sets the insertion index decides.
        let sets = vec![set(&[3]), set(&[3])];
        let c = ctxs(&sets, &others, &meta, &interner);
        assert_eq!(select_victim(&Popularity, &c), Some(0));
    }

    #[test]
    fn popularity_decay_fades_old_hits() {
        let w = 8.0;
        assert_eq!(decayed(w, 0.0, 0.0, 300.0), 8.0);
        let later = decayed(w, 0.0, 300.0, 300.0);
        assert!((later - 8.0 / std::f64::consts::E).abs() < 1e-9);
        // Clock can never run the weight *up*.
        assert_eq!(decayed(w, 100.0, 50.0, 300.0), 8.0);
    }

    #[test]
    fn untouched_layers_are_coldest_under_lru() {
        let interner = LayerInterner::new();
        let sets = vec![set(&[0]), set(&[1])];
        let others = LayerSet::new();
        let meta = meta_with(&[(0, 5.0, 0.0)]);
        let c = ctxs(&sets, &others, &meta, &interner);
        assert_eq!(select_victim(&Lru, &c), Some(1), "no metadata reads as never used");
    }

    #[test]
    fn choice_parses_every_label() {
        for choice in CachePolicyChoice::all() {
            assert_eq!(CachePolicyChoice::parse(choice.label()), Some(choice));
        }
        assert_eq!(CachePolicyChoice::parse("fifo"), None);
        assert_eq!(CachePolicyChoice::default(), CachePolicyChoice::PressureSweep);
    }
}
