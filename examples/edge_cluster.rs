//! End-to-end driver — the full system on a realistic workload, proving
//! all layers compose:
//!
//!   workload trace (200 pods, Zipf popularity, timed arrivals)
//!     → registry watcher (cache.json metadata)
//!     → LRScheduler over the K8s-plugin framework
//!       → batched scoring through the AOT JAX/Pallas artifact via PJRT
//!         (falls back to the native scorer when artifacts are absent)
//!     → kubelet pull/start lifecycle over the per-node link model
//!
//! Reports the paper's headline metric — download cost (and time) vs. the
//! default scheduler — plus scheduling throughput. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example edge_cluster`

use lrsched::exp::common;
use lrsched::registry::Registry;
use lrsched::runtime::XlaScorer;
use lrsched::sim::{
    Popularity, SchedulerChoice, SimConfig, Simulation, WorkloadConfig, WorkloadGen,
};
use std::time::Instant;

const PODS: usize = 200;
const NODES: usize = 5;

fn trace() -> Vec<lrsched::cluster::Pod> {
    let registry = Registry::with_corpus();
    let cfg = WorkloadConfig {
        seed: 2026,
        popularity: Popularity::Zipf(1.1), // realistic pull popularity
        // Long-running services: requests sized so 200 pods fit the
        // 5-worker cluster (20 cores, 18 GB).
        cpu_range: (20, 90),
        mem_range: (20_000_000, 80_000_000),
        ..WorkloadConfig::default()
    };
    WorkloadGen::new(&registry, cfg).trace(PODS)
}

fn run(choice: SchedulerChoice, backend_xla: bool) -> (lrsched::sim::SimReport, f64) {
    let mut cfg = SimConfig::default();
    cfg.scheduler = choice;
    cfg.inter_arrival_secs = Some(3.0); // overlapping pulls
    cfg.gc_enabled = true; // kubelet image GC under disk pressure
    let mut sim = Simulation::new(common::paper_nodes(NODES), Registry::with_corpus(), cfg);
    if backend_xla {
        match XlaScorer::load_default() {
            Ok(s) => sim = sim.with_backend(Box::new(s)),
            Err(e) => eprintln!("note: xla backend unavailable ({e:#}); using native"),
        }
    }
    let t0 = Instant::now();
    let report = sim.run_trace(trace());
    let wall = t0.elapsed().as_secs_f64();
    sim.state.check_invariants().expect("cluster invariants");
    (report, wall)
}

fn main() {
    println!("E2E: {PODS} pods, {NODES} nodes, Zipf workload, 3s arrivals, GC on\n");
    let (def, _) = run(SchedulerChoice::Default, false);
    let (lr_native, wall_native) = run(SchedulerChoice::LR, false);
    let (lr_xla, wall_xla) = run(SchedulerChoice::LR, true);

    for (label, rep) in [
        ("Default (native)", &def),
        ("LRScheduler (native)", &lr_native),
        ("LRScheduler (xla/PJRT)", &lr_xla),
    ] {
        println!(
            "{label:<24} deployed {:>3}/{PODS}  dl {:>8.1} MB  dl-time {:>8.1}s  STD {:.3}  w1/w2 {}/{}",
            rep.deployed(),
            rep.total_download().as_mb(),
            rep.total_download_secs(),
            rep.final_std(),
            rep.omega1_used,
            rep.omega2_used,
        );
    }

    let dl_red = 1.0 - lr_xla.total_download().as_mb() / def.total_download().as_mb();
    let t_red = 1.0 - lr_xla.total_download_secs() / def.total_download_secs();
    println!("\nheadline: LRScheduler cuts download cost {:.0}% and download time {:.0}% vs Default", dl_red * 100.0, t_red * 100.0);
    println!(
        "scheduling throughput: native {:.0} pods/s, xla {:.0} pods/s (wall)",
        PODS as f64 / wall_native,
        PODS as f64 / wall_xla
    );
    // Backends must agree on outcome quality. Placements may differ on
    // exact-tie nodes (worker3/4/5 share a spec; f32 vs f64 tie-breaks),
    // and one flipped tie changes every later cycle's state — so the
    // robust check is the aggregate cost, not per-step equality.
    let same = lr_native
        .records
        .iter()
        .zip(&lr_xla.records)
        .filter(|(a, b)| a.node == b.node)
        .count();
    println!("backend agreement: {same}/{} identical placements", lr_native.records.len());
    let (a, b) = (lr_native.total_download().as_mb(), lr_xla.total_download().as_mb());
    assert!((a - b).abs() / a < 0.05, "backend download costs diverged: {a} vs {b}");
    assert_eq!(lr_native.deployed(), lr_xla.deployed());
    assert!(dl_red > 0.0, "LRScheduler must beat Default on download cost");
}
