//! Sharded per-node event lanes — the parallel substrate of the engine's
//! `--shards N` mode.
//!
//! The engine partitions the node table into `N` contiguous slices
//! ([`lane_bounds`]) and classifies every event as *node-local* (pull
//! completions, pod terminations, per-node GC checks — see
//! [`super::events::EventPayload::is_node_local`]) or *coordinator-only*
//! (scheduling cycles, arrivals, churn, registry outages, watcher ticks).
//! Between two coordinator events the coordinator drains a **window** of
//! node-local events from the global queue in (time, class, seq) order,
//! routes each to the lane owning its node, and then advances all lanes
//! in parallel on a [`LanePool`]. Arrivals stay coordinator-only: under
//! the streaming pipeline the coordinator pulls the next pod from the
//! run's `ArrivalSource` when an arrival event pops, so the lanes are
//! oblivious to whether the workload is buffered or streamed. Lanes
//! mutate only their own `&mut [Node]` slice and buffer every globally
//! visible side effect (the
//! crate-internal `LaneEffects`); the coordinator applies the buffers
//! back in the original pop order, which makes the report and event log
//! byte-identical to the sequential engine by construction. The
//! merge-order proof sketch lives in `docs/ARCHITECTURE.md` ("Sharded
//! event lanes").
//!
//! The same pool also fans the read-only half of a scheduling cycle
//! (filters, score plugins, the layer-sharing pass) across node chunks via
//! [`par_fill`] — chunk outputs land at fixed indices, so reductions run
//! in the sequential engine's exact order regardless of which worker
//! computed what.
//!
//! **Work stealing**: chunks/lanes are claimed from a shared atomic
//! counter, not pinned to threads — a worker that finishes its lane early
//! claims the next unclaimed one, so an overloaded lane's backlog is
//! absorbed by idle workers without affecting outputs (claiming order
//! never changes where a chunk's results land).

use super::cache::CachePolicyChoice;
use super::kubelet::{self, ImageLayerStore, OverlayImages, PendingStart};
use crate::cluster::{install_image_on, EventKind, Node, Pod, PodId, Resources, NODE_SCOPE};
use crate::cluster::NodeId;
use crate::registry::{ImageRef, LayerInterner, LayerSet};
use crate::util::units::Bytes;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

// --- partition math -------------------------------------------------------

/// Partition `n` items into `lanes` contiguous `(lo, hi)` ranges whose
/// sizes differ by at most one (the first `n % lanes` ranges get the extra
/// item). Empty ranges are produced when `lanes > n`.
pub fn lane_bounds(n: usize, lanes: usize) -> Vec<(usize, usize)> {
    let lanes = lanes.max(1);
    let q = n / lanes;
    let r = n % lanes;
    let mut out = Vec::with_capacity(lanes);
    let mut lo = 0usize;
    for i in 0..lanes {
        let size = q + usize::from(i < r);
        out.push((lo, lo + size));
        lo += size;
    }
    out
}

/// The lane owning item `i` under the [`lane_bounds`] partition of `n`
/// items into `lanes` ranges (O(1) inverse of the bounds table).
pub fn lane_of(i: usize, n: usize, lanes: usize) -> usize {
    let lanes = lanes.max(1);
    debug_assert!(i < n, "item {i} outside partition of {n}");
    let q = n / lanes;
    let r = n % lanes;
    let big = (q + 1) * r; // items covered by the r larger lanes
    if i < big {
        i / (q + 1)
    } else {
        r + (i - big) / q.max(1)
    }
}

// --- the worker pool ------------------------------------------------------

/// A persistent worker pool for lane windows and scheduling fan-outs.
///
/// `threads` counts the caller: a pool of `N` spawns `N − 1` workers, and
/// the thread calling [`LanePool::run`] claims chunks alongside them.
/// Claiming is the work-stealing mechanism: chunks are handed out from one
/// atomic counter, so load imbalance between lanes self-corrects without
/// any effect on where results land (determinism by construction).
pub struct LanePool {
    workers: Vec<JoinHandle<()>>,
    senders: Vec<mpsc::Sender<Msg>>,
    threads: usize,
}

enum Msg {
    Job(Job),
    Shutdown,
}

/// Type-erased pointer to the caller's task closure. Deliberately a raw
/// pointer, not a reference: a worker that wakes up *after*
/// [`LanePool::run`] returned may still move a stale `Job` out of its
/// channel, and moving a dangling reference would be UB — moving a raw
/// pointer is not. The pointer is only dereferenced under the
/// `i < n_chunks` claim guard, which can only succeed while `run` is
/// still blocked (see `run_job`).
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared access from any thread is fine),
// and the pointer's validity window is enforced by `run`'s barrier.
unsafe impl Send for TaskRef {}

#[derive(Clone)]
struct Job {
    task: TaskRef,
    state: Arc<JobState>,
}

struct JobState {
    next: AtomicUsize,
    done: AtomicUsize,
    n_chunks: usize,
    panicked: AtomicBool,
}

fn run_job(job: &Job) {
    loop {
        let i = job.state.next.fetch_add(1, Ordering::SeqCst);
        if i >= job.state.n_chunks {
            break;
        }
        // SAFETY: a chunk index below `n_chunks` can only be claimed while
        // `run` is still blocked waiting for `done == n_chunks` (every
        // claim must be completed before `run` returns), so the caller's
        // closure is alive for the duration of this call.
        let task: &(dyn Fn(usize) + Sync) = unsafe { &*job.task.0 };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i))).is_ok();
        if !ok {
            job.state.panicked.store(true, Ordering::SeqCst);
        }
        // The completion count is the release point `run` synchronizes on.
        job.state.done.fetch_add(1, Ordering::SeqCst);
    }
}

impl LanePool {
    /// A pool of `threads` total workers (including the calling thread);
    /// `threads <= 1` spawns nothing and `run` executes inline.
    pub fn new(threads: usize) -> LanePool {
        let threads = threads.max(1);
        let mut workers = Vec::with_capacity(threads - 1);
        let mut senders = Vec::with_capacity(threads - 1);
        for i in 1..threads {
            let (tx, rx) = mpsc::channel::<Msg>();
            let handle = std::thread::Builder::new()
                .name(format!("lrsched-lane-{i}"))
                .spawn(move || loop {
                    // Jobs arrive back-to-back on the scheduling hot path
                    // (several fan-outs per cycle); spin briefly before
                    // blocking so a futex sleep/wake does not dominate
                    // small jobs. Miri interprets every spin iteration, so
                    // keep the budget tiny there (behavior is identical —
                    // the loop just falls through to the blocking recv).
                    let spin = if cfg!(miri) { 50 } else { 20_000 };
                    let mut msg = None;
                    for _ in 0..spin {
                        match rx.try_recv() {
                            Ok(m) => {
                                msg = Some(m);
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => std::hint::spin_loop(),
                            Err(mpsc::TryRecvError::Disconnected) => return,
                        }
                    }
                    let msg = match msg {
                        Some(m) => m,
                        None => match rx.recv() {
                            Ok(m) => m,
                            Err(_) => return,
                        },
                    };
                    match msg {
                        Msg::Job(job) => run_job(&job),
                        Msg::Shutdown => break,
                    }
                })
                .expect("spawn lane worker");
            workers.push(handle);
            senders.push(tx);
        }
        LanePool { workers, senders, threads }
    }

    /// Total workers, including the calling thread.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `task(chunk)` for every `chunk in 0..n_chunks` across the pool,
    /// returning once all chunks completed. Chunks are claimed dynamically
    /// (work stealing); a panicking task fails the whole call after every
    /// chunk has drained (no worker is left running).
    pub fn run(&self, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        let state = Arc::new(JobState {
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            n_chunks,
            panicked: AtomicBool::new(false),
        });
        // Lifetime erasure happens here (reference → raw pointer, then a
        // ptr cast that only widens the trait-object lifetime bound); the
        // deref site in `run_job` proves validity via the claim guard,
        // because this function blocks below until `done == n_chunks`.
        let raw: *const (dyn Fn(usize) + Sync + '_) = task;
        let job = Job {
            task: TaskRef(raw as *const (dyn Fn(usize) + Sync)),
            state: Arc::clone(&state),
        };
        for tx in &self.senders {
            tx.send(Msg::Job(job.clone())).expect("lane worker alive");
        }
        // The caller is a worker too.
        run_job(&job);
        while state.done.load(Ordering::SeqCst) < n_chunks {
            std::thread::yield_now();
        }
        assert!(
            !state.panicked.load(Ordering::SeqCst),
            "lane worker panicked during a parallel window"
        );
    }
}

impl Drop for LanePool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

// --- deterministic parallel fill -----------------------------------------

struct Chunk<'a, T> {
    base: usize,
    items: &'a mut [T],
}

/// Fill `out[i] = f(i, …)` for every index in parallel. Results land at
/// fixed indices, so downstream reductions iterate in the sequential
/// engine's order regardless of scheduling — the primitive behind the
/// sharded filter/score/layer passes.
pub fn par_fill<T, F>(pool: &LanePool, out: &mut [T], f: &F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    // More chunks than workers so a slow chunk can be compensated by idle
    // workers claiming the rest (work stealing granularity).
    let n_chunks = (pool.threads() * 2).clamp(1, n);
    let bounds = lane_bounds(n, n_chunks);
    let mut chunks: Vec<Mutex<Chunk<'_, T>>> = Vec::with_capacity(n_chunks);
    let mut rest = out;
    for &(lo, hi) in &bounds {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
        rest = tail;
        chunks.push(Mutex::new(Chunk { base: lo, items: head }));
    }
    pool.run(n_chunks, &|c| {
        let mut g = chunks[c].lock().expect("chunk lock");
        let base = g.base;
        for (k, item) in g.items.iter_mut().enumerate() {
            f(base + k, item);
        }
    });
}

/// Row-oriented [`par_fill`]: treat `out` as a dense row-major matrix of
/// `out.len() / width` rows and fill `f(row_index, row_slice)` in
/// parallel. One flat allocation serves a whole scheduling cycle's score
/// matrix — no per-row `Vec`s on the hot path.
pub fn par_fill_rows<T, F>(pool: &LanePool, out: &mut [T], width: usize, f: &F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if width == 0 {
        return;
    }
    debug_assert_eq!(out.len() % width, 0, "out is not a whole number of rows");
    let n = out.len() / width;
    if n == 0 {
        return;
    }
    let n_chunks = (pool.threads() * 2).clamp(1, n);
    let bounds = lane_bounds(n, n_chunks);
    let mut chunks: Vec<Mutex<Chunk<'_, T>>> = Vec::with_capacity(n_chunks);
    let mut rest = out;
    for &(lo, hi) in &bounds {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * width);
        rest = tail;
        chunks.push(Mutex::new(Chunk { base: lo, items: head }));
    }
    pool.run(n_chunks, &|c| {
        let mut g = chunks[c].lock().expect("chunk lock");
        let base = g.base;
        for (k, row) in g.items.chunks_mut(width).enumerate() {
            f(base + k, row);
        }
    });
}

// --- lane work items and effects -----------------------------------------

/// GC knobs a lane needs to replicate the engine's per-node sweep.
#[derive(Clone, Copy)]
pub(crate) struct GcParams {
    pub enabled: bool,
    pub high: f64,
    pub low: f64,
    /// Eviction policy driving victim selection inside the kubelet GC.
    pub policy: CachePolicyChoice,
    /// Popularity half-life knob forwarded to time-aware policies.
    pub decay: f64,
}

/// One node-local unit of work routed to a lane by the coordinator.
pub(crate) enum LaneTask {
    /// A pull completed: install the image and start the container
    /// (the lane half of the engine's `finish_pull`).
    Pull {
        /// The in-flight pull, removed from the coordinator's pending map.
        p: PendingStart,
    },
    /// A pod terminated: release its resources on its node (the binding
    /// entry was already removed by the coordinator).
    Term { pod: PodId, node: NodeId, requests: Resources },
    /// Per-node kubelet GC pressure check.
    Sweep { t: f64, node: NodeId },
}

/// A routed task tagged with its global pop-order slot.
pub(crate) struct LaneItem {
    pub slot: usize,
    pub task: LaneTask,
}

/// Terminal pod outcome a lane observed (mapped onto the engine's private
/// outcome enum at merge time).
pub(crate) enum LaneOutcome {
    /// Container started.
    Started,
    /// Image install wedged (ImagePullBackOff analog).
    FailedPull,
}

/// Globally visible side effects of one lane task, buffered for the
/// coordinator to apply in pop order at the window barrier.
pub(crate) struct LaneEffects {
    pub slot: usize,
    /// Node the task ran against (the coordinator re-marks it in the
    /// swarm index when the effects show an inventory change).
    pub node: NodeId,
    /// Event-log records, in the exact order the sequential engine emits.
    pub log: Vec<(f64, PodId, EventKind)>,
    /// Terminal-outcome update for one pod.
    pub outcome: Option<(PodId, LaneOutcome)>,
    /// Image → layer-set memo entry (`ImageLayerStore::remember`).
    pub remember: Option<(ImageRef, LayerSet)>,
    /// Did the container start? `false` retracts the speculatively
    /// scheduled termination event.
    pub started: bool,
    /// Did this task free node capacity in a way the sequential engine
    /// treats as a capacity wake-up source? `true` for every termination
    /// (the release always frees the pod's requests) and for a GC sweep
    /// that actually evicted; always `false` for pull completions (a
    /// finish-side eviction wakes nothing in the sequential engine
    /// either). The coordinator reads the window's *final* slot's flag to
    /// decide whether to fire the barrier wake-up — earlier slots cannot
    /// be wake-relevant by window construction.
    pub freed_capacity: bool,
}

/// One event lane: a contiguous slice of the node table plus the window's
/// routed work, processed in pop order, with effects buffered.
pub(crate) struct Shard<'a> {
    /// Global node id of `nodes[0]`.
    pub base: usize,
    /// This lane's slice of the node table.
    pub nodes: &'a mut [Node],
    /// Routed work in global pop order.
    pub items: Vec<LaneItem>,
    /// Buffered effects, one per item.
    pub effects: Vec<LaneEffects>,
    /// Window-local image installs (read by same-window GC on this lane).
    overlay: Vec<(ImageRef, LayerSet)>,
}

impl<'a> Shard<'a> {
    /// A lane over `nodes`, whose first element is global node `base`.
    pub fn new(base: usize, nodes: &'a mut [Node], items: Vec<LaneItem>) -> Shard<'a> {
        let cap = items.len();
        Shard { base, nodes, items, effects: Vec::with_capacity(cap), overlay: Vec::new() }
    }

    /// Process every routed item in order, mirroring the sequential
    /// engine's handlers exactly (`finish_pull`, the unbind release, the
    /// per-node GC check) but against this lane's node slice, with all
    /// globally visible effects buffered.
    pub fn process(
        &mut self,
        pods: &BTreeMap<PodId, Pod>,
        interner: &LayerInterner,
        images: &ImageLayerStore,
        gc: GcParams,
    ) {
        let base = self.base;
        let nodes = &mut *self.nodes;
        let overlay = &mut self.overlay;
        let effects = &mut self.effects;
        let items = std::mem::take(&mut self.items);
        for item in items {
            let task_node = match &item.task {
                LaneTask::Pull { p } => p.node,
                LaneTask::Term { node, .. } => *node,
                LaneTask::Sweep { node, .. } => *node,
            };
            let mut eff = LaneEffects {
                slot: item.slot,
                node: task_node,
                log: Vec::new(),
                outcome: None,
                remember: None,
                started: true,
                freed_capacity: false,
            };
            match item.task {
                LaneTask::Pull { p } => {
                    let nidx = p.node.0 as usize - base;
                    let now = p.plan.ready_at;
                    if gc.enabled {
                        let need = p.layers.difference_bytes(&nodes[nidx].layers, interner);
                        if need > nodes[nidx].disk_free() {
                            let view = OverlayImages::new(images, overlay);
                            let freed = kubelet::gc_images_node(
                                &mut nodes[nidx],
                                pods,
                                interner,
                                &view,
                                need,
                                gc.policy,
                                gc.decay,
                                now,
                            );
                            if freed > Bytes::ZERO {
                                eff.log.push((
                                    now,
                                    p.pod,
                                    EventKind::Evicted { node: p.node, bytes: freed },
                                ));
                            }
                        }
                    }
                    match install_image_on(&mut nodes[nidx], interner, &p.image, &p.layers) {
                        Ok(_) => {
                            overlay.push((p.image.clone(), p.layers.clone()));
                            for l in p.layers.iter() {
                                nodes[nidx].touch_layer_install(l, now);
                            }
                            eff.remember = Some((p.image, p.layers));
                            eff.outcome = Some((p.pod, LaneOutcome::Started));
                            eff.log.push((
                                now,
                                p.pod,
                                EventKind::PullFinished {
                                    node: p.node,
                                    secs: now - p.plan.start,
                                },
                            ));
                            eff.log.push((now, p.pod, EventKind::Started { node: p.node }));
                        }
                        Err(e) => {
                            // Disk overcommitted by concurrent binds: the
                            // pod wedges (ImagePullBackOff analog).
                            eff.outcome = Some((p.pod, LaneOutcome::FailedPull));
                            eff.log.push((
                                now,
                                p.pod,
                                EventKind::Unschedulable { reason: format!("pull failed: {e}") },
                            ));
                            eff.started = false;
                        }
                    }
                }
                LaneTask::Term { pod, node, requests } => {
                    // Binding removal already happened on the coordinator;
                    // this is the node half of `ClusterState::unbind`.
                    nodes[node.0 as usize - base].release(pod, requests);
                    eff.freed_capacity = true;
                }
                LaneTask::Sweep { t, node } => {
                    let nidx = node.0 as usize - base;
                    let n = &mut nodes[nidx];
                    if gc.enabled && n.is_up() {
                        let (disk, used) = (n.disk.0 as f64, n.disk_used.0 as f64);
                        if disk > 0.0 && used / disk > gc.high {
                            let target = Bytes((disk * (1.0 - gc.low)) as u64);
                            let view = OverlayImages::new(images, overlay);
                            let freed = kubelet::gc_images_node(
                                n, pods, interner, &view, target, gc.policy, gc.decay, t,
                            );
                            if freed > Bytes::ZERO {
                                eff.freed_capacity = true;
                                eff.log.push((
                                    t,
                                    NODE_SCOPE,
                                    EventKind::Evicted { node, bytes: freed },
                                ));
                            }
                        }
                    }
                }
            }
            effects.push(eff);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn bounds_partition_exactly() {
        for n in 0..40 {
            for lanes in 1..8 {
                let b = lane_bounds(n, lanes);
                assert_eq!(b.len(), lanes);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[lanes - 1].1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
                }
                let sizes: Vec<usize> = b.iter().map(|&(lo, hi)| hi - lo).collect();
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "sizes differ by more than one: {sizes:?}");
            }
        }
    }

    #[test]
    fn lane_of_inverts_bounds() {
        for n in 1..40 {
            for lanes in 1..8 {
                let b = lane_bounds(n, lanes);
                for i in 0..n {
                    let l = lane_of(i, n, lanes);
                    assert!(b[l].0 <= i && i < b[l].1, "item {i} not in lane {l} of {b:?}");
                }
            }
        }
    }

    #[test]
    fn pool_runs_every_chunk_exactly_once() {
        let pool = LanePool::new(4);
        let sum = AtomicU64::new(0);
        let hits = AtomicUsize::new(0);
        pool.run(100, &|i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 100);
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
        // The pool is reusable across jobs.
        let again = AtomicUsize::new(0);
        pool.run(7, &|_| {
            again.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(again.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = LanePool::new(1);
        let hits = AtomicUsize::new(0);
        pool.run(13, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 13);
    }

    #[test]
    #[should_panic(expected = "lane worker panicked")]
    fn task_panics_fail_the_run() {
        let pool = LanePool::new(3);
        pool.run(8, &|i| {
            if i == 5 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn stale_jobs_are_dropped_without_touching_the_closure() {
        // The TaskRef lifetime-erasure contract, exercised end to end:
        // with a single chunk the calling thread usually claims it before
        // any worker wakes, so the workers' copies of the `Job` go stale
        // the moment `run` returns — and each later wake-up must discard
        // them through the failed `i < n_chunks` claim without ever
        // dereferencing the (now dangling) task pointer. Every round
        // re-borrows a fresh stack local, so a stale dereference reads
        // freed memory; this test runs under Miri in CI, which flags
        // exactly that as UB.
        let pool = LanePool::new(4);
        let rounds = if cfg!(miri) { 25 } else { 2_000 };
        for round in 0..rounds {
            let local = vec![round; 8];
            let hits = AtomicUsize::new(0);
            pool.run(1, &|i| {
                assert_eq!(local[i], round);
                hits.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), 1, "chunk ran exactly once");
        }
    }

    #[test]
    fn par_fill_results_land_at_fixed_indices() {
        let pool = LanePool::new(4);
        let mut out = vec![0usize; 257];
        par_fill(&pool, &mut out, &|i, slot| {
            *slot = i * i;
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_fill_rows_fills_dense_matrices() {
        let pool = LanePool::new(3);
        let width = 5;
        let rows = 37;
        let mut out = vec![0usize; rows * width];
        par_fill_rows(&pool, &mut out, width, &|i, row| {
            assert_eq!(row.len(), width);
            for (j, v) in row.iter_mut().enumerate() {
                *v = i * 100 + j;
            }
        });
        for i in 0..rows {
            for j in 0..width {
                assert_eq!(out[i * width + j], i * 100 + j);
            }
        }
        // Degenerate shapes are no-ops, not panics.
        let mut empty: Vec<usize> = Vec::new();
        par_fill_rows(&pool, &mut empty, 4, &|_, _| unreachable!());
        par_fill_rows(&pool, &mut out, 0, &|_, _| unreachable!());
    }

    #[test]
    fn termination_effects_always_report_freed_capacity() {
        use crate::cluster::Node;
        use crate::registry::LayerInterner;
        use crate::util::units::Bandwidth;

        let mut node = Node::new(
            NodeId(0),
            "n0",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(10.0),
            Bandwidth::from_mbps(10.0),
        );
        let requests = Resources::cores_gb(1.0, 1.0);
        node.assign(PodId(7), requests);
        let mut nodes = vec![node];
        let pods = BTreeMap::new();
        let interner = LayerInterner::new();
        let images = ImageLayerStore::new();
        let gc = GcParams {
            enabled: false,
            high: 0.85,
            low: 0.70,
            policy: CachePolicyChoice::PressureSweep,
            decay: 300.0,
        };
        let mut shard = Shard::new(
            0,
            &mut nodes,
            vec![LaneItem {
                slot: 0,
                task: LaneTask::Term { pod: PodId(7), node: NodeId(0), requests },
            }],
        );
        shard.process(&pods, &interner, &images, gc);
        assert_eq!(shard.effects.len(), 1);
        // The coordinator only routes a termination whose binding removal
        // succeeded, so the lane's release is unconditional — and so is
        // the wake-relevance flag the barrier wake-up reads.
        assert!(shard.effects[0].freed_capacity);
        assert_eq!(shard.nodes[0].used, Resources::ZERO);
    }

    #[test]
    fn shard_processes_pull_and_sweep_like_the_engine() {
        use crate::cluster::{ClusterState, Node, PodBuilder};
        use crate::registry::hub;
        use crate::sim::download::PullPlan;
        use crate::util::units::Bandwidth;

        let mut state = ClusterState::new();
        state.add_node(Node::new(
            NodeId(0),
            "n0",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(10.0),
            Bandwidth::from_mbps(10.0),
        ));
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = state.intern_image(redis);
        let mut b = PodBuilder::new();
        let pod = state.submit_pod(b.build("redis:7.2", Resources::cores_gb(0.5, 0.5)));

        let pending = PendingStart {
            pod,
            node: NodeId(0),
            image: redis.image_ref(),
            layers: layers.clone(),
            plan: PullPlan {
                bytes: redis.total_size,
                start: 1.0,
                finish: 7.0,
                ready_at: 7.0,
                new_layers: ids,
            },
            wan_bytes: redis.total_size,
            p2p_bytes: Bytes::ZERO,
            p2p_layers: 0,
        };

        let images = ImageLayerStore::new();
        let gc = GcParams {
            enabled: true,
            high: 0.85,
            low: 0.70,
            policy: CachePolicyChoice::PressureSweep,
            decay: 300.0,
        };
        let (nodes, pods, interner) = state.lane_split();
        let mut shard = Shard::new(
            0,
            nodes,
            vec![
                LaneItem { slot: 0, task: LaneTask::Pull { p: pending } },
                LaneItem { slot: 1, task: LaneTask::Sweep { t: 7.0, node: NodeId(0) } },
            ],
        );
        shard.process(pods, interner, &images, gc);

        assert_eq!(shard.effects.len(), 2);
        let pull_eff = &shard.effects[0];
        assert!(pull_eff.started);
        assert!(matches!(pull_eff.outcome, Some((p, LaneOutcome::Started)) if p == pod));
        assert!(pull_eff.remember.is_some());
        assert_eq!(pull_eff.log.len(), 2, "PullFinished then Started");
        assert!(matches!(pull_eff.log[0].2, EventKind::PullFinished { .. }));
        assert!(matches!(pull_eff.log[1].2, EventKind::Started { .. }));
        // A pull completion is never a wake-up source, even though it
        // changed the node's disk state.
        assert!(!pull_eff.freed_capacity);
        // Below the pressure threshold: the sweep evicts nothing, so it
        // frees no capacity either.
        assert!(shard.effects[1].log.is_empty());
        assert!(!shard.effects[1].freed_capacity);
        assert!(shard.nodes[0].has_image(&redis.image_ref()));
        assert_eq!(shard.nodes[0].disk_used, redis.total_size);
    }
}
