//! Wire types for the `lrsched serve` NDJSON protocol.
//!
//! One JSON object per line in both directions. Input lines are
//! [`InEvent`]s — pod submissions and the node/registry lifecycle events
//! that map onto the engine's churn event classes
//! ([`crate::sim::EventPayload`]). Output lines are decision, summary,
//! and error objects rendered by [`crate::exp::export::decision_to_json`]
//! / [`crate::exp::export::serve_summary_to_json`] /
//! [`error_to_json`]. The full field-by-field reference with types and
//! units lives in `docs/SERVE.md`.
//!
//! Timestamps (`t`) are absolute virtual seconds since session start and
//! must be finite and non-decreasing across lines — the same contract the
//! arrival pipeline imposes on trace offsets
//! ([`crate::sim::ArrivalSource`]): the engine schedules each event as it
//! learns about it and cannot reorder the future.

use crate::util::json::Json;

/// One parsed input line of the serve protocol (see the module docs and
/// `docs/SERVE.md` for the JSON shapes). Every variant carries its
/// virtual timestamp `t`; `shutdown` may omit it to mean "now".
#[derive(Debug, Clone, PartialEq)]
pub enum InEvent {
    /// `{"event":"pod", ...}` — submit a pod to the scheduler. Exactly
    /// one decision (or a terminal non-bind accounted in the summary)
    /// results per pod.
    Pod {
        /// Virtual submission time (seconds).
        t: f64,
        /// Optional metadata name; defaults to the session's `pod-<id>`.
        name: Option<String>,
        /// Image reference (`name[:tag]`); must exist in the registry
        /// catalog the session was built with.
        image: String,
        /// CPU request in millicores (default 100).
        cpu_milli: u64,
        /// Memory request in MB (default 128).
        mem_mb: f64,
        /// Optional container lifetime (seconds); omitted means the pod
        /// runs to the end of the session.
        duration_secs: Option<f64>,
    },
    /// `{"event":"node-join","t":..}` — a node joins the fleet
    /// ([`crate::sim::EventPayload::NodeJoin`]).
    NodeJoin {
        /// Virtual event time (seconds).
        t: f64,
    },
    /// `{"event":"node-drain","t":..,"node":..}` — cordon + drain a node
    /// ([`crate::sim::EventPayload::NodeDrain`]).
    NodeDrain {
        /// Virtual event time (seconds).
        t: f64,
        /// Id of the node to drain.
        node: u32,
    },
    /// `{"event":"node-crash","t":..,"node":..}` — crash a node, losing
    /// its pods ([`crate::sim::EventPayload::NodeCrash`]).
    NodeCrash {
        /// Virtual event time (seconds).
        t: f64,
        /// Id of the node to crash.
        node: u32,
    },
    /// `{"event":"outage","t":..,"secs":..}` — registry unreachable for
    /// `secs` ([`crate::sim::EventPayload::RegistryOutageStart`]).
    Outage {
        /// Virtual outage start (seconds).
        t: f64,
        /// Outage window length (seconds, > 0).
        secs: f64,
    },
    /// `{"event":"shutdown"}` — graceful end of session: drain every
    /// queued event, emit the summary line, exit. Equivalent to EOF on
    /// stdin.
    Shutdown {
        /// Optional virtual shutdown time; `None` means "at the current
        /// frontier".
        t: Option<f64>,
    },
}

impl InEvent {
    /// The event's timestamp, when it carries one.
    pub fn t(&self) -> Option<f64> {
        match self {
            InEvent::Pod { t, .. }
            | InEvent::NodeJoin { t }
            | InEvent::NodeDrain { t, .. }
            | InEvent::NodeCrash { t, .. }
            | InEvent::Outage { t, .. } => Some(*t),
            InEvent::Shutdown { t } => *t,
        }
    }

    /// Render back to the protocol's JSON object — the inverse of
    /// [`InEvent::from_json`] (optional fields are omitted when `None`),
    /// used by the round-trip golden tests and fixture generators.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            InEvent::Pod { t, name, image, cpu_milli, mem_mb, duration_secs } => {
                o.set("event", Json::Str("pod".into()))
                    .set("t", Json::Num(*t))
                    .set("image", Json::Str(image.clone()))
                    .set("cpu_milli", Json::Int(*cpu_milli as i64))
                    .set("mem_mb", Json::Num(*mem_mb));
                if let Some(n) = name {
                    o.set("name", Json::Str(n.clone()));
                }
                if let Some(d) = duration_secs {
                    o.set("duration_secs", Json::Num(*d));
                }
            }
            InEvent::NodeJoin { t } => {
                o.set("event", Json::Str("node-join".into())).set("t", Json::Num(*t));
            }
            InEvent::NodeDrain { t, node } => {
                o.set("event", Json::Str("node-drain".into()))
                    .set("t", Json::Num(*t))
                    .set("node", Json::Int(*node as i64));
            }
            InEvent::NodeCrash { t, node } => {
                o.set("event", Json::Str("node-crash".into()))
                    .set("t", Json::Num(*t))
                    .set("node", Json::Int(*node as i64));
            }
            InEvent::Outage { t, secs } => {
                o.set("event", Json::Str("outage".into()))
                    .set("t", Json::Num(*t))
                    .set("secs", Json::Num(*secs));
            }
            InEvent::Shutdown { t } => {
                o.set("event", Json::Str("shutdown".into()));
                if let Some(t) = t {
                    o.set("t", Json::Num(*t));
                }
            }
        }
        o
    }

    /// Decode one protocol object. Unknown `event` kinds, missing or
    /// ill-typed required fields, non-finite numbers, and unknown keys
    /// (typo protection) are all errors; the returned reason is what the
    /// codec wraps with the line number.
    pub fn from_json(j: &Json) -> Result<InEvent, String> {
        let obj = j.as_obj().ok_or("expected a JSON object")?;
        let kind = j
            .get("event")
            .and_then(Json::as_str)
            .ok_or("missing or non-string \"event\" field")?;
        let allowed: &[&str] = match kind {
            "pod" => &["event", "t", "name", "image", "cpu_milli", "mem_mb", "duration_secs"],
            "node-join" => &["event", "t"],
            "node-drain" | "node-crash" => &["event", "t", "node"],
            "outage" => &["event", "t", "secs"],
            "shutdown" => &["event", "t"],
            other => return Err(format!("unknown event kind {other:?}")),
        };
        for key in obj.keys() {
            if !allowed.contains(&key.as_str()) {
                return Err(format!("unknown field {key:?} for event {kind:?}"));
            }
        }
        let t = match j.get("t") {
            None => None,
            Some(v) => {
                let t = v.as_f64().ok_or("\"t\" must be a number")?;
                if !t.is_finite() || t < 0.0 {
                    return Err(format!("\"t\" must be finite and >= 0, got {t}"));
                }
                Some(t)
            }
        };
        let need_t = || t.ok_or_else(|| format!("event {kind:?} requires \"t\""));
        match kind {
            "pod" => {
                let image = j
                    .get("image")
                    .and_then(Json::as_str)
                    .ok_or("pod event requires a string \"image\"")?;
                if image.is_empty() {
                    return Err("\"image\" must be non-empty".into());
                }
                let cpu_milli = match j.get("cpu_milli") {
                    None => 100,
                    Some(v) => {
                        let n = v.as_i64().ok_or("\"cpu_milli\" must be an integer")?;
                        u64::try_from(n).map_err(|_| "\"cpu_milli\" must be >= 0".to_string())?
                    }
                };
                let mem_mb = match j.get("mem_mb") {
                    None => 128.0,
                    Some(v) => {
                        let m = v.as_f64().ok_or("\"mem_mb\" must be a number")?;
                        if !m.is_finite() || m < 0.0 {
                            return Err(format!("\"mem_mb\" must be finite and >= 0, got {m}"));
                        }
                        m
                    }
                };
                let duration_secs = match j.get("duration_secs") {
                    None => None,
                    Some(v) => {
                        let d = v.as_f64().ok_or("\"duration_secs\" must be a number")?;
                        if !d.is_finite() || d <= 0.0 {
                            return Err(format!(
                                "\"duration_secs\" must be finite and > 0, got {d}"
                            ));
                        }
                        Some(d)
                    }
                };
                let name = match j.get("name") {
                    None => None,
                    Some(v) => Some(
                        v.as_str().ok_or("\"name\" must be a string")?.to_string(),
                    ),
                };
                Ok(InEvent::Pod {
                    t: need_t()?,
                    name,
                    image: image.to_string(),
                    cpu_milli,
                    mem_mb,
                    duration_secs,
                })
            }
            "node-join" => Ok(InEvent::NodeJoin { t: need_t()? }),
            "node-drain" | "node-crash" => {
                let node = j
                    .get("node")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| format!("event {kind:?} requires an integer \"node\""))?;
                let node =
                    u32::try_from(node).map_err(|_| "\"node\" must be a u32".to_string())?;
                let t = need_t()?;
                Ok(if kind == "node-drain" {
                    InEvent::NodeDrain { t, node }
                } else {
                    InEvent::NodeCrash { t, node }
                })
            }
            "outage" => {
                let secs = j
                    .get("secs")
                    .and_then(Json::as_f64)
                    .ok_or("outage event requires a numeric \"secs\"")?;
                if !secs.is_finite() || secs <= 0.0 {
                    return Err(format!("\"secs\" must be finite and > 0, got {secs}"));
                }
                Ok(InEvent::Outage { t: need_t()?, secs })
            }
            "shutdown" => Ok(InEvent::Shutdown { t }),
            _ => unreachable!("kind validated against the allow-list above"),
        }
    }
}

/// A rejected protocol line. Mirrors the trace importers'
/// [`crate::sim::TraceError`] split: under
/// [`crate::sim::ErrorMode::Strict`] the first error aborts the session
/// with its 1-based line number; under lenient mode the line is skipped,
/// counted in the summary's `skipped_lines`, and reported on the
/// diagnostic channel as [`error_to_json`].
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The line was not a valid protocol object.
    Malformed {
        /// 1-based line number within the session's input stream.
        line: usize,
        /// What was wrong with it.
        reason: String,
    },
    /// The line's timestamp went backwards. A live session cannot
    /// reorder the future, so this is never repairable — lenient mode
    /// skips the line, strict mode aborts.
    OutOfOrder {
        /// 1-based line number within the session's input stream.
        line: usize,
        /// The offending timestamp.
        t: f64,
        /// The session's current time frontier (last accepted `t`).
        last: f64,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Malformed { line, reason } => {
                write!(f, "line {line}: malformed event: {reason}")
            }
            ServeError::OutOfOrder { line, t, last } => {
                write!(f, "line {line}: out-of-order timestamp t={t} < last accepted t={last}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Render a [`ServeError`] as the protocol's `{"type":"error",...}`
/// diagnostic object (lenient sessions emit one per skipped line).
pub fn error_to_json(e: &ServeError) -> Json {
    let mut o = Json::obj();
    o.set("type", Json::Str("error".into()));
    match e {
        ServeError::Malformed { line, reason } => {
            o.set("kind", Json::Str("malformed".into()))
                .set("line", Json::Int(*line as i64))
                .set("reason", Json::Str(reason.clone()));
        }
        ServeError::OutOfOrder { line, t, last } => {
            o.set("kind", Json::Str("out-of-order".into()))
                .set("line", Json::Int(*line as i64))
                .set("t", Json::Num(*t))
                .set("last", Json::Num(*last));
        }
    }
    o
}
