//! Edge-cluster substrate: nodes with capacities/taints/labels, single-
//! container pods with placement constraints, the etcd-like state store,
//! and the cluster event log.

pub mod events;
pub mod node;
pub mod pod;
pub mod resources;
pub mod state;

pub use events::{Event, EventKind, EventLog, NODE_SCOPE};
pub use node::{LayerUse, Node, NodeId, NodeStatus, Taint};
pub use pod::{Pod, PodBuilder, PodId};
pub use resources::Resources;
pub use state::{evict_layers_on, install_image_on, prefetch_layers_on, ClusterState, StateError};
