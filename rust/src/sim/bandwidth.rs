//! Link model between the registry and each edge node.
//!
//! The paper's model is T = C_c^n(t) / b_n (§III-B): each node has its own
//! downlink; pulls on one node serialize (Docker pulls a layer stream), and
//! pulls on different nodes proceed independently. An optional registry
//! uplink cap models a constrained private registry shared by all nodes —
//! an ablation the paper's future work hints at.

use crate::util::units::{Bandwidth, Bytes};

#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Per-node downlink.
    node_bw: Vec<Bandwidth>,
    /// Time each node's link becomes free.
    node_free_at: Vec<f64>,
    /// Optional shared registry uplink (None = unconstrained).
    pub registry_uplink: Option<Bandwidth>,
    registry_free_at: f64,
}

impl LinkModel {
    pub fn new(node_bw: Vec<Bandwidth>) -> LinkModel {
        let n = node_bw.len();
        LinkModel { node_bw, node_free_at: vec![0.0; n], registry_uplink: None, registry_free_at: 0.0 }
    }

    pub fn bandwidth(&self, node: usize) -> Bandwidth {
        self.node_bw[node]
    }

    pub fn set_bandwidth(&mut self, node: usize, bw: Bandwidth) {
        self.node_bw[node] = bw;
    }

    /// Schedule a transfer of `bytes` to `node` starting no earlier than
    /// `now`; returns (start, finish) and books the link.
    pub fn schedule_transfer(&mut self, node: usize, bytes: Bytes, now: f64) -> (f64, f64) {
        let mut start = now.max(self.node_free_at[node]);
        if self.registry_uplink.is_some() {
            start = start.max(self.registry_free_at);
        }
        let mut secs = self.node_bw[node].transfer_secs(bytes);
        if let Some(up) = self.registry_uplink {
            secs = secs.max(up.transfer_secs(bytes));
        }
        let finish = start + secs;
        self.node_free_at[node] = finish;
        if self.registry_uplink.is_some() {
            self.registry_free_at = finish;
        }
        (start, finish)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_node_links_are_independent() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        let (s0, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, f1) = lm.schedule_transfer(1, Bytes::from_mb(50.0), 0.0);
        assert_eq!((s0, f0), (0.0, 10.0));
        assert_eq!((s1, f1), (0.0, 5.0));
    }

    #[test]
    fn same_node_transfers_serialize() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0)]);
        let (_, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, f1) = lm.schedule_transfer(0, Bytes::from_mb(10.0), 2.0);
        assert_eq!(f0, 10.0);
        assert_eq!(s1, 10.0); // waits for the first pull
        assert_eq!(f1, 11.0);
    }

    #[test]
    fn registry_uplink_serializes_across_nodes() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(10.0); 2]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        let (_, f0) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        let (s1, _) = lm.schedule_transfer(1, Bytes::from_mb(10.0), 0.0);
        assert_eq!(s1, f0, "second node waits on the registry uplink");
    }

    #[test]
    fn slow_uplink_dominates() {
        let mut lm = LinkModel::new(vec![Bandwidth::from_mbps(100.0)]);
        lm.registry_uplink = Some(Bandwidth::from_mbps(10.0));
        let (_, f) = lm.schedule_transfer(0, Bytes::from_mb(100.0), 0.0);
        assert_eq!(f, 10.0, "uplink is the bottleneck");
    }
}
