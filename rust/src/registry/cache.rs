//! The layer-metadata cache — the paper's `cache.json` (§V-1, Listing 1
//! `ImageMetadataLists`). The watcher fills it from the registry; the
//! scheduler reads it on every scoring cycle instead of hitting the
//! registry, which is the paper's answer to unstable edge bandwidth.

use super::image::{ImageMetadata, ImageRef};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// `ImageMetadataLists` from the paper's Listing 1.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetadataCache {
    /// Paper `CatchFile` (sic) — where the cache persists.
    pub cache_file: String,
    /// Keyed by `name:tag` (the paper keys "by image name and tag").
    lists: BTreeMap<String, ImageMetadata>,
}

impl MetadataCache {
    /// An empty cache that persists to `cache_file`.
    pub fn new(cache_file: &str) -> MetadataCache {
        MetadataCache { cache_file: cache_file.to_string(), lists: BTreeMap::new() }
    }

    /// Insert/refresh one image's metadata.
    pub fn insert(&mut self, meta: ImageMetadata) {
        self.lists.insert(meta.image_ref().key(), meta);
    }

    /// Lookup by image reference — the scheduler's step 2 in §V-2.
    pub fn lookup(&self, image: &ImageRef) -> Option<&ImageMetadata> {
        self.lists.get(&image.key())
    }

    /// Cached images.
    pub fn len(&self) -> usize {
        self.lists.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.lists.is_empty()
    }

    /// Every cached manifest, in key order.
    pub fn iter(&self) -> impl Iterator<Item = &ImageMetadata> {
        self.lists.values()
    }

    /// Drop every entry.
    pub fn clear(&mut self) {
        self.lists.clear();
    }

    /// Serialize in the paper's `cache.json` shape.
    pub fn to_json(&self) -> Json {
        let mut lists = Json::obj();
        for (k, v) in &self.lists {
            lists.set(k, v.to_json());
        }
        let mut o = Json::obj();
        o.set("catch_file", Json::Str(self.cache_file.clone()))
            .set("lists", lists);
        o
    }

    /// Parse the paper's `cache.json` shape; None on any inconsistency.
    pub fn from_json(v: &Json) -> Option<MetadataCache> {
        let mut cache = MetadataCache::new(v.get("catch_file")?.as_str()?);
        for (k, entry) in v.get("lists")?.as_obj()? {
            let meta = ImageMetadata::from_json(entry)?;
            if meta.image_ref().key() != *k {
                return None; // key/value mismatch ⇒ corrupt cache
            }
            cache.insert(meta);
        }
        Some(cache)
    }

    /// Persist to `self.cache_file` as pretty JSON.
    pub fn save(&self) -> std::io::Result<()> {
        std::fs::write(&self.cache_file, self.to_json().to_string_pretty())
    }

    /// Load from a path; a missing file yields an empty cache (first boot),
    /// a corrupt file is an error.
    pub fn load(path: &str) -> std::io::Result<MetadataCache> {
        if !Path::new(path).exists() {
            return Ok(MetadataCache::new(path));
        }
        let text = std::fs::read_to_string(path)?;
        let v = json::parse(&text).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
        })?;
        MetadataCache::from_json(&v).ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "corrupt cache.json")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::hub;
    use crate::registry::layer::LayerMetadata;
    use crate::util::units::Bytes;

    fn sample_cache() -> MetadataCache {
        let mut c = MetadataCache::new("/tmp/lrsched-test-cache.json");
        for m in hub::corpus().into_iter().take(5) {
            c.insert(m);
        }
        c
    }

    #[test]
    fn insert_lookup() {
        let c = sample_cache();
        assert_eq!(c.len(), 5);
        let hit = c.lookup(&ImageRef::new("wordpress", "6.4"));
        assert!(hit.is_some());
        assert!(c.lookup(&ImageRef::new("wordpress", "0.0")).is_none());
    }

    #[test]
    fn json_roundtrip() {
        let c = sample_cache();
        let j = c.to_json();
        assert_eq!(MetadataCache::from_json(&j), Some(c));
    }

    #[test]
    fn detects_key_mismatch() {
        let c = sample_cache();
        let mut j = c.to_json();
        // Move an entry under the wrong key.
        let entry = j.get("lists").unwrap().as_obj().unwrap().values().next().unwrap().clone();
        if let Json::Obj(m) = j.get("lists").unwrap().clone() {
            let mut m2 = m;
            m2.insert("bogus:key".to_string(), entry);
            j.set("lists", Json::Obj(m2));
        }
        assert_eq!(MetadataCache::from_json(&j), None);
    }

    #[test]
    fn save_load_roundtrip() {
        let path = "/tmp/lrsched-test-cache-roundtrip.json";
        let mut c = MetadataCache::new(path);
        c.insert(ImageMetadata::new(
            "sha256:x",
            "app",
            "v2",
            vec![LayerMetadata { digest: "sha256:l".into(), size: Bytes::from_mb(3.0) }],
        ));
        c.save().unwrap();
        let loaded = MetadataCache::load(path).unwrap();
        assert_eq!(loaded, c);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_missing_file_is_empty() {
        let c = MetadataCache::load("/tmp/does-not-exist-lrsched.json").unwrap();
        assert!(c.is_empty());
    }

    #[test]
    fn load_corrupt_file_errors() {
        let path = "/tmp/lrsched-test-corrupt.json";
        std::fs::write(path, "{not json").unwrap();
        assert!(MetadataCache::load(path).is_err());
        std::fs::write(path, r#"{"catch_file": "x", "lists": {"a:b": {"bad": 1}}}"#).unwrap();
        assert!(MetadataCache::load(path).is_err());
        std::fs::remove_file(path).ok();
    }
}
