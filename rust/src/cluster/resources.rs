//! Resource vectors: requests/limits and allocatable capacity, following
//! Kubernetes semantics (scheduling is by *requests* against *allocatable*).

use crate::util::units::{Bytes, MilliCpu};

/// A (cpu, memory) resource vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    /// CPU request/capacity in millicores.
    pub cpu: MilliCpu,
    /// Memory request/capacity in bytes.
    pub memory: Bytes,
}

impl Resources {
    /// The zero vector.
    pub const ZERO: Resources = Resources { cpu: MilliCpu::ZERO, memory: Bytes::ZERO };

    /// Construct from explicit units.
    pub fn new(cpu: MilliCpu, memory: Bytes) -> Resources {
        Resources { cpu, memory }
    }

    /// Construct from whole cores and gigabytes.
    pub fn cores_gb(cores: f64, gb: f64) -> Resources {
        Resources { cpu: MilliCpu::from_cores(cores), memory: Bytes::from_gb(gb) }
    }

    /// Does this request fit inside `available` on every dimension?
    pub fn fits_within(&self, available: &Resources) -> bool {
        self.cpu <= available.cpu && self.memory <= available.memory
    }

    /// Component-wise sum.
    pub fn checked_add(&self, rhs: &Resources) -> Resources {
        Resources { cpu: self.cpu + rhs.cpu, memory: self.memory + rhs.memory }
    }

    /// Component-wise subtraction, clamping at zero.
    pub fn saturating_sub(&self, rhs: &Resources) -> Resources {
        Resources {
            cpu: self.cpu.saturating_sub(rhs.cpu),
            memory: self.memory.saturating_sub(rhs.memory),
        }
    }

    /// Fraction of `capacity` this vector uses, per dimension.
    /// Returns (cpu_frac, mem_frac); 0 for zero-capacity dimensions.
    pub fn fraction_of(&self, capacity: &Resources) -> (f64, f64) {
        let cf = if capacity.cpu.0 == 0 { 0.0 } else { self.cpu.0 as f64 / capacity.cpu.0 as f64 };
        let mf = if capacity.memory.0 == 0 {
            0.0
        } else {
            self.memory.0 as f64 / capacity.memory.0 as f64
        };
        (cf, mf)
    }
}

impl std::ops::Add for Resources {
    type Output = Resources;
    fn add(self, rhs: Resources) -> Resources {
        self.checked_add(&rhs)
    }
}

impl std::ops::AddAssign for Resources {
    fn add_assign(&mut self, rhs: Resources) {
        *self = self.checked_add(&rhs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits() {
        let cap = Resources::cores_gb(4.0, 8.0);
        assert!(Resources::cores_gb(4.0, 8.0).fits_within(&cap));
        assert!(!Resources::cores_gb(4.1, 1.0).fits_within(&cap));
        assert!(!Resources::cores_gb(1.0, 8.1).fits_within(&cap));
        assert!(Resources::ZERO.fits_within(&cap));
    }

    #[test]
    fn arithmetic() {
        let a = Resources::cores_gb(1.0, 2.0);
        let b = Resources::cores_gb(0.5, 1.0);
        let sum = a + b;
        assert_eq!(sum.cpu, MilliCpu::from_cores(1.5));
        assert_eq!(sum.memory, Bytes::from_gb(3.0));
        let diff = a.saturating_sub(&sum);
        assert_eq!(diff, Resources::ZERO);
    }

    #[test]
    fn fractions() {
        let cap = Resources::cores_gb(4.0, 8.0);
        let used = Resources::cores_gb(1.0, 4.0);
        let (cf, mf) = used.fraction_of(&cap);
        assert!((cf - 0.25).abs() < 1e-12);
        assert!((mf - 0.5).abs() < 1e-12);
        let (zc, zm) = used.fraction_of(&Resources::ZERO);
        assert_eq!((zc, zm), (0.0, 0.0));
    }
}
