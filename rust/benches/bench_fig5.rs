//! Bench target regenerating paper Fig. 5: accumulated download size for
//! 20 pods. Run: `cargo bench --bench bench_fig5`

use lrsched::exp::fig5;
use lrsched::testing::bench::{bench, header};

fn main() {
    let fig = fig5::run(42, 20, 4);
    print!("{}", fig.print());

    println!("\n{}", header());
    let r = bench("fig5: 3 sequential 20-pod runs", 2_000, || {
        std::hint::black_box(fig5::run(42, 20, 4));
    });
    println!("{}", r.report());
}
