//! Workload generation — the paper's §VI-A protocol: "we randomly request
//! these images, setting random CPU and memory limits for each request."
//!
//! Pods draw an image uniformly (or Zipf-weighted, the realistic variant)
//! from the corpus, CPU requests uniform in [100m, 1000m], memory uniform
//! in [100 MB, 1 GB]. Traces are reproducible from the seed. For
//! large-scale runs the generator is wrapped **lazily** by
//! [`crate::sim::arrivals::WorkloadSource`] — pods are built one at a
//! time as the engine pulls them, instead of pre-materializing a
//! `Vec<Pod>` ([`WorkloadGen::trace`] remains the buffered convenience).
//!
//! Alongside pods, this module generates the *cluster-volatility* trace
//! ([`ChurnModel`]): node joins, drains, crashes, and registry outage
//! windows spread over a horizon — the EdgePier-style edge churn the
//! engine injects as events. Churn traces are reproducible from their own
//! seed, independent of the pod-trace seed.

use crate::cluster::{NodeId, Pod, PodBuilder, Resources};
use crate::registry::Registry;
use crate::util::rng::Pcg;
use crate::util::units::{Bytes, MilliCpu};

/// Image-popularity model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Popularity {
    /// Uniform over the catalog (the paper's protocol).
    Uniform,
    /// Zipf(s) over the catalog — container registries see heavy-tailed
    /// pull distributions; used by the ablation benches.
    Zipf(f64),
}

/// Synthetic-workload parameters (see also [`crate::sim::trace`] for
/// replaying real cluster traces instead).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Pod-trace RNG seed.
    pub seed: u64,
    /// Image-popularity model.
    pub popularity: Popularity,
    /// CPU request range in millicores.
    pub cpu_range: (u64, u64),
    /// Memory request range in bytes.
    pub mem_range: (u64, u64),
    /// Restrict to the images the paper names (None = whole corpus).
    pub image_allowlist: Option<Vec<String>>,
    /// Pod lifetime range in seconds; None = services that run forever
    /// (the paper's protocol). Finite lifetimes model churn workloads.
    pub duration_range: Option<(f64, f64)>,
}

impl Default for WorkloadConfig {
    fn default() -> WorkloadConfig {
        // Ranges sized like the paper's testbed: 20 pods must fit the
        // 3-worker cluster (12 cores, 10 GB) with headroom to spare.
        WorkloadConfig {
            seed: 42,
            popularity: Popularity::Uniform,
            cpu_range: (100, 800),
            mem_range: (50_000_000, 500_000_000),
            image_allowlist: None,
            duration_range: None,
        }
    }
}

/// Generates pods from a registry catalog.
pub struct WorkloadGen {
    rng: Pcg,
    builder: PodBuilder,
    /// (name, tag) choices with popularity weights.
    choices: Vec<(String, String)>,
    weights: Vec<f64>,
    cfg: WorkloadConfig,
}

impl WorkloadGen {
    /// Build a generator over `registry`'s catalog (optionally allowlisted).
    pub fn new(registry: &Registry, cfg: WorkloadConfig) -> WorkloadGen {
        let mut choices: Vec<(String, String)> = registry
            .all_manifests()
            .filter(|m| match &cfg.image_allowlist {
                Some(allow) => allow.iter().any(|a| *a == m.name),
                None => true,
            })
            .map(|m| (m.name.clone(), m.tag.clone()))
            .collect();
        choices.sort(); // deterministic order independent of map iteration
        assert!(!choices.is_empty(), "workload: empty image catalog");
        let weights = match cfg.popularity {
            Popularity::Uniform => vec![1.0; choices.len()],
            Popularity::Zipf(s) => (1..=choices.len())
                .map(|r| 1.0 / (r as f64).powf(s))
                .collect(),
        };
        WorkloadGen { rng: Pcg::new(cfg.seed, 7), builder: PodBuilder::new(), choices, weights, cfg }
    }

    /// Generate the next pod.
    pub fn next_pod(&mut self) -> Pod {
        let idx = self.rng.weighted(&self.weights);
        let (name, tag) = &self.choices[idx];
        let cpu = self.rng.range(self.cfg.cpu_range.0 as usize, self.cfg.cpu_range.1 as usize + 1);
        let mem = self.rng.range(self.cfg.mem_range.0 as usize, self.cfg.mem_range.1 as usize + 1);
        let mut pod = self.builder.build(
            &format!("{name}:{tag}"),
            Resources::new(MilliCpu(cpu as u64), Bytes(mem as u64)),
        );
        if let Some((lo, hi)) = self.cfg.duration_range {
            pod = pod.with_duration(self.rng.f64_range(lo, hi));
        }
        pod
    }

    /// Generate a trace of `n` pods.
    pub fn trace(&mut self, n: usize) -> Vec<Pod> {
        (0..n).map(|_| self.next_pod()).collect()
    }
}

// --- cluster volatility (churn) ------------------------------------------

/// Parameters of the seeded churn model. Rates are totals over the
/// `horizon_secs` window, so a trace's volatility is explicit and
/// reproducible rather than emergent from per-second probabilities.
#[derive(Debug, Clone)]
pub struct ChurnConfig {
    /// Churn RNG seed (independent of the pod-trace seed).
    pub seed: u64,
    /// Window over which churn events are spread.
    pub horizon_secs: f64,
    /// Cold nodes that join during the window.
    pub joins: usize,
    /// Initial-fleet nodes cordoned during the window.
    pub drains: usize,
    /// Fraction of the initial fleet that crashes (EdgePier-style loss).
    pub crash_fraction: f64,
    /// Registry outage windows.
    pub outages: usize,
    /// Duration of each outage window.
    pub outage_secs: f64,
    /// Spec of joining nodes (mirrors the `scale` fleet by default).
    pub join_cores: f64,
    /// Memory (GB) of joining nodes.
    pub join_mem_gb: f64,
    /// Disk (GB) of joining nodes.
    pub join_disk_gb: f64,
    /// Downlink (MB/s) of joining nodes.
    pub join_bw_mbps: f64,
}

impl Default for ChurnConfig {
    fn default() -> ChurnConfig {
        ChurnConfig {
            seed: 42,
            horizon_secs: 600.0,
            joins: 2,
            drains: 1,
            crash_fraction: 0.05,
            outages: 1,
            outage_secs: 30.0,
            join_cores: 4.0,
            join_mem_gb: 8.0,
            join_disk_gb: 64.0,
            join_bw_mbps: 100.0,
        }
    }
}

/// One churn occurrence at absolute offset `at` from trace start.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnEvent {
    /// Offset in seconds from trace start.
    pub at: f64,
    /// What happens.
    pub action: ChurnAction,
}

/// What happens to the cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum ChurnAction {
    /// A cold node joins the cluster.
    Join,
    /// A node is cordoned (running pods finish).
    Drain {
        /// The drained node.
        node: NodeId,
    },
    /// A node crashes (pods lost and resubmitted).
    Crash {
        /// The crashed node.
        node: NodeId,
    },
    /// Registry unreachable for `[at, at + secs)`.
    Outage {
        /// Window length in seconds.
        secs: f64,
    },
}

/// Deterministic churn-trace generator.
pub struct ChurnModel;

impl ChurnModel {
    /// Generate the volatility trace for a fleet of `initial_nodes`.
    /// Crash/drain victims are distinct nodes of the initial fleet, and at
    /// least one initial node is always left untouched so the cluster
    /// cannot become permanently unschedulable before any join lands.
    pub fn trace(cfg: &ChurnConfig, initial_nodes: usize) -> Vec<ChurnEvent> {
        let mut rng = Pcg::new(cfg.seed, 13);
        let mut events: Vec<ChurnEvent> = Vec::new();
        let span = cfg.horizon_secs.max(1.0);
        // Events land in the middle 90% of the window so joins/crashes
        // interleave with live traffic instead of bunching at the edges.
        let when = |rng: &mut Pcg| rng.f64_range(0.05 * span, 0.95 * span);

        let crashes = ((initial_nodes as f64) * cfg.crash_fraction).round() as usize;
        let mut victims: Vec<u32> = (0..initial_nodes as u32).collect();
        rng.shuffle(&mut victims);
        // Keep one untouched survivor.
        let budget = initial_nodes.saturating_sub(1);
        let crashes = crashes.min(budget);
        let drains = cfg.drains.min(budget - crashes);

        for &node in victims.iter().take(crashes) {
            events.push(ChurnEvent {
                at: when(&mut rng),
                action: ChurnAction::Crash { node: NodeId(node) },
            });
        }
        for &node in victims.iter().skip(crashes).take(drains) {
            events.push(ChurnEvent {
                at: when(&mut rng),
                action: ChurnAction::Drain { node: NodeId(node) },
            });
        }
        for _ in 0..cfg.joins {
            events.push(ChurnEvent { at: when(&mut rng), action: ChurnAction::Join });
        }
        for _ in 0..cfg.outages {
            events.push(ChurnEvent {
                at: when(&mut rng),
                action: ChurnAction::Outage { secs: cfg.outage_secs },
            });
        }
        // Stable order: by time, ties by generation order (sort_by is
        // stable, so equal timestamps keep the push order above).
        events.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let reg = Registry::with_corpus();
        let t1 = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        let t2 = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.image, b.image);
            assert_eq!(a.requests, b.requests);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let reg = Registry::with_corpus();
        let t1 = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(10);
        let mut cfg = WorkloadConfig::default();
        cfg.seed = 43;
        let t2 = WorkloadGen::new(&reg, cfg).trace(10);
        assert!(t1.iter().zip(&t2).any(|(a, b)| a.image != b.image));
    }

    #[test]
    fn requests_within_ranges() {
        let reg = Registry::with_corpus();
        let trace = WorkloadGen::new(&reg, WorkloadConfig::default()).trace(200);
        for p in &trace {
            assert!((100..=800).contains(&p.requests.cpu.0), "{:?}", p.requests.cpu);
            assert!((50_000_000..=500_000_000).contains(&p.requests.memory.0));
        }
    }

    #[test]
    fn allowlist_restricts_images() {
        let reg = Registry::with_corpus();
        let mut cfg = WorkloadConfig::default();
        cfg.image_allowlist = Some(
            crate::registry::hub::paper_images().iter().map(|s| s.to_string()).collect(),
        );
        let trace = WorkloadGen::new(&reg, cfg).trace(100);
        let allowed = crate::registry::hub::paper_images();
        for p in &trace {
            assert!(allowed.contains(&p.image.name.as_str()), "{}", p.image);
        }
    }

    #[test]
    fn churn_trace_is_deterministic_and_sorted() {
        let cfg = ChurnConfig { joins: 3, drains: 2, crash_fraction: 0.25, ..Default::default() };
        let a = ChurnModel::trace(&cfg, 8);
        let b = ChurnModel::trace(&cfg, 8);
        assert_eq!(a, b, "same churn seed ⇒ same volatility trace");
        assert!(!a.is_empty());
        for w in a.windows(2) {
            assert!(w[1].at >= w[0].at, "churn events must be time-sorted");
        }
        for ev in &a {
            assert!(ev.at >= 0.0 && ev.at <= cfg.horizon_secs);
        }
        let mut cfg2 = cfg.clone();
        cfg2.seed = 7;
        assert_ne!(ChurnModel::trace(&cfg2, 8), a, "different churn seeds differ");
    }

    #[test]
    fn churn_victims_are_distinct_and_leave_a_survivor() {
        let cfg = ChurnConfig {
            drains: 10,
            crash_fraction: 1.0, // ask for everything; the model must clamp
            joins: 0,
            outages: 0,
            ..Default::default()
        };
        let trace = ChurnModel::trace(&cfg, 4);
        let mut touched = std::collections::HashSet::new();
        for ev in &trace {
            match ev.action {
                ChurnAction::Crash { node } | ChurnAction::Drain { node } => {
                    assert!(touched.insert(node), "node {node:?} targeted twice");
                }
                _ => {}
            }
        }
        assert!(touched.len() <= 3, "at least one initial node stays untouched");
    }

    #[test]
    fn churn_counts_match_config() {
        let cfg = ChurnConfig {
            joins: 2,
            drains: 1,
            crash_fraction: 0.5,
            outages: 2,
            outage_secs: 15.0,
            ..Default::default()
        };
        let trace = ChurnModel::trace(&cfg, 6);
        let count = |f: &dyn Fn(&ChurnAction) -> bool| trace.iter().filter(|e| f(&e.action)).count();
        assert_eq!(count(&|a| matches!(a, ChurnAction::Join)), 2);
        assert_eq!(count(&|a| matches!(a, ChurnAction::Drain { .. })), 1);
        assert_eq!(count(&|a| matches!(a, ChurnAction::Crash { .. })), 3);
        assert_eq!(count(&|a| matches!(a, ChurnAction::Outage { .. })), 2);
    }

    #[test]
    fn zipf_skews_popularity() {
        let reg = Registry::with_corpus();
        let mut cfg = WorkloadConfig::default();
        cfg.popularity = Popularity::Zipf(1.5);
        let trace = WorkloadGen::new(&reg, cfg).trace(500);
        let mut counts = std::collections::HashMap::new();
        for p in &trace {
            *counts.entry(p.image.key()).or_insert(0usize) += 1;
        }
        let max = *counts.values().max().unwrap();
        assert!(max > 500 / 30 * 3, "head image should dominate: max={max}");
    }
}
