//! A minimal localhost HTTP/1.1 front-end for the serve session
//! (`lrsched serve --listen 127.0.0.1:7473`). Hand-rolled over
//! `std::net::TcpListener` — the vendored dependency set has no HTTP
//! stack — and deliberately tiny: sequential (one connection at a time;
//! the engine is single-threaded state), `Connection: close` per
//! response, two routes:
//!
//! - `GET /healthz` → `200 ok`
//! - `POST /v1/events` — request body is NDJSON [`InEvent`] lines
//!   (line numbers continue across requests); the response body is the
//!   resulting NDJSON decision lines plus, in lenient mode, any
//!   `{"type":"error",...}` diagnostics. A `shutdown` event drains the
//!   session, appends the summary line to the response, and stops the
//!   server. A strict-mode protocol error returns `400` with the error
//!   and terminates the session, mirroring the stdin path's exit 2.
//!
//! [`InEvent`]: super::protocol::InEvent

use super::protocol::{error_to_json, ServeError};
use super::session::Session;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};

/// Bind `addr` (e.g. `127.0.0.1:7473`) and serve the session until a
/// `shutdown` event or a strict-mode protocol error. Returns the final
/// summary line on graceful shutdown (already sent to the client too).
pub fn run_http(addr: &str, session: &mut Session<'_>) -> Result<String, String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    crate::log_info!("serve: listening on http://{local} (POST /v1/events, GET /healthz)");
    let mut lineno = 0usize;
    for conn in listener.incoming() {
        let mut stream = conn.map_err(|e| format!("accept: {e}"))?;
        let (method, path, body) = match read_request(&mut stream) {
            Ok(req) => req,
            Err(e) => {
                // A malformed request poisons only its connection.
                let _ = respond(&mut stream, 400, &format!("bad request: {e}\n"));
                continue;
            }
        };
        match (method.as_str(), path.as_str()) {
            ("GET", "/healthz") => {
                respond(&mut stream, 200, "ok\n")?;
            }
            ("POST", "/v1/events") => {
                let mut out = Vec::new();
                let mut diag = Vec::new();
                let mut shutdown = false;
                let mut fatal: Option<ServeError> = None;
                for line in body.lines() {
                    lineno += 1;
                    match session.handle_line(line, lineno, &mut out, &mut diag) {
                        Ok(false) => {}
                        Ok(true) => {
                            shutdown = true;
                            break;
                        }
                        Err(e) => {
                            fatal = Some(e);
                            break;
                        }
                    }
                }
                if let Some(e) = fatal {
                    out.append(&mut diag);
                    out.push(error_to_json(&e).to_string());
                    respond(&mut stream, 400, &ndjson(&out))?;
                    return Err(e.to_string());
                }
                if shutdown {
                    let mut tail = Vec::new();
                    session.finish(&mut tail);
                    let summary = tail.last().cloned().unwrap_or_default();
                    out.append(&mut diag);
                    out.append(&mut tail);
                    respond(&mut stream, 200, &ndjson(&out))?;
                    return Ok(summary);
                }
                out.append(&mut diag);
                respond(&mut stream, 200, &ndjson(&out))?;
            }
            _ => {
                respond(&mut stream, 404, "not found\n")?;
            }
        }
    }
    unreachable!("TcpListener::incoming never returns None")
}

/// Join output lines into an NDJSON body (trailing newline included).
fn ndjson(lines: &[String]) -> String {
    let mut s = String::with_capacity(lines.iter().map(|l| l.len() + 1).sum());
    for l in lines {
        s.push_str(l);
        s.push('\n');
    }
    s
}

/// Read one HTTP/1.1 request: request line, headers, and a
/// `Content-Length`-delimited body. Honors `Expect: 100-continue` so
/// `curl --data-binary @stream.ndjson` works for large bodies.
fn read_request(stream: &mut TcpStream) -> Result<(String, String, String), String> {
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let path = parts.next().ok_or("request line missing path")?.to_string();
    let mut content_length = 0usize;
    let mut expect_continue = false;
    loop {
        let mut header = String::new();
        let n = reader.read_line(&mut header).map_err(|e| e.to_string())?;
        let header = header.trim_end();
        if n == 0 || header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length =
                    value.parse().map_err(|_| format!("bad Content-Length {value:?}"))?;
            } else if name.eq_ignore_ascii_case("expect")
                && value.eq_ignore_ascii_case("100-continue")
            {
                expect_continue = true;
            }
        }
    }
    if expect_continue && content_length > 0 {
        stream
            .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
            .map_err(|e| e.to_string())?;
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| format!("reading body: {e}"))?;
    String::from_utf8(body).map_err(|_| "body is not UTF-8".to_string()).map(|b| (method, path, b))
}

/// Write one response and close the connection.
fn respond(stream: &mut TcpStream, code: u16, body: &str) -> Result<(), String> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/x-ndjson\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body.as_bytes()))
        .map_err(|e| e.to_string())
}
