//! Resource unit newtypes. The paper mixes MB (layer sizes), cores (CPU),
//! GB (memory/disk) and MB/s (bandwidth); explicit types keep the unit
//! algebra honest across the scheduler, simulator, and experiment reports.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// Bytes of storage (layer sizes, disk capacity). Internally u64 bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Bytes(pub u64);

impl Bytes {
    /// Zero bytes.
    pub const ZERO: Bytes = Bytes(0);

    /// Construct from megabytes (10^6 bytes).
    pub fn from_mb(mb: f64) -> Bytes {
        Bytes((mb * 1_000_000.0).round() as u64)
    }

    /// Construct from gigabytes (10^9 bytes).
    pub fn from_gb(gb: f64) -> Bytes {
        Bytes((gb * 1_000_000_000.0).round() as u64)
    }

    /// This size in megabytes.
    pub fn as_mb(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }

    /// This size in gigabytes.
    pub fn as_gb(self) -> f64 {
        self.0 as f64 / 1_000_000_000.0
    }

    /// Subtract, clamping at zero.
    pub fn saturating_sub(self, rhs: Bytes) -> Bytes {
        Bytes(self.0.saturating_sub(rhs.0))
    }
}

impl Add for Bytes {
    type Output = Bytes;
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}

impl AddAssign for Bytes {
    fn add_assign(&mut self, rhs: Bytes) {
        self.0 += rhs.0;
    }
}

impl Sub for Bytes {
    type Output = Bytes;
    fn sub(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 - rhs.0)
    }
}

impl SubAssign for Bytes {
    fn sub_assign(&mut self, rhs: Bytes) {
        self.0 -= rhs.0;
    }
}

impl Sum for Bytes {
    fn sum<I: Iterator<Item = Bytes>>(iter: I) -> Bytes {
        Bytes(iter.map(|b| b.0).sum())
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000_000 {
            write!(f, "{:.2} GB", self.as_gb())
        } else if self.0 >= 1_000_000 {
            write!(f, "{:.1} MB", self.as_mb())
        } else if self.0 >= 1_000 {
            write!(f, "{:.1} kB", self.0 as f64 / 1e3)
        } else {
            write!(f, "{} B", self.0)
        }
    }
}

/// CPU in millicores, matching Kubernetes resource semantics
/// (1000m = 1 core).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct MilliCpu(pub u64);

impl MilliCpu {
    /// Zero CPU.
    pub const ZERO: MilliCpu = MilliCpu(0);

    /// Construct from whole cores (1 core = 1000m).
    pub fn from_cores(cores: f64) -> MilliCpu {
        MilliCpu((cores * 1000.0).round() as u64)
    }

    /// This request in cores.
    pub fn as_cores(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    /// Subtract, clamping at zero.
    pub fn saturating_sub(self, rhs: MilliCpu) -> MilliCpu {
        MilliCpu(self.0.saturating_sub(rhs.0))
    }
}

impl Add for MilliCpu {
    type Output = MilliCpu;
    fn add(self, rhs: MilliCpu) -> MilliCpu {
        MilliCpu(self.0 + rhs.0)
    }
}

impl AddAssign for MilliCpu {
    fn add_assign(&mut self, rhs: MilliCpu) {
        self.0 += rhs.0;
    }
}

impl Sub for MilliCpu {
    type Output = MilliCpu;
    fn sub(self, rhs: MilliCpu) -> MilliCpu {
        MilliCpu(self.0 - rhs.0)
    }
}

impl Sum for MilliCpu {
    fn sum<I: Iterator<Item = MilliCpu>>(iter: I) -> MilliCpu {
        MilliCpu(iter.map(|b| b.0).sum())
    }
}

impl fmt::Display for MilliCpu {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}m", self.0)
    }
}

/// Link bandwidth in bytes/second.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Bandwidth(pub f64);

impl Bandwidth {
    /// Construct from MB/s.
    pub fn from_mbps(mb_per_s: f64) -> Bandwidth {
        Bandwidth(mb_per_s * 1_000_000.0)
    }

    /// This bandwidth in MB/s.
    pub fn as_mbps(self) -> f64 {
        self.0 / 1_000_000.0
    }

    /// Seconds to transfer `bytes` at this bandwidth.
    pub fn transfer_secs(self, bytes: Bytes) -> f64 {
        if self.0 <= 0.0 {
            return f64::INFINITY;
        }
        bytes.0 as f64 / self.0
    }
}

impl fmt::Display for Bandwidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.1} MB/s", self.as_mbps())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_conversions() {
        assert_eq!(Bytes::from_mb(1.0).0, 1_000_000);
        assert_eq!(Bytes::from_gb(2.0).as_mb(), 2000.0);
        assert_eq!(Bytes(5_000_000) + Bytes(5_000_000), Bytes::from_mb(10.0));
        assert_eq!(Bytes(3).saturating_sub(Bytes(5)), Bytes::ZERO);
    }

    #[test]
    fn bytes_display() {
        assert_eq!(Bytes::from_gb(1.5).to_string(), "1.50 GB");
        assert_eq!(Bytes::from_mb(34.0).to_string(), "34.0 MB");
        assert_eq!(Bytes(512).to_string(), "512 B");
    }

    #[test]
    fn cpu_conversions() {
        assert_eq!(MilliCpu::from_cores(4.0).0, 4000);
        assert_eq!(MilliCpu(2500).as_cores(), 2.5);
        assert_eq!(MilliCpu(100).to_string(), "100m");
    }

    #[test]
    fn bandwidth_transfer_time() {
        let bw = Bandwidth::from_mbps(10.0);
        assert!((bw.transfer_secs(Bytes::from_mb(100.0)) - 10.0).abs() < 1e-9);
        assert!(Bandwidth(0.0).transfer_secs(Bytes(1)).is_infinite());
    }

    #[test]
    fn bytes_sum() {
        let total: Bytes = vec![Bytes(1), Bytes(2), Bytes(3)].into_iter().sum();
        assert_eq!(total, Bytes(6));
    }
}
