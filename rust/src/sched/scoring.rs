//! Dense batched scoring — the numeric hot path of Algorithm 1 expressed
//! over padded vectors. This module defines the input/output layout shared
//! by the two backends:
//!
//! - [`NativeScorer`] (here): pure-rust reference implementation, always
//!   available, used by default and as the differential-test oracle.
//! - `runtime::XlaScorer`: executes the AOT-compiled JAX/Pallas artifact
//!   (`python/compile/model.py` lowers the *same math* to HLO).
//!
//! Layout: `present` is row-major `[n_nodes_cap × n_layers_cap]` with 0/1
//! entries; every per-node vector has length `n_nodes_cap`; `req`/`sizes_mb`
//! have length `n_layers_cap`. Capacities are the artifact's fixed shapes —
//! the native scorer accepts any size.

use super::dynamic_weight::WeightParams;

/// Scores below this are "minus infinity" for masked (infeasible) nodes.
pub const NEG_MASK: f32 = -1.0e30;

/// Dense inputs for one scheduling cycle.
#[derive(Debug, Clone)]
pub struct ScoreInputs {
    pub n_nodes: usize,
    pub n_layers: usize,
    /// Row-major node×layer presence (1.0 where the node holds the layer).
    pub present: Vec<f32>,
    /// 1.0 where the pod's image requires the layer.
    pub req: Vec<f32>,
    /// Layer sizes in MB.
    pub sizes_mb: Vec<f32>,
    pub cpu_used: Vec<f32>,
    pub cpu_cap: Vec<f32>,
    pub mem_used: Vec<f32>,
    pub mem_cap: Vec<f32>,
    /// S_K8s per node (already weighted/normalized by the framework).
    pub k8s_score: Vec<f32>,
    /// 1.0 for feasible nodes, 0.0 for filtered ones.
    pub feasible: Vec<f32>,
    pub params: WeightParams,
}

impl ScoreInputs {
    /// Zeroed inputs at the given capacity.
    pub fn zeros(n_nodes: usize, n_layers: usize, params: WeightParams) -> ScoreInputs {
        ScoreInputs {
            n_nodes,
            n_layers,
            present: vec![0.0; n_nodes * n_layers],
            req: vec![0.0; n_layers],
            sizes_mb: vec![0.0; n_layers],
            cpu_used: vec![0.0; n_nodes],
            cpu_cap: vec![1.0; n_nodes], // avoid 0/0 in padding rows
            mem_used: vec![0.0; n_nodes],
            mem_cap: vec![1.0; n_nodes],
            k8s_score: vec![0.0; n_nodes],
            feasible: vec![0.0; n_nodes],
            params,
        }
    }

    /// Flat parameter vector handed to the XLA artifact:
    /// `[ω₁, ω₂, h_size, h_cpu, h_std]`.
    pub fn params_vec(&self) -> [f32; 5] {
        [
            self.params.omega1 as f32,
            self.params.omega2 as f32,
            self.params.h_size_mb as f32,
            self.params.h_cpu as f32,
            self.params.h_std as f32,
        ]
    }
}

/// Per-node outputs of the scoring pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreOutputs {
    /// Final S = ω·S_layer + S_K8s, masked to NEG_MASK where infeasible.
    pub final_score: Vec<f32>,
    /// S_layer (Eq. 3).
    pub layer_score: Vec<f32>,
    /// The ω each node was scored with (Eq. 13 gate applied).
    pub omega: Vec<f32>,
    /// Argmax over final_score (Eq. 5).
    pub best: usize,
}

/// Backend interface implemented natively and by the XLA runtime.
pub trait ScoringBackend {
    fn name(&self) -> &'static str;
    fn score(&mut self, inputs: &ScoreInputs) -> ScoreOutputs;
}

/// Pure-rust implementation of the L2 scoring pipeline.
#[derive(Debug, Default, Clone)]
pub struct NativeScorer;

impl ScoringBackend for NativeScorer {
    fn name(&self) -> &'static str {
        "native"
    }

    fn score(&mut self, x: &ScoreInputs) -> ScoreOutputs {
        let (n, l) = (x.n_nodes, x.n_layers);
        debug_assert_eq!(x.present.len(), n * l);
        // Required layers are sparse (a pod needs a handful of the
        // interner's layers): gather (index, weight) pairs once and reduce
        // only over them — ~5× fewer flops than the dense row product at
        // the 20%-density the workloads produce (§Perf in EXPERIMENTS.md).
        let mut req_idx: Vec<(u32, f32)> = Vec::with_capacity(l / 4);
        let mut total_mb = 0.0f32;
        for j in 0..l {
            let w = x.req[j] * x.sizes_mb[j];
            if w != 0.0 {
                req_idx.push((j as u32, w));
                total_mb += w;
            }
        }
        let p = &x.params;
        let mut final_score = vec![0.0f32; n];
        let mut layer_score = vec![0.0f32; n];
        let mut omega = vec![0.0f32; n];
        for i in 0..n {
            // shared[i] = Σ_j present[i,j]·req[j]·size[j]  (Eq. 2, in MB)
            let row = &x.present[i * l..(i + 1) * l];
            let mut shared = 0.0f32;
            for &(j, w) in &req_idx {
                shared += row[j as usize] * w;
            }
            // Eq. 3.
            let s_layer = if total_mb > 0.0 { shared / total_mb * 100.0 } else { 0.0 };
            // Eqs. 11–12.
            let cpu_frac = if x.cpu_cap[i] > 0.0 { x.cpu_used[i] / x.cpu_cap[i] } else { 0.0 };
            let mem_frac = if x.mem_cap[i] > 0.0 { x.mem_used[i] / x.mem_cap[i] } else { 0.0 };
            let s_std = (cpu_frac - mem_frac).abs() / 2.0;
            // Eq. 13 gate → ω.
            let gate = shared > p.h_size_mb as f32
                && cpu_frac < p.h_cpu as f32
                && s_std < p.h_std as f32;
            let w = if gate { p.omega1 as f32 } else { p.omega2 as f32 };
            // Eq. 4 + feasibility mask.
            let s = w * s_layer + x.k8s_score[i];
            final_score[i] = if x.feasible[i] > 0.5 { s } else { NEG_MASK };
            layer_score[i] = s_layer;
            omega[i] = w;
        }
        // Eq. 5: argmax (first max wins, matching jnp.argmax).
        let best = argmax(&final_score);
        ScoreOutputs { final_score, layer_score, omega, best }
    }
}

/// First-index argmax, matching `jnp.argmax` semantics for ties.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs_2x4() -> ScoreInputs {
        let mut x = ScoreInputs::zeros(2, 4, WeightParams::default());
        // Layers: sizes 10, 20, 30, 40 MB; pod requires layers 0,1,3 (70 MB).
        x.sizes_mb = vec![10.0, 20.0, 30.0, 40.0];
        x.req = vec![1.0, 1.0, 0.0, 1.0];
        // Node 0 holds layers 1,2 → shared 20 MB; node 1 holds nothing.
        x.present[0 * 4 + 1] = 1.0;
        x.present[0 * 4 + 2] = 1.0;
        x.cpu_used = vec![1.0, 1.0];
        x.cpu_cap = vec![4.0, 4.0];
        x.mem_used = vec![1.0, 1.0];
        x.mem_cap = vec![4.0, 4.0];
        x.k8s_score = vec![50.0, 60.0];
        x.feasible = vec![1.0, 1.0];
        x
    }

    #[test]
    fn native_scorer_matches_hand_math() {
        let x = inputs_2x4();
        let out = NativeScorer.score(&x);
        // Node 0: shared 20/70 → layer 28.571…; idle & balanced & >10MB → ω=2.
        let expected_layer0 = 20.0 / 70.0 * 100.0;
        assert!((out.layer_score[0] - expected_layer0).abs() < 1e-4);
        assert_eq!(out.omega[0], 2.0);
        assert!((out.final_score[0] - (2.0 * expected_layer0 + 50.0)).abs() < 1e-4);
        // Node 1: shared 0 → gate fails (h_size) → ω=0.5, final = 60.
        assert_eq!(out.omega[1], 0.5);
        assert!((out.final_score[1] - 60.0).abs() < 1e-4);
        // Node 0 wins: 107.1 > 60.
        assert_eq!(out.best, 0);
    }

    #[test]
    fn infeasible_nodes_masked() {
        let mut x = inputs_2x4();
        x.feasible = vec![0.0, 1.0];
        let out = NativeScorer.score(&x);
        assert_eq!(out.final_score[0], NEG_MASK);
        assert_eq!(out.best, 1);
    }

    #[test]
    fn gate_respects_cpu_threshold() {
        let mut x = inputs_2x4();
        x.cpu_used = vec![3.0, 1.0]; // node 0 at 75% ≥ h_cpu=0.6
        x.mem_used = vec![3.0, 1.0];
        let out = NativeScorer.score(&x);
        assert_eq!(out.omega[0], 0.5);
    }

    #[test]
    fn gate_respects_std_threshold() {
        let mut x = inputs_2x4();
        x.cpu_used = vec![2.0, 1.0]; // cpu 50%, mem 25% → std 0.125 < 0.16 passes
        x.mem_used = vec![1.0, 1.0];
        assert_eq!(NativeScorer.score(&x).omega[0], 2.0);
        x.mem_used = vec![0.0, 1.0]; // cpu 50%, mem 0% → std 0.25 ≥ 0.16 fails
        assert_eq!(NativeScorer.score(&x).omega[0], 0.5);
    }

    #[test]
    fn zero_required_bytes_zero_layer_score() {
        let mut x = inputs_2x4();
        x.req = vec![0.0; 4];
        let out = NativeScorer.score(&x);
        assert_eq!(out.layer_score, vec![0.0, 0.0]);
        assert_eq!(out.best, 1); // falls back to k8s score
    }

    #[test]
    fn argmax_first_tie_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn padding_rows_never_win() {
        // Capacity 8 nodes, only 2 real: padding has feasible=0.
        let mut x = ScoreInputs::zeros(8, 4, WeightParams::default());
        x.feasible[0] = 1.0;
        x.feasible[1] = 1.0;
        x.k8s_score[0] = 10.0;
        x.k8s_score[1] = 20.0;
        let out = NativeScorer.score(&x);
        assert_eq!(out.best, 1);
        for i in 2..8 {
            assert_eq!(out.final_score[i], NEG_MASK);
        }
    }
}
