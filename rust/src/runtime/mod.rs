//! The PJRT runtime: loads the AOT-compiled JAX/Pallas scoring artifacts
//! (HLO text) and serves them on the scheduling hot path. Python never
//! runs here — `make artifacts` is the only build-time Python step.

pub mod pjrt;
pub mod scorer;

pub use pjrt::PjRt;
pub use scorer::XlaScorer;
