//! `detlint` — the in-repo determinism-contract analyzer behind
//! `lrsched lint`.
//!
//! The whole value of this reproduction is that `--shards N` replay is
//! byte-identical to sequential and that every repair/retry is
//! deterministic and counted. That contract used to be enforced only by
//! convention (hand-written "collect, then sort" comments) and
//! after-the-fact differential tests; this module turns it into a build
//! gate. It walks `rust/src/**`, lexes every file with the token-level
//! lexer in [`crate::util::rustlex`], and enforces four rules:
//!
//! - **R1** — no `HashMap`/`HashSet` iteration-order escape (`.iter()`,
//!   `.keys()`, `.values()`, `.drain()`, `for`-loops, …) in `sim/`,
//!   `sched/`, `cluster/`, or `registry/` unless the site carries a
//!   `// det: sorted(<key>)` annotation marking a collect-then-sort.
//! - **R2** — no ambient nondeterminism (`Instant::now`, `SystemTime`,
//!   `std::env`, OS RNG) outside `main.rs`, `testing/`, and benches.
//! - **R3** — every `unsafe` carries a `SAFETY:` comment, and `unsafe`
//!   stays confined to an allowlisted file set (currently
//!   `sim/shard.rs` only).
//! - **R4** — no accumulation into captured state inside closures handed
//!   to `LanePool::run`/`par_fill`/`par_fill_rows`; reductions must
//!   happen coordinator-side in node order so every float is
//!   bit-identical regardless of worker scheduling.
//!
//! Suppressions use the `det:` annotation grammar (see
//! `docs/ARCHITECTURE.md`, "Determinism contract"):
//!
//! ```text
//! // det: sorted(<key>)           R1: collect-then-sort site, keyed <key>
//! // det: allow(R<n>): <reason>   suppress rule n on the next code line
//! ```
//!
//! An annotation that suppresses nothing is itself an error (**R0**), so
//! suppressions cannot rot. Code from the first `#[cfg(test)]` to
//! end-of-file is exempt from R1/R2/R4 (house style keeps test modules
//! last); R3 applies everywhere, tests included.
//!
//! The rules are token-level heuristics, not a type checker: they can
//! miss an iteration reached through a reference whose hash-typed origin
//! is in another file, and they deliberately over-approximate in the
//! other direction (e.g. any `std::env` access). Both directions are
//! fine for a gate whose self-tests pin the exact behavior — see
//! [`self_test`] and the embedded fixtures.

mod fixtures;
mod rules;

pub use fixtures::self_test;

use crate::util::json::Json;
use crate::util::rustlex::{lex, Tok};
use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding: a determinism-contract violation (R1–R4) or a
/// stale/malformed suppression (R0).
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Path of the offending file, as printed (root-joined).
    pub file: String,
    /// 1-based line of the offending token run.
    pub line: u32,
    /// Rule id: `R0` (annotation hygiene) through `R4`.
    pub rule: &'static str,
    /// The offending token run, compressed for display.
    pub token: String,
    /// Human-readable explanation with the expected fix.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} `{}` — {}", self.file, self.line, self.rule, self.token, self.message)
    }
}

impl Diagnostic {
    /// This diagnostic as a JSON object (for `lrsched lint --json`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("file", Json::Str(self.file.clone()))
            .set("line", Json::Int(i64::from(self.line)))
            .set("rule", Json::Str(self.rule.to_string()))
            .set("token", Json::Str(self.token.clone()))
            .set("message", Json::Str(self.message.clone()));
        o
    }
}

/// Result of a full lint run: what was scanned and what was found.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Files scanned, in deterministic (sorted) walk order.
    pub files: usize,
    /// Findings across all files, in walk order then line order.
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    /// Did the tree pass clean?
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// All findings as a JSON array (stable order).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect())
    }
}

/// A parsed `det:` suppression annotation.
struct Annotation {
    /// Rule this annotation suppresses (`R1` for `sorted(…)`).
    rule: &'static str,
    /// The code line it targets (same line, or the next code line).
    target: Option<u32>,
    /// Line of the comment itself (for R0 reporting).
    line: u32,
    /// Did it suppress at least one diagnostic?
    used: bool,
}

/// Per-file context shared by the rule passes.
pub(crate) struct FileCtx<'a> {
    /// Relative, `/`-separated path used for rule scoping.
    pub rel: &'a str,
    /// Code tokens (comments stripped).
    pub code: Vec<&'a Tok>,
    /// Comment tokens only (R3 `SAFETY:` + `det:` annotations live here).
    pub comments: Vec<&'a Tok>,
    /// Line of the first `#[cfg(test)]`; R1/R2/R4 skip lines ≥ this.
    test_from_line: Option<u32>,
}

impl FileCtx<'_> {
    /// Is `line` inside the trailing test region?
    pub fn in_test(&self, line: u32) -> bool {
        matches!(self.test_from_line, Some(t) if line >= t)
    }
}

/// Diagnostic sink that routes each finding through the annotation table
/// before recording it.
pub(crate) struct Emitter<'a> {
    file: String,
    anns: &'a mut Vec<Annotation>,
    diags: &'a mut Vec<Diagnostic>,
}

impl Emitter<'_> {
    pub(crate) fn emit(&mut self, line: u32, rule: &'static str, token: &str, message: &str) {
        for a in self.anns.iter_mut() {
            if a.rule == rule && a.target == Some(line) {
                a.used = true;
                return;
            }
        }
        self.diags.push(Diagnostic {
            file: self.file.clone(),
            line,
            rule,
            token: token.to_string(),
            message: message.to_string(),
        });
    }
}

/// Parse the text after `det:` into `(rule, ok)`. Returns `None` for a
/// malformed annotation.
fn parse_annotation(spec: &str) -> Option<&'static str> {
    let spec = spec.trim();
    if let Some(rest) = spec.strip_prefix("sorted(") {
        // `sorted(<key>)` — key must be non-empty, nothing after `)`.
        if let Some(end) = rest.find(')') {
            if end > 0 && rest[end + 1..].trim().is_empty() {
                return Some("R1");
            }
        }
        return None;
    }
    if let Some(rest) = spec.strip_prefix("allow(") {
        // `allow(R<n>): <reason>` — reason must be non-empty.
        let rule = match rest.as_bytes() {
            [b'R', b'1', b')', b':', ..] => "R1",
            [b'R', b'2', b')', b':', ..] => "R2",
            [b'R', b'3', b')', b':', ..] => "R3",
            [b'R', b'4', b')', b':', ..] => "R4",
            _ => return None,
        };
        if rest[4..].trim().is_empty() {
            return None;
        }
        return Some(rule);
    }
    None
}

/// Lint one file's source. `rel` is the path relative to the walked root
/// (`/`-separated — it drives rule scoping); `display` is the path as it
/// should appear in diagnostics.
pub fn lint_source(rel: &str, display: &str, src: &str) -> Vec<Diagnostic> {
    let toks = lex(src);
    let code: Vec<&Tok> = toks.iter().filter(|t| t.is_code()).collect();
    let comments: Vec<&Tok> = toks.iter().filter(|t| !t.is_code()).collect();
    let mut diags: Vec<Diagnostic> = Vec::new();

    // Test-region cutoff: first `#[cfg(test)]` in the code stream.
    let mut test_from_line = None;
    for w in code.windows(7) {
        if w[0].text == "#"
            && w[1].text == "["
            && w[2].text == "cfg"
            && w[3].text == "("
            && w[4].text == "test"
            && w[5].text == ")"
            && w[6].text == "]"
        {
            test_from_line = Some(w[0].line);
            break;
        }
    }

    // Collect `det:` annotations and their target lines. An annotation
    // is a plain `// det: …` line comment — `det:` first, so doc comments
    // and prose that merely *mention* the grammar are not annotations.
    let mut anns: Vec<Annotation> = Vec::new();
    for c in &comments {
        let Some(body) = c.text.strip_prefix("//") else { continue };
        if body.starts_with('/') || body.starts_with('!') {
            continue;
        }
        let Some(spec) = body.trim_start().strip_prefix("det:") else { continue };
        let spec = spec.trim();
        // Target: the same line when code precedes the comment on it,
        // otherwise the next line holding a code token.
        let target = if code.iter().any(|t| t.line == c.line) {
            Some(c.line)
        } else {
            code.iter().map(|t| t.line).filter(|&l| l > c.line).min()
        };
        match parse_annotation(spec) {
            Some(rule) => anns.push(Annotation { rule, target, line: c.line, used: false }),
            None => diags.push(Diagnostic {
                file: display.to_string(),
                line: c.line,
                rule: "R0",
                token: "det:".to_string(),
                message: format!("malformed det: annotation {spec:?}"),
            }),
        }
    }

    let ctx = FileCtx { rel, code, comments, test_from_line };
    let mut em = Emitter { file: display.to_string(), anns: &mut anns, diags: &mut diags };
    rules::r1_hash_order(&ctx, &mut em);
    rules::r2_ambient(&ctx, &mut em);
    rules::r3_unsafe(&ctx, &mut em);
    rules::r4_pool_accumulation(&ctx, &mut em);

    // Stale suppressions are errors themselves.
    for a in &anns {
        if !a.used {
            diags.push(Diagnostic {
                file: display.to_string(),
                line: a.line,
                rule: "R0",
                token: "det:".to_string(),
                message: "unused det: annotation (nothing suppressed)".to_string(),
            });
        }
    }
    diags
}

/// Recursively collect `.rs` files under `dir`, sorted at every level —
/// the lint's own output order must not depend on directory-entry order.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        entries.push(entry.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (normally `rust/src`). Diagnostics
/// come back in deterministic (sorted-walk, then line) order.
pub fn run(root: &Path) -> Result<LintReport, String> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut report = LintReport::default();
    for f in &files {
        let rel: String = f
            .strip_prefix(root)
            .map_err(|e| e.to_string())?
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let display = f.display().to_string();
        let src = std::fs::read_to_string(f).map_err(|e| format!("{}: {e}", f.display()))?;
        report.diagnostics.extend(lint_source(&rel, &display, &src));
        report.files += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_pass_self_test() {
        self_test().unwrap();
    }

    #[test]
    fn repo_is_lint_clean() {
        // The determinism contract gates the crate's own source: every
        // hash-order iteration is sorted or justified, ambient
        // nondeterminism stays in main/testing, unsafe stays in the pool.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = run(&root).unwrap();
        assert!(report.files > 50, "walk found too few files: {}", report.files);
        let rendered: Vec<String> =
            report.diagnostics.iter().map(|d| d.to_string()).collect();
        assert!(report.clean(), "lint findings in the repo:\n{}", rendered.join("\n"));
    }

    #[test]
    fn annotation_grammar() {
        assert_eq!(parse_annotation("sorted(pid)"), Some("R1"));
        assert_eq!(parse_annotation("allow(R2): reads only a log gate"), Some("R2"));
        assert_eq!(parse_annotation("sorted()"), None);
        assert_eq!(parse_annotation("allow(R2):"), None);
        assert_eq!(parse_annotation("allow(R9): nope"), None);
        assert_eq!(parse_annotation("because reasons"), None);
    }

    #[test]
    fn diagnostics_render_file_line_rule() {
        let d = Diagnostic {
            file: "src/sim/engine.rs".to_string(),
            line: 7,
            rule: "R1",
            token: "m.keys()".to_string(),
            message: "hash-order iteration escapes".to_string(),
        };
        let s = d.to_string();
        assert!(s.starts_with("src/sim/engine.rs:7: R1"));
        let j = d.to_json();
        assert_eq!(j.get("line").and_then(|v| v.as_i64()), Some(7));
        assert_eq!(j.get("rule").and_then(|v| v.as_str()), Some("R1"));
    }
}
