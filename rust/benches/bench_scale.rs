//! Scale benchmarks for the event-driven engine and the scoring arena:
//! - reused-arena `ScoreArena::fill` vs per-cycle `ScoreInputs::zeros`
//!   rebuilding (`build_inputs`) on a 64-node × full-corpus cluster — the
//!   arena must win, since steady-state cycles touch only dirty rows;
//! - event-engine throughput on a timed trace with finite-duration pods,
//!   GC, and scheduling-queue retries (default 20k pods; set
//!   LRSCHED_BENCH_FULL=1 for the 100k-pod acceptance run);
//! - the same trace under **churn** (node joins/drains, a 5% crash rate,
//!   and a registry outage window) — volatility bookkeeping must keep
//!   event throughput within 1.5× of the static-cluster baseline;
//! - the same trace with the **peer swarm** on (125 MB/s LAN, seeder
//!   cap 4 — the `scale --p2p` defaults) vs the pure-registry run:
//!   deployment cost (WAN GB) and total startup seconds side by side;
//!   the swarm must cut WAN bytes strictly, stay accounting-balanced,
//!   and never exceed the seeder cap;
//! - trace import + replay throughput on a synthetic Alibaba CSV;
//! - **streaming ingest**: a generated `.csv.gz` (1M rows under
//!   `LRSCHED_BENCH_FULL=1`, 100k otherwise) through the constant-memory
//!   pipeline — streaming gzip inflate, two-pass scan, pull-based
//!   `ArrivalSource` — reporting rows/sec and the peak reorder-buffer
//!   depth;
//! - **sharded event lanes**: the churn workload on a 256-node fleet at
//!   `shards ∈ {1, 4}` — the reports must be byte-identical, and under
//!   `LRSCHED_BENCH_STRICT=1` with ≥4 hardware threads the 4-lane run
//!   must be ≥2× the single-lane engine-event throughput (the PR 4
//!   acceptance criterion, enforced by the CI bench job);
//! - **parked-heavy engine** (`engine_parked_*`): a churn + disk-starved
//!   Zipf overload on 16 small nodes that keeps the scheduling queue
//!   non-empty ≥80% of sim-time, at shards {1, 4} plus a shards-4 run
//!   with `cure_aware_windows` off (the pre-PR conservative guard). All
//!   three byte-identical; under `LRSCHED_BENCH_STRICT=1` with ≥4
//!   hardware threads the cure-aware 4-lane run must be ≥1.5× the
//!   conservative engine-event throughput (the wake-safe-windows
//!   acceptance criterion);
//! - **cache policies** (`engine_cache_*`): a Zipf-skewed trace on a
//!   disk-starved 16-node fleet (2 GB disks, so image GC churns) once
//!   per `--cache-policy`, recording cache hit rate and deployment cost
//!   (WAN GB) for each eviction order side by side.
//!
//! Run: `cargo bench --bench bench_scale`
//!
//! CI mode: `cargo bench --bench bench_scale -- --json BENCH_PR4.json \
//!   --baseline BENCH_baseline.json --max-regress 0.30` additionally
//! writes every mode's throughput as JSON and exits nonzero if any mode
//! regressed more than `--max-regress` against the committed baseline
//! (a baseline with `"bootstrap": true` is record-only).

use lrsched::cli::{self, OptSpec};
use lrsched::cluster::{ClusterState, NodeId, PodBuilder, Resources};
use lrsched::exp::common;
use lrsched::registry::{hub, Registry};
use lrsched::sched::lrscheduler::build_inputs;
use lrsched::sched::scoring::ScoreArena;
use lrsched::sched::{default_framework, CycleContext, NativeScorer, ScoringBackend, WeightParams};
use lrsched::serve::Session;
use lrsched::sim::{
    trace, ArrivalSource, CachePolicyChoice, ChurnConfig, ErrorMode, Popularity, SchedulerChoice,
    SimConfig, SimReport, Simulation, TraceOptions, TraceReplay, WorkloadConfig, WorkloadGen,
};
use lrsched::testing::bench::{bench, header};
use lrsched::testing::fixtures;
use lrsched::testing::fixtures::synthetic_alibaba_csv;
use lrsched::util::json::{self, Json};
use std::time::Instant;

/// 64 warm nodes over the whole corpus: the dense-scoring shape the
/// acceptance criterion names.
fn warm_cluster() -> ClusterState {
    let mut state = ClusterState::new();
    for node in common::scale_nodes(64) {
        state.add_node(node);
    }
    // Intern the full corpus and warm every node with a few images so the
    // presence matrix is realistic (and every layer id is live).
    let corpus = hub::corpus();
    for (i, m) in corpus.iter().enumerate() {
        let (_, layers) = state.intern_image(m);
        for k in 0..3u32 {
            let node = NodeId(((i as u32).wrapping_mul(7).wrapping_add(k * 11)) % 64);
            let _ = state.install_image(node, &m.image_ref(), &layers);
        }
    }
    state
}

/// One recorded throughput mode for the JSON report / regression gate.
struct Mode {
    name: &'static str,
    value: f64,
    unit: &'static str,
    higher_is_better: bool,
}

fn spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "json", help: "write mode throughputs to this JSON file", default: Some("") },
        OptSpec {
            name: "baseline",
            help: "committed baseline JSON to gate regressions against",
            default: Some(""),
        },
        OptSpec {
            name: "max-regress",
            help: "fail if any mode regresses more than this fraction",
            default: Some("0.30"),
        },
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, &spec()).unwrap_or_else(|e| {
        eprintln!("error: {e}\n{}", cli::usage("bench_scale", "Scale benchmarks", &spec()));
        std::process::exit(2);
    });
    let max_regress = args.f64_or("max-regress", 0.30).expect("valid --max-regress");
    let mut modes: Vec<Mode> = Vec::new();

    println!("{}", header());

    // --- arena vs zeros rebuild ------------------------------------------
    let mut state = warm_cluster();
    let cache = fixtures::corpus_cache();
    let pod = PodBuilder::new().build("wordpress:6.4", Resources::cores_gb(0.25, 0.25));
    let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
    let meta = meta.cloned();
    let ctx = CycleContext::new(&state, &pod, meta.as_ref(), req, bytes);
    let fw = default_framework();
    let feasible = fw.feasible(&ctx).expect("feasible");
    let scores = fw.score(&ctx, &feasible);
    let params = WeightParams::default();
    let (n, l) = (state.node_count(), state.interner.len());

    let r_zeros = bench(&format!("build_inputs zeros rebuild {n}x{l}"), 300, || {
        std::hint::black_box(build_inputs(&ctx, &scores, &params));
    });
    println!("{}", r_zeros.report());

    let mut arena = ScoreArena::new();
    std::hint::black_box(arena.fill(&ctx, &scores, &params)); // cold fill
    let r_arena = bench(&format!("ScoreArena reused fill {n}x{l}"), 300, || {
        std::hint::black_box(arena.fill(&ctx, &scores, &params));
    });
    println!("{}", r_arena.report());
    let speedup = r_zeros.mean_ns / r_arena.mean_ns.max(1.0);
    println!(
        "arena speedup vs zeros rebuild: {speedup:.1}x (rows refilled {}, full rebuilds {})",
        arena.rows_refilled, arena.full_rebuilds
    );
    assert!(
        r_arena.mean_ns < r_zeros.mean_ns,
        "reused arena must beat per-cycle zeros rebuild: {} vs {} ns",
        r_arena.mean_ns,
        r_zeros.mean_ns
    );
    modes.push(Mode {
        name: "arena_fill",
        value: r_arena.mean_ns,
        unit: "ns/iter",
        higher_is_better: false,
    });

    // Full dense cycle through each input path for context.
    let mut scorer = NativeScorer;
    let r = bench("dense score via arena inputs", 200, || {
        let inputs = arena.fill(&ctx, &scores, &params);
        std::hint::black_box(scorer.score(inputs));
    });
    println!("{}", r.report());

    // --- event-engine scale run ------------------------------------------
    let full = std::env::var("LRSCHED_BENCH_FULL").is_ok();
    let pods = if full { 100_000 } else { 20_000 };
    let engine_run = |churn: Option<ChurnConfig>, p2p: Option<(f64, usize)>| {
        let registry = Registry::with_corpus();
        let trace = WorkloadGen::new(
            &registry,
            WorkloadConfig {
                seed: 42,
                popularity: Popularity::Zipf(1.1),
                duration_range: Some((30.0, 300.0)),
                ..Default::default()
            },
        )
        .trace(pods);
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        cfg.inter_arrival_secs = Some(0.3);
        cfg.gc_enabled = true;
        cfg.retry_limit = 10;
        cfg.snapshot_every = 1000;
        cfg.churn = churn;
        if let Some((lan_mbps, cap)) = p2p {
            cfg.p2p_lan_mbps = Some(lan_mbps);
            cfg.p2p_seeder_cap = cap;
        }
        let mut sim = Simulation::new(common::scale_nodes(64), registry, cfg)
            .with_backend(Box::new(NativeScorer));
        let t0 = Instant::now();
        let report = sim.run_trace(trace);
        let wall = t0.elapsed().as_secs_f64();
        sim.state.check_invariants().expect("invariants");
        let (virtual_secs, events) = (sim.clock.now(), sim.events_queued());
        (report, wall, virtual_secs, events)
    };

    let (report, wall, virtual_secs, events) = engine_run(None, None);
    println!(
        "event engine: {pods} pods / 64 nodes in {wall:.2}s wall ({:.0} pods/s), \
         virtual {virtual_secs:.0}s, events {events}",
        pods as f64 / wall.max(1e-9),
    );
    println!(
        "  completed={} failed={} unschedulable={} retries={} download={:.1} GB",
        report.completed(),
        report.failed_pulls,
        report.unschedulable,
        report.retries,
        report.total_download().as_gb()
    );
    assert!(
        report.accounting_balanced(),
        "dropped events: completed {} + failed {} + unschedulable {} + lost {} != submitted {}",
        report.completed(),
        report.failed_pulls,
        report.unschedulable,
        report.lost_to_crash,
        report.submitted
    );
    println!("  accounting balanced: no dropped events");
    modes.push(Mode {
        name: "engine",
        value: events as f64 / wall.max(1e-9),
        unit: "events/sec",
        higher_is_better: true,
    });

    // --- churn mode: joins/drains, 5% crash rate, one outage window ------
    let churn = ChurnConfig {
        seed: 42,
        horizon_secs: pods as f64 * 0.3,
        joins: 3,
        drains: 2,
        crash_fraction: 0.05,
        outages: 1,
        outage_secs: 60.0,
        ..Default::default()
    };
    let (creport, cwall, cvirtual, cevents) = engine_run(Some(churn.clone()), None);
    println!(
        "churn engine: {pods} pods / 64 nodes in {cwall:.2}s wall ({:.0} pods/s), \
         virtual {cvirtual:.0}s, events {cevents}",
        pods as f64 / cwall.max(1e-9),
    );
    println!(
        "  joined={} drained={} crashed={} resubmitted={} stalled={} wakeups={} lost={}",
        creport.nodes_joined,
        creport.nodes_drained,
        creport.nodes_crashed,
        creport.resubmitted,
        creport.pulls_stalled,
        creport.wakeups,
        creport.lost_to_crash
    );
    assert!(creport.accounting_balanced(), "churn run dropped events");
    assert!(creport.nodes_crashed >= 1, "5% of 64 nodes must crash");
    let slowdown = cwall / wall.max(1e-9);
    println!("  churn slowdown vs static cluster: {slowdown:.2}x (budget 1.5x)");
    assert!(
        slowdown <= 1.5,
        "churn bookkeeping degraded event throughput {slowdown:.2}x (> 1.5x budget)"
    );
    modes.push(Mode {
        name: "engine_churn",
        value: cevents as f64 / cwall.max(1e-9),
        unit: "events/sec",
        higher_is_better: true,
    });

    // --- p2p swarm mode: peer-sourced pulls vs pure registry -------------
    // Same trace as the pure-registry engine run above, with the swarm on
    // at the `scale --p2p` defaults. Deployment cost = WAN bytes billed to
    // the registry; startup = total download seconds across all pods.
    let (lan_mbps, seeder_cap) = (125.0, 4usize);
    let (preport, pwall, pvirtual, pevents) = engine_run(None, Some((lan_mbps, seeder_cap)));
    println!(
        "p2p engine: {pods} pods / 64 nodes in {pwall:.2}s wall ({:.0} pods/s), \
         virtual {pvirtual:.0}s, events {pevents}",
        pods as f64 / pwall.max(1e-9),
    );
    println!(
        "  wan={:.1} GB vs registry-only {:.1} GB, p2p={:.1} GB, peak_uploads={} (cap {}), \
         startup {:.0}s total vs registry-only {:.0}s",
        preport.total_download().as_gb(),
        report.total_download().as_gb(),
        preport.total_p2p().as_gb(),
        preport.peak_peer_uploads,
        seeder_cap,
        preport.total_download_secs(),
        report.total_download_secs(),
    );
    assert!(preport.accounting_balanced(), "p2p run dropped events");
    assert!(
        preport.total_download() < report.total_download(),
        "the swarm must cut WAN bytes vs pure registry: {:.1} vs {:.1} GB",
        preport.total_download().as_gb(),
        report.total_download().as_gb()
    );
    assert!(
        preport.peak_peer_uploads <= seeder_cap,
        "seeder served {} concurrent uploads (cap {seeder_cap})",
        preport.peak_peer_uploads
    );
    modes.push(Mode {
        name: "engine_p2p",
        value: pevents as f64 / pwall.max(1e-9),
        unit: "events/sec",
        higher_is_better: true,
    });

    // --- trace-replay mode: import + synthesize + replay -----------------
    let rows = if full { 60_000 } else { 12_000 };
    let csv = synthetic_alibaba_csv(rows, 42);
    let t0 = Instant::now();
    let parsed = trace::parse_reader(
        std::io::Cursor::new(csv.as_bytes()),
        &TraceOptions { speedup: 4.0, ..Default::default() },
    )
    .expect("synthetic trace parses");
    let parse_wall = t0.elapsed().as_secs_f64();
    let registry = parsed.synthesize_registry();
    let arrivals = parsed.arrivals();
    let n_events = arrivals.len();
    println!(
        "trace import: {rows} rows → {n_events} events / {} apps in {parse_wall:.2}s \
         ({:.0} rows/s)",
        parsed.stats.apps,
        rows as f64 / parse_wall.max(1e-9),
    );
    modes.push(Mode {
        name: "trace_import",
        value: rows as f64 / parse_wall.max(1e-9),
        unit: "rows/sec",
        higher_is_better: true,
    });
    let mut cfg = SimConfig::default();
    cfg.scheduler = SchedulerChoice::LR;
    cfg.inter_arrival_secs = Some(0.3);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 1000;
    let mut sim = Simulation::new(common::scale_nodes(64), registry, cfg)
        .with_backend(Box::new(NativeScorer));
    let t0 = Instant::now();
    let treport = sim.run_arrivals(arrivals);
    let replay_wall = t0.elapsed().as_secs_f64();
    sim.state.check_invariants().expect("invariants");
    println!(
        "trace replay: {n_events} pods / 64 nodes in {replay_wall:.2}s wall \
         ({:.0} pods/s), virtual {:.0}s, events {}",
        n_events as f64 / replay_wall.max(1e-9),
        sim.clock.now(),
        sim.events_queued(),
    );
    println!(
        "  completed={} failed={} unschedulable={} download={:.1} GB",
        treport.completed(),
        treport.failed_pulls,
        treport.unschedulable,
        treport.total_download().as_gb()
    );
    assert!(treport.accounting_balanced(), "trace replay dropped events");
    modes.push(Mode {
        name: "trace_replay",
        value: n_events as f64 / replay_wall.max(1e-9),
        unit: "pods/sec",
        higher_is_better: true,
    });

    // --- streaming-ingest mode: .csv.gz → scan → pull, constant memory ---
    // The whole pipeline the 1M-row CI bounded-memory gate exercises:
    // stored-block gzip on disk, streaming inflate, two-pass scan +
    // pull-based arrival source. Throughput is rows/sec over both passes.
    let ingest_rows = if full { 1_000_000 } else { 100_000 };
    let gz_path = std::env::temp_dir()
        .join(format!("lrsched-bench-ingest-{}.csv.gz", std::process::id()));
    {
        let csv = synthetic_alibaba_csv(ingest_rows, 7);
        let gz = lrsched::util::gzip::compress_stored(csv.as_bytes());
        std::fs::write(&gz_path, &gz).expect("write bench trace");
    }
    let t0 = Instant::now();
    let replay = TraceReplay::open(&gz_path, &TraceOptions { speedup: 4.0, ..Default::default() })
        .expect("bench trace parses");
    let ingest_stats = replay.stats.clone();
    let mut src = replay.into_source();
    let mut pulled = 0usize;
    let mut last_off = 0.0f64;
    while let Some((off, pod)) = src.next_arrival() {
        std::hint::black_box(&pod);
        assert!(off >= last_off, "source offsets must be non-decreasing");
        last_off = off;
        pulled += 1;
    }
    assert!(src.take_error().is_none(), "streaming ingest failed");
    let ingest_wall = t0.elapsed().as_secs_f64();
    let _ = std::fs::remove_file(&gz_path);
    assert_eq!(pulled, ingest_stats.events, "source must emit every scanned event");
    println!(
        "stream ingest: {ingest_rows} rows (.csv.gz) → {} events scanned + pulled in \
         {ingest_wall:.2}s ({:.0} rows/s), peak reorder depth {} (cap 65536), path={}",
        ingest_stats.events,
        ingest_rows as f64 / ingest_wall.max(1e-9),
        ingest_stats.reorder_depth,
        ingest_stats.ingest_path.label(),
    );
    modes.push(Mode {
        name: "stream_ingest",
        value: ingest_rows as f64 / ingest_wall.max(1e-9),
        unit: "rows/sec",
        higher_is_better: true,
    });

    // --- sharded event lanes: 256-node churn fleet, shards {1, 4} --------
    // Big fleet: per-cycle work is O(nodes), which is what the lanes
    // absorb; the node-local pull/termination/GC windows ride along.
    let shard_nodes = 256;
    let sharded_run = |shards: usize| -> (SimReport, String, f64, u64) {
        let registry = Registry::with_corpus();
        let trace = WorkloadGen::new(
            &registry,
            WorkloadConfig {
                seed: 42,
                popularity: Popularity::Zipf(1.1),
                duration_range: Some((30.0, 300.0)),
                ..Default::default()
            },
        )
        .trace(pods);
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        cfg.inter_arrival_secs = Some(0.3);
        cfg.gc_enabled = true;
        cfg.retry_limit = 10;
        cfg.snapshot_every = 1000;
        cfg.shards = shards;
        cfg.churn = Some(ChurnConfig {
            seed: 42,
            horizon_secs: pods as f64 * 0.3,
            joins: 3,
            drains: 2,
            crash_fraction: 0.05,
            outages: 1,
            outage_secs: 60.0,
            ..Default::default()
        });
        let mut sim = Simulation::new(common::scale_nodes(shard_nodes), registry, cfg);
        let t0 = Instant::now();
        let report = sim.run_trace(trace);
        let wall = t0.elapsed().as_secs_f64();
        sim.state.check_invariants().expect("invariants");
        assert!(report.accounting_balanced(), "sharded run dropped events");
        let events = sim.events_queued();
        let fingerprint = format!("{}\n{}", report.render(), sim.events.render());
        (report, fingerprint, wall, events)
    };
    let (_r1, fp1, wall1, ev1) = sharded_run(1);
    let (_r4, fp4, wall4, ev4) = sharded_run(4);
    assert_eq!(ev1, ev4, "sharded run queued a different number of events");
    assert!(
        fp1 == fp4,
        "sharded run is not byte-identical to the single-lane engine"
    );
    let tput1 = ev1 as f64 / wall1.max(1e-9);
    let tput4 = ev4 as f64 / wall4.max(1e-9);
    let lane_speedup = tput4 / tput1.max(1e-9);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "sharded engine: {pods} pods / {shard_nodes} nodes (churn): shards=1 {wall1:.2}s \
         ({tput1:.0} ev/s), shards=4 {wall4:.2}s ({tput4:.0} ev/s) → {lane_speedup:.2}x \
         on {threads} hardware threads"
    );
    println!("  byte-identical across shard counts: yes");
    // The PR 4 acceptance criterion: ≥2× engine-event throughput at 4
    // lanes. It needs ≥4 hardware threads and a quiet machine, so the hard
    // assert is opt-in (LRSCHED_BENCH_STRICT=1 — set by the CI bench job
    // on the pinned runner); every run records the ratio in the JSON.
    let strict = std::env::var("LRSCHED_BENCH_STRICT").is_ok();
    if strict && threads >= 4 {
        assert!(
            lane_speedup >= 2.0,
            "4-lane engine-event throughput must be ≥2x the single lane, got {lane_speedup:.2}x"
        );
    } else if threads >= 4 && lane_speedup < 2.0 {
        println!(
            "  WARNING: lane speedup {lane_speedup:.2}x below the 2x target \
             (set LRSCHED_BENCH_STRICT=1 to enforce)"
        );
    }
    modes.push(Mode {
        name: "engine_sharded_1",
        value: tput1,
        unit: "events/sec",
        higher_is_better: true,
    });
    modes.push(Mode {
        name: "engine_sharded_4",
        value: tput4,
        unit: "events/sec",
        higher_is_better: true,
    });

    // --- parked-heavy mode: lanes must stay parallel while pods park -----
    // The regime the paper's edge clusters actually live in: a churn +
    // disk-starved overload that keeps the scheduling queue non-empty for
    // ≥80% of sim-time (pods perpetually park on capacity and wake on
    // terminations/evictions). Pre-PR, any parked pod collapsed the
    // sharded engine to fully sequential draining; cure-aware windows
    // keep the lanes busy between wake-relevant events. Three runs on the
    // identical workload: shards=1 (sequential reference), shards=4
    // cure-aware, and shards=4 with `cure_aware_windows=false` (the
    // pre-PR conservative guard) — all three byte-identical, with the
    // cure-aware/conservative ratio as the tentpole's measured win.
    let parked_pods = if full { 20_000 } else { 6_000 };
    let parked_run = |shards: usize, cure_aware: bool| -> (SimReport, String, f64, u64, f64, u64) {
        let registry = Registry::with_corpus();
        let trace = WorkloadGen::new(
            &registry,
            WorkloadConfig {
                seed: 42,
                popularity: Popularity::Zipf(1.3),
                duration_range: Some((5.0, 60.0)),
                ..Default::default()
            },
        )
        .trace(parked_pods);
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        // 3x overload: ~mean duration 32.5s / 0.08s arrivals ≈ 406
        // concurrent pods wanted vs ~142 cpu slots on 16 nodes — the
        // queue never empties once warm.
        cfg.inter_arrival_secs = Some(0.08);
        cfg.gc_enabled = true;
        cfg.retry_limit = 10;
        cfg.snapshot_every = 1000;
        cfg.shards = shards;
        cfg.cure_aware_windows = cure_aware;
        cfg.churn = Some(ChurnConfig {
            seed: 42,
            horizon_secs: parked_pods as f64 * 0.08,
            joins: 2,
            drains: 1,
            crash_fraction: 0.05,
            outages: 1,
            outage_secs: 30.0,
            ..Default::default()
        });
        // 2 GB disks: image GC churns, so parks are disk-cured as well as
        // cpu-cured and evicting sweeps are real wake sources.
        let mut sim = Simulation::new(common::scale_nodes_with_disk(16, 2.0), registry, cfg)
            .with_backend(Box::new(NativeScorer));
        let t0 = Instant::now();
        let report = sim.run_trace(trace);
        let wall = t0.elapsed().as_secs_f64();
        sim.state.check_invariants().expect("invariants");
        assert!(report.accounting_balanced(), "parked run dropped events");
        let ws = sim.window_stats();
        let occupancy = ws.parked_busy_secs / sim.clock.now().max(1e-9);
        let fingerprint = format!("{}\n{}", report.render(), sim.events.render());
        (report, fingerprint, wall, sim.events_queued(), occupancy, ws.wake_stops)
    };
    let (qreport, qfp1, qwall1, qev1, qocc, _) = parked_run(1, true);
    let (_q4, qfp4, qwall4, qev4, _, q_wake_stops) = parked_run(4, true);
    let (_qc, qfpc, qwallc, qevc, _, _) = parked_run(4, false);
    assert_eq!(qev1, qev4, "parked cure-aware run queued a different number of events");
    assert_eq!(qev1, qevc, "parked conservative run queued a different number of events");
    assert!(qfp1 == qfp4, "cure-aware parked run is not byte-identical to the single lane");
    assert!(qfp1 == qfpc, "conservative parked run is not byte-identical to the single lane");
    // The workload contract: pods must actually sit parked for ≥80% of
    // sim-time (deterministic — virtual-time occupancy, not wall time),
    // otherwise this mode is not measuring the parked regime at all.
    assert!(
        qocc >= 0.8,
        "parked-heavy workload kept the queue parked only {:.0}% of sim-time (need ≥80%)",
        qocc * 100.0
    );
    assert!(
        q_wake_stops > 0,
        "cure-aware windows never hit a wake-relevant event; the workload is not parking"
    );
    let qtput1 = qev1 as f64 / qwall1.max(1e-9);
    let qtput4 = qev4 as f64 / qwall4.max(1e-9);
    let qtputc = qevc as f64 / qwallc.max(1e-9);
    let parked_speedup = qtput4 / qtputc.max(1e-9);
    println!(
        "parked engine: {parked_pods} pods / 16 nodes (churn, 2 GB disks, parked \
         {:.0}% of sim-time, wakeups={}): shards=1 {qwall1:.2}s ({qtput1:.0} ev/s), \
         shards=4 cure-aware {qwall4:.2}s ({qtput4:.0} ev/s), shards=4 conservative \
         {qwallc:.2}s ({qtputc:.0} ev/s) → {parked_speedup:.2}x cure-aware win",
        qocc * 100.0,
        qreport.wakeups,
    );
    println!("  byte-identical across shard counts and window modes: yes");
    // The tentpole acceptance criterion: ≥1.5x engine-event throughput on
    // the parked-heavy workload vs the pre-PR sequential-stretch
    // behavior. Like the PR 4 lane gate it needs ≥4 hardware threads and
    // a quiet machine, so the hard assert is opt-in via
    // LRSCHED_BENCH_STRICT=1 (set by the CI bench job).
    if strict && threads >= 4 {
        assert!(
            parked_speedup >= 1.5,
            "cure-aware windows must be ≥1.5x the conservative parked engine, \
             got {parked_speedup:.2}x"
        );
    } else if threads >= 4 && parked_speedup < 1.5 {
        println!(
            "  WARNING: parked cure-aware speedup {parked_speedup:.2}x below the 1.5x \
             target (set LRSCHED_BENCH_STRICT=1 to enforce)"
        );
    }
    modes.push(Mode {
        name: "engine_parked_1",
        value: qtput1,
        unit: "events/sec",
        higher_is_better: true,
    });
    modes.push(Mode {
        name: "engine_parked_4",
        value: qtput4,
        unit: "events/sec",
        higher_is_better: true,
    });
    modes.push(Mode {
        name: "engine_parked_4_conservative",
        value: qtputc,
        unit: "events/sec",
        higher_is_better: true,
    });

    // --- cache-policy mode: hit rate + deployment GB per policy ----------
    // Disk-starved fleet (2 GB/node — a handful of corpus images) so
    // kubelet GC churns constantly: the eviction order is what separates
    // the policies on a skewed workload.
    let cache_pods = if full { 20_000 } else { 4_000 };
    let cache_run = |policy: CachePolicyChoice| -> SimReport {
        let registry = Registry::with_corpus();
        let trace = WorkloadGen::new(
            &registry,
            WorkloadConfig {
                seed: 42,
                popularity: Popularity::Zipf(1.3),
                duration_range: Some((5.0, 60.0)),
                ..Default::default()
            },
        )
        .trace(cache_pods);
        let mut cfg = SimConfig::default();
        cfg.scheduler = SchedulerChoice::LR;
        cfg.inter_arrival_secs = Some(0.5);
        cfg.gc_enabled = true;
        cfg.retry_limit = 10;
        cfg.snapshot_every = 1000;
        cfg.cache_policy = policy;
        let mut sim = Simulation::new(common::scale_nodes_with_disk(16, 2.0), registry, cfg)
            .with_backend(Box::new(NativeScorer));
        let report = sim.run_trace(trace);
        sim.state.check_invariants().expect("invariants");
        assert!(report.accounting_balanced(), "cache-policy run dropped events");
        report
    };
    for policy in CachePolicyChoice::all() {
        let rep = cache_run(policy);
        println!(
            "cache policy {}: hit_rate={:.3} wan={:.1} GB evicted={:.1} GB prefetched={:.1} GB",
            policy.label(),
            rep.cache_hit_rate,
            rep.total_download().as_gb(),
            rep.evicted_bytes.as_gb(),
            rep.prefetched_bytes.as_gb(),
        );
        // Mode names must be static for the JSON gate.
        let (hit_name, wan_name): (&'static str, &'static str) = match policy {
            CachePolicyChoice::PressureSweep => {
                ("engine_cache_pressure_hit", "engine_cache_pressure_wan")
            }
            CachePolicyChoice::Lru => ("engine_cache_lru_hit", "engine_cache_lru_wan"),
            CachePolicyChoice::Popularity => {
                ("engine_cache_popularity_hit", "engine_cache_popularity_wan")
            }
            CachePolicyChoice::ScorerKeepSet => {
                ("engine_cache_scorer_hit", "engine_cache_scorer_wan")
            }
            CachePolicyChoice::Prefetch => {
                ("engine_cache_prefetch_hit", "engine_cache_prefetch_wan")
            }
        };
        modes.push(Mode {
            name: hit_name,
            value: rep.cache_hit_rate,
            unit: "fraction",
            higher_is_better: true,
        });
        modes.push(Mode {
            name: wan_name,
            value: rep.total_download().as_gb(),
            unit: "GB",
            higher_is_better: false,
        });
    }

    // --- serve mode: online decision latency on a 10k-node fleet ---------
    // The `lrsched serve` hot path: one pod event in through
    // Session::submit_pod, one decision line out, on a fleet two orders
    // of magnitude past the paper's testbed. Reports sustained
    // decisions/sec plus per-decision p50/p99 wall latency — the numbers
    // docs/SERVE.md quotes as the sizing guidance.
    let serve_nodes = 10_000;
    let serve_pods = if full { 10_000 } else { 2_000 };
    let registry = Registry::with_corpus();
    let serve_trace = WorkloadGen::new(
        &registry,
        WorkloadConfig {
            seed: 42,
            popularity: Popularity::Zipf(1.1),
            duration_range: Some((30.0, 300.0)),
            ..Default::default()
        },
    )
    .trace(serve_pods);
    let mut cfg = SimConfig::default();
    cfg.scheduler = SchedulerChoice::LR;
    cfg.inter_arrival_secs = Some(0.3);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 1000;
    let mut serve_sim = Simulation::new(common::scale_nodes(serve_nodes), registry, cfg)
        .with_backend(Box::new(NativeScorer));
    let wall0 = Instant::now();
    let mut session = Session::new(
        &mut serve_sim,
        ErrorMode::Strict,
        Box::new(move || wall0.elapsed().as_micros() as u64),
    );
    let mut out: Vec<String> = Vec::with_capacity(serve_pods + 1);
    let mut lat_us: Vec<u64> = Vec::with_capacity(serve_pods);
    let t0 = Instant::now();
    for (i, pod) in serve_trace.into_iter().enumerate() {
        let s = Instant::now();
        session.submit_pod(i as f64 * 0.3, pod, &mut out);
        lat_us.push(s.elapsed().as_micros() as u64);
    }
    let sreport = session.finish(&mut out);
    let serve_wall = t0.elapsed().as_secs_f64();
    let decisions = session.stats.decisions;
    assert!(sreport.accounting_balanced(), "serve run dropped events");
    assert_eq!(out.len(), decisions + 1, "decision lines + one summary");
    assert!(
        decisions >= serve_pods / 2,
        "a 10k-node fleet should bind most of {serve_pods} pods, got {decisions} decisions"
    );
    lat_us.sort_unstable();
    let pct = |p: usize| lat_us[(lat_us.len() * p / 100).min(lat_us.len() - 1)];
    let (p50, p99) = (pct(50), pct(99));
    println!(
        "serve engine: {serve_pods} pod events / {serve_nodes} nodes in {serve_wall:.2}s wall \
         ({:.0} decisions/s), decision latency p50={p50} µs p99={p99} µs",
        decisions as f64 / serve_wall.max(1e-9),
    );
    modes.push(Mode {
        name: "serve_decisions",
        value: decisions as f64 / serve_wall.max(1e-9),
        unit: "decisions/sec",
        higher_is_better: true,
    });
    modes.push(Mode { name: "serve_p50_us", value: p50 as f64, unit: "us", higher_is_better: false });
    modes.push(Mode { name: "serve_p99_us", value: p99 as f64, unit: "us", higher_is_better: false });

    // --- JSON report + regression gate -----------------------------------
    if let Some(path) = args.get("json") {
        let mut doc = Json::obj();
        doc.set("schema", Json::Int(1));
        doc.set("pods", Json::Int(pods as i64));
        doc.set("full", Json::Bool(full));
        doc.set("threads", Json::Int(threads as i64));
        doc.set("sharded_speedup", Json::Num(lane_speedup));
        let mut m = Json::obj();
        for mode in &modes {
            let mut entry = Json::obj();
            entry.set("value", Json::Num(mode.value));
            entry.set("unit", Json::Str(mode.unit.to_string()));
            entry.set("higher_is_better", Json::Bool(mode.higher_is_better));
            m.set(mode.name, entry);
        }
        doc.set("modes", m);
        std::fs::write(path, doc.to_string_pretty()).expect("write bench JSON");
        println!("wrote {path}");
    }
    if let Some(baseline_path) = args.get("baseline") {
        match check_baseline(baseline_path, &modes, max_regress) {
            Ok(msgs) => {
                for m in msgs {
                    println!("{m}");
                }
            }
            Err(failures) => {
                for f in &failures {
                    eprintln!("REGRESSION: {f}");
                }
                eprintln!(
                    "{} mode(s) regressed more than {:.0}% vs {baseline_path}",
                    failures.len(),
                    max_regress * 100.0
                );
                std::process::exit(1);
            }
        }
    }
}

/// Compare measured modes against a committed baseline. `Ok` carries
/// info lines; `Err` carries one line per regressed mode.
fn check_baseline(
    path: &str,
    modes: &[Mode],
    max_regress: f64,
) -> Result<Vec<String>, Vec<String>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return Ok(vec![format!("baseline {path} unreadable ({e}); gate inactive")]),
    };
    let doc = match json::parse(&text) {
        Ok(d) => d,
        Err(e) => return Ok(vec![format!("baseline {path} unparsable ({e}); gate inactive")]),
    };
    if doc.get("bootstrap").and_then(|b| b.as_bool()) == Some(true) {
        return Ok(vec![format!(
            "baseline {path} is a bootstrap placeholder; gate records only — commit a \
             measured BENCH_PR4.json from the pinned runner to arm it"
        )]);
    }
    let base_modes = match doc.get("modes") {
        Some(m) => m,
        None => return Ok(vec![format!("baseline {path} has no modes; gate inactive")]),
    };
    let mut info = Vec::new();
    let mut failures = Vec::new();
    for mode in modes {
        let old = base_modes
            .get(mode.name)
            .and_then(|e| e.get("value"))
            .and_then(|v| v.as_f64());
        let old = match old {
            Some(v) if v > 0.0 => v,
            _ => {
                info.push(format!("mode {}: no baseline value; recorded only", mode.name));
                continue;
            }
        };
        let (regressed, delta) = if mode.higher_is_better {
            (mode.value < old * (1.0 - max_regress), mode.value / old - 1.0)
        } else {
            (mode.value > old * (1.0 + max_regress), old / mode.value - 1.0)
        };
        let line = format!(
            "mode {}: {:.1} {} vs baseline {:.1} ({:+.1}%)",
            mode.name,
            mode.value,
            mode.unit,
            old,
            delta * 100.0
        );
        if regressed {
            failures.push(line);
        } else {
            info.push(line);
        }
    }
    if failures.is_empty() {
        Ok(info)
    } else {
        Err(failures)
    }
}
