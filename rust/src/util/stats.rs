//! Summary statistics used by the metrics pipeline and experiment reports.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for empty input.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolation percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Streaming mean/variance accumulator (Welford's algorithm) — used on the
/// simulator hot path so metrics collection never stores per-event vectors.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Welford {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean; 0.0 before any observation.
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Running population variance; 0.0 before any observation.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Running population standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation; 0.0 before any observation.
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observation; 0.0 before any observation.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.std_dev(), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        assert!((percentile(&xs, 10.0) - 1.4).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn single_value() {
        let mut w = Welford::new();
        w.push(3.5);
        assert_eq!(w.mean(), 3.5);
        assert_eq!(w.std_dev(), 0.0);
    }
}
