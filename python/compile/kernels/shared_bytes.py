"""L1 Pallas kernel: per-node shared-layer bytes (paper Eq. 2).

Computes ``shared[n] = sum_l present[n, l] * req[l] * sizes[l]`` — the
O(N*L) reduction at the heart of the layer-sharing score — as a tiled
masked mat-vec.

TPU mapping (DESIGN.md §Hardware-Adaptation): ``present`` streams from
HBM in (BN, BL) VMEM blocks; ``req * sizes`` is precomputed once into a
(BL,) VMEM vector per grid column; partials accumulate into the (BN,)
output block across the L grid axis. This is a VPU reduction (no MXU);
the roofline is HBM bandwidth. VMEM per block ≈ BN*BL*4 + BL*4 bytes
(≈ 9 KiB at BN=8, BL=256), far under budget, so BN can widen until
HBM-bound.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops (see
/opt/xla-example/README.md). Correctness vs. ``ref.py`` is enforced by
pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shape: 8 node-rows x 256 layer-columns.
DEFAULT_BLOCK_N = 8
DEFAULT_BLOCK_L = 256


def _shared_bytes_kernel(req_sizes_ref, present_ref, out_ref):
    """One (BN, BL) tile: out[BN] += present[BN, BL] @ req_sizes[BL]."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(present_ref[...], req_sizes_ref[...])


def shared_bytes(present, req, sizes, *, block_n=None, block_l=None):
    """shared[n] = sum_l present[n,l] * req[l] * sizes[l] via pallas_call.

    Shapes: present (N, L), req (L,), sizes (L,) -> (N,). N and L must be
    multiples of the block shape; the AOT variants are sized accordingly
    and the rust runtime pads.
    """
    n, l = present.shape
    bn = min(block_n or DEFAULT_BLOCK_N, n)
    bl = min(block_l or DEFAULT_BLOCK_L, l)
    if n % bn != 0 or l % bl != 0:
        raise ValueError(f"shape ({n},{l}) not divisible by block ({bn},{bl})")
    req_sizes = (req * sizes).astype(jnp.float32)
    grid = (n // bn, l // bl)
    return pl.pallas_call(
        _shared_bytes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl,), lambda i, j: (j,)),
            pl.BlockSpec((bn, bl), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(req_sizes, present.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_n", "block_l"))
def shared_bytes_jit(present, req, sizes, block_n=None, block_l=None):
    return shared_bytes(present, req, sizes, block_n=block_n, block_l=block_l)
