//! Shared experiment setup — the paper's §VI-A testbed, encoded.
//!
//! "All nodes have 4-core CPUs. … Worker node 1 has 4GB of memory and a
//! 30GB hard drive. Worker node 2 has 2GB of memory and a 30GB hard drive.
//! Worker nodes 3 and 4 each have 4GB of memory and a 20GB hard drive."
//! Experiments run with 3, 4, and 5 workers; the 5th reuses the w3/w4 spec.

use crate::cluster::{Node, NodeId, Pod, Resources};
use crate::registry::Registry;
use crate::sim::{SchedulerChoice, SimConfig, SimReport, Simulation, WorkloadConfig, WorkloadGen};
use crate::util::units::{Bandwidth, Bytes};

/// Default per-node downlink for experiments that don't sweep bandwidth.
pub const DEFAULT_BANDWIDTH_MBPS: f64 = 10.0;

/// Worker specs from §VI-A: (memory GB, disk GB), all 4-core.
const WORKER_SPECS: [(f64, f64); 5] = [
    (4.0, 30.0), // worker1
    (2.0, 30.0), // worker2
    (4.0, 20.0), // worker3
    (4.0, 20.0), // worker4
    (4.0, 20.0), // worker5 (5-node runs; spec follows w3/w4)
];

/// Build the paper's worker nodes (1-based names, as in the paper).
pub fn paper_nodes(n: usize) -> Vec<Node> {
    assert!((1..=WORKER_SPECS.len()).contains(&n), "supported node counts: 1..=5");
    (0..n)
        .map(|i| {
            let (mem_gb, disk_gb) = WORKER_SPECS[i];
            Node::new(
                NodeId(i as u32),
                &format!("worker{}", i + 1),
                Resources::cores_gb(4.0, mem_gb),
                Bytes::from_gb(disk_gb),
                Bandwidth::from_mbps(DEFAULT_BANDWIDTH_MBPS),
            )
        })
        .collect()
}

/// A uniform edge cluster for scale harnesses beyond the paper's 5-worker
/// testbed (the `scale` CLI subcommand and `bench_scale`): 4-core / 8 GB
/// workers with 64 GB disks and fast downlinks.
pub fn scale_nodes(n: usize) -> Vec<Node> {
    scale_nodes_with_disk(n, 64.0)
}

/// [`scale_nodes`] with a configurable per-node disk (`scale --disk-gb`):
/// disk-starved fleets put kubelet image GC — and with it the pluggable
/// cache policies — on the hot path.
pub fn scale_nodes_with_disk(n: usize, disk_gb: f64) -> Vec<Node> {
    (0..n)
        .map(|i| {
            Node::new(
                NodeId(i as u32),
                &format!("edge{:03}", i + 1),
                Resources::cores_gb(4.0, 8.0),
                Bytes::from_gb(disk_gb),
                Bandwidth::from_mbps(100.0),
            )
        })
        .collect()
}

/// The paper's 20-pod random-image workload (same trace for every
/// scheduler so comparisons are paired).
pub fn paper_trace(seed: u64, n_pods: usize) -> Vec<Pod> {
    let registry = Registry::with_corpus();
    let cfg = WorkloadConfig { seed, ..WorkloadConfig::default() };
    WorkloadGen::new(&registry, cfg).trace(n_pods)
}

/// Run one scheduler over a trace on a fresh paper cluster.
pub fn run_one(
    choice: SchedulerChoice,
    n_nodes: usize,
    trace: Vec<Pod>,
    mutate_cfg: impl FnOnce(&mut SimConfig),
) -> SimReport {
    let mut cfg = SimConfig::default();
    cfg.scheduler = choice;
    mutate_cfg(&mut cfg);
    let mut sim = Simulation::new(paper_nodes(n_nodes), Registry::with_corpus(), cfg);
    sim.run_trace(trace)
}

/// Run all three schedulers on the same trace (paired comparison).
pub fn run_all(
    n_nodes: usize,
    trace: &[Pod],
    mutate_cfg: impl Fn(&mut SimConfig),
) -> Vec<SimReport> {
    SchedulerChoice::all()
        .into_iter()
        .map(|c| run_one(c, n_nodes, trace.to_vec(), &mutate_cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_matches_section_6a() {
        let nodes = paper_nodes(4);
        assert_eq!(nodes.len(), 4);
        assert_eq!(nodes[0].capacity, Resources::cores_gb(4.0, 4.0));
        assert_eq!(nodes[1].capacity, Resources::cores_gb(4.0, 2.0));
        assert_eq!(nodes[0].disk, Bytes::from_gb(30.0));
        assert_eq!(nodes[2].disk, Bytes::from_gb(20.0));
        assert_eq!(nodes[3].name, "worker4");
    }

    #[test]
    fn trace_is_paired_across_runs() {
        let t1 = paper_trace(1, 20);
        let t2 = paper_trace(1, 20);
        assert_eq!(t1.len(), 20);
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.image, b.image);
        }
    }

    #[test]
    fn run_all_produces_three_reports() {
        let trace = paper_trace(5, 5);
        let reports = run_all(3, &trace, |_| {});
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0].scheduler, "Default");
        assert_eq!(reports[2].scheduler, "LRScheduler");
    }
}
